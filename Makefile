# Repo-level entry points. The Rust workspace lives under rust/.

.PHONY: verify verify-quick build test bench artifacts

# Tier-1 gate + hygiene (fmt/clippy when installed): one command for CI
# and for every later PR.
verify:
	bash scripts/verify.sh

# Build + test + rustdoc gate only (no smokes, no fmt/clippy) — the
# fast CI leg and the pre-push sanity loop.
verify-quick:
	bash scripts/verify.sh --quick

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench

# AOT-lower the JAX model + Pallas kernels to HLO artifacts (build-time
# only; needs the python toolchain — see python/compile/aot.py).
artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts --configs test
