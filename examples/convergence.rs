//! End-to-end validation driver (Fig. 4 + Table 1): trains a real
//! transformer with EVERY method of the paper over the synthetic
//! corpus through the full three-layer stack, logging loss curves and
//! the probe-PPL table. This is the run recorded in EXPERIMENTS.md.
//!
//! Run:   cargo run --release --example convergence -- \
//!            [--model tiny] [--steps 240] [--noisy] [--mesh 2x4]
//! Costs: ~minutes at the default `test` scale; use `--model tiny
//!        --steps 240` for the headline run (longer).

use edit_train::coordinator::{MeshSpec, Method};
use edit_train::experiments::{convergence, ExpOpts};
use edit_train::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mesh = {
        let s = args.str("mesh", "2x4");
        let (m, n) = s.split_once('x').unwrap_or(("2", "4"));
        MeshSpec::new(m.parse()?, n.parse()?)
    };
    let opts = ExpOpts {
        model: args.str("model", "test"),
        steps: args.u64("steps", 96),
        tau: args.u64("tau", 8),
        mesh,
        log: args.flag("log"),
        ..ExpOpts::default()
    };
    let noisy = args.flag("noisy");
    let methods = Method::ALL;

    println!(
        "convergence driver: model={} steps={} mesh={}x{} corpus={}",
        opts.model,
        opts.steps,
        opts.mesh.shard,
        opts.mesh.replicas,
        if noisy { "noisy" } else { "clean" }
    );
    let finals = convergence::fig4(&opts, &methods, noisy)?;
    convergence::table1(&opts, &methods, noisy)?;

    // The paper's headline ordering: EDiT at or near the best loss.
    let edit = finals.iter().find(|(m, _, _)| *m == Method::Edit).unwrap();
    let best = finals
        .iter()
        .map(|&(_, loss, _)| loss)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nEDiT final loss {:.4} vs best {:.4} (gap {:+.4})",
        edit.1,
        best,
        edit.1 - best
    );
    Ok(())
}
