//! CI perf gate: diff the machine-readable bench snapshots
//! (`results/bench_summary.json` from `cargo bench --bench hotpath`,
//! `results/bench_collectives.json` from `--bench collectives`) against
//! the committed baseline (`BENCH_BASELINE.json` at the repo root) and
//! exit non-zero on regression.
//!
//! The baseline is a list of gates, each a dotted path into a summary
//! plus a band:
//!
//!  * `exact` — the value must match exactly (schema version pins);
//!  * `min` + optional `tolerance` — the value must be at least
//!    `min * (1 - tolerance)`;
//!  * `max` + optional `tolerance` — the value must be at most
//!    `max * (1 + tolerance)`; a gate may carry both `min` and `max`
//!    (a band — used for the measured-vs-analytic cross-validation
//!    ratios, where drifting high is as wrong as drifting low).
//!
//! Timing-derived gates carry wide tolerances (shared CI runners);
//! deterministic gates — the bytes-on-wire reduction comes straight
//! from the comm-plan byte accounting — carry none.
//!
//! A gate reads from the default summary unless it names a `file`
//! (path relative to the working directory, e.g.
//! `results/bench_collectives.json`). A gate whose path is missing
//! from its summary **fails**: silently dropping a tracked metric is
//! itself a regression.
//!
//! Paths default to the CI layout (`cd rust && cargo run --release
//! --example bench_gate`); override with `EDIT_BENCH_SUMMARY` /
//! `EDIT_BENCH_BASELINE`.

use anyhow::Context;
use edit_train::util::json::Json;
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let summary_path = std::env::var("EDIT_BENCH_SUMMARY")
        .unwrap_or_else(|_| "results/bench_summary.json".to_string());
    let baseline_path = std::env::var("EDIT_BENCH_BASELINE")
        .unwrap_or_else(|_| "../BENCH_BASELINE.json".to_string());

    let baseline = Json::parse(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading {baseline_path}"))?,
    )
    .with_context(|| format!("parsing {baseline_path}"))?;

    let gates = baseline
        .at(&["gates"])
        .and_then(Json::as_arr)
        .context("baseline has no 'gates' array")?;

    // Summaries are loaded lazily and cached: most gates read the
    // hotpath summary, a few read the collectives one.
    let mut cache: HashMap<String, Option<Json>> = HashMap::new();
    let mut failures = 0usize;
    for gate in gates {
        let path = gate
            .at(&["path"])
            .and_then(Json::as_str)
            .context("gate entry missing 'path'")?;
        let file = gate
            .at(&["file"])
            .and_then(Json::as_str)
            .unwrap_or(&summary_path)
            .to_string();
        let summary = cache.entry(file.clone()).or_insert_with(|| {
            std::fs::read_to_string(&file)
                .ok()
                .and_then(|s| Json::parse(&s).ok())
        });
        let Some(summary) = summary else {
            println!("FAIL {path}: cannot read/parse {file} (run the benches first)");
            failures += 1;
            continue;
        };
        let keys: Vec<&str> = path.split('.').collect();
        let value = match summary.at(&keys).and_then(Json::as_f64) {
            Some(v) => v,
            None => {
                println!("FAIL {path}: missing from {file}");
                failures += 1;
                continue;
            }
        };
        let tol = gate.at(&["tolerance"]).and_then(Json::as_f64).unwrap_or(0.0);
        if let Some(exact) = gate.at(&["exact"]).and_then(Json::as_f64) {
            if value != exact {
                println!("FAIL {path}: {value} != required {exact}");
                failures += 1;
            } else {
                println!("ok   {path}: {value} (exact)");
            }
            continue;
        }
        let min = gate.at(&["min"]).and_then(Json::as_f64);
        let max = gate.at(&["max"]).and_then(Json::as_f64);
        if min.is_none() && max.is_none() {
            println!("FAIL {path}: gate has none of 'exact', 'min', 'max'");
            failures += 1;
            continue;
        }
        let mut bad = false;
        if let Some(min) = min {
            let floor = min * (1.0 - tol);
            if value < floor {
                println!(
                    "FAIL {path}: {value:.4} < floor {floor:.4} (baseline {min}, tolerance {tol})"
                );
                bad = true;
            }
        }
        if let Some(max) = max {
            let ceil = max * (1.0 + tol);
            if value > ceil {
                println!(
                    "FAIL {path}: {value:.4} > ceiling {ceil:.4} (baseline {max}, tolerance {tol})"
                );
                bad = true;
            }
        }
        if bad {
            failures += 1;
        } else {
            println!("ok   {path}: {value:.4} within band");
        }
    }

    if failures > 0 {
        anyhow::bail!("{failures} perf gate(s) failed against {baseline_path}");
    }
    println!("bench gate: all {} gates passed", gates.len());
    Ok(())
}
