//! CI perf gate: diff the machine-readable bench snapshot
//! (`results/bench_summary.json`, written by `cargo bench --bench
//! hotpath`) against the committed baseline (`BENCH_BASELINE.json` at
//! the repo root) and exit non-zero on regression.
//!
//! The baseline is a list of gates, each a dotted path into the summary
//! plus a band:
//!
//!  * `exact` — the value must match exactly (schema version pins);
//!  * `min` + optional `tolerance` — the value must be at least
//!    `min * (1 - tolerance)`. Timing-derived gates carry wide
//!    tolerances (shared CI runners); deterministic gates — the
//!    bytes-on-wire reduction comes straight from the comm-plan byte
//!    accounting — carry none.
//!
//! A gate whose path is missing from the summary **fails**: silently
//! dropping a tracked metric is itself a regression.
//!
//! Paths default to the CI layout (`cd rust && cargo run --release
//! --example bench_gate`); override with `EDIT_BENCH_SUMMARY` /
//! `EDIT_BENCH_BASELINE`.

use anyhow::Context;
use edit_train::util::json::Json;

fn main() -> anyhow::Result<()> {
    let summary_path = std::env::var("EDIT_BENCH_SUMMARY")
        .unwrap_or_else(|_| "results/bench_summary.json".to_string());
    let baseline_path = std::env::var("EDIT_BENCH_BASELINE")
        .unwrap_or_else(|_| "../BENCH_BASELINE.json".to_string());

    let summary = Json::parse(
        &std::fs::read_to_string(&summary_path)
            .with_context(|| format!("reading {summary_path} (run the hotpath bench first)"))?,
    )
    .with_context(|| format!("parsing {summary_path}"))?;
    let baseline = Json::parse(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading {baseline_path}"))?,
    )
    .with_context(|| format!("parsing {baseline_path}"))?;

    let gates = baseline
        .at(&["gates"])
        .and_then(Json::as_arr)
        .context("baseline has no 'gates' array")?;

    let mut failures = 0usize;
    for gate in gates {
        let path = gate
            .at(&["path"])
            .and_then(Json::as_str)
            .context("gate entry missing 'path'")?;
        let keys: Vec<&str> = path.split('.').collect();
        let value = match summary.at(&keys).and_then(Json::as_f64) {
            Some(v) => v,
            None => {
                println!("FAIL {path}: missing from {summary_path}");
                failures += 1;
                continue;
            }
        };
        if let Some(exact) = gate.at(&["exact"]).and_then(Json::as_f64) {
            if value != exact {
                println!("FAIL {path}: {value} != required {exact}");
                failures += 1;
            } else {
                println!("ok   {path}: {value} (exact)");
            }
        } else if let Some(min) = gate.at(&["min"]).and_then(Json::as_f64) {
            let tol = gate.at(&["tolerance"]).and_then(Json::as_f64).unwrap_or(0.0);
            let floor = min * (1.0 - tol);
            if value < floor {
                println!("FAIL {path}: {value:.4} < floor {floor:.4} (baseline {min}, tolerance {tol})");
                failures += 1;
            } else {
                println!("ok   {path}: {value:.4} >= floor {floor:.4}");
            }
        } else {
            println!("FAIL {path}: gate has neither 'exact' nor 'min'");
            failures += 1;
        }
    }

    if failures > 0 {
        anyhow::bail!("{failures} perf gate(s) failed against {baseline_path}");
    }
    println!("bench gate: all {} gates passed", gates.len());
    Ok(())
}
