//! Elastic-training scenario (Fig. 6c): scale the replica count
//! 1→2→4→8 and 8→4→2→1 at a fixed learning rate and compare the PPL
//! trajectories of Baseline vs EDiT across rescale boundaries.
//!
//! Run: cargo run --release --example elastic -- [--phase-steps 24] [--lr 2e-3]

use edit_train::experiments::{scaling, ExpOpts};
use edit_train::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let opts = ExpOpts {
        model: args.str("model", "test"),
        tau: args.u64("tau", 4),
        ..ExpOpts::default()
    };
    scaling::fig6c(&opts, args.u64("phase-steps", 24), args.f64("lr", 2e-3))?;
    println!("curves -> results/fig6c_elastic.csv");
    Ok(())
}
