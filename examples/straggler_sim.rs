//! Straggler & bandwidth study (Fig. 5 / Table 6) twice over:
//!
//! 1. the analytic A100-cluster simulator at paper scale (7B, 8×8), and
//! 2. the REAL numerics path with injected virtual-clock lag at the CPU
//!    scale, demonstrating that A-EDiT's time-based sync lets fast
//!    replicas keep stepping while EDiT waits (paper §3.3).
//!
//! Run: cargo run --release --example straggler_sim

use edit_train::coordinator::{Method, Straggler};
use edit_train::data::Quality;
use edit_train::experiments::{throughput, ExpOpts};
use edit_train::metrics::Table;

fn main() -> anyhow::Result<()> {
    let opts = ExpOpts::default();

    // --- paper-scale analytic study -----------------------------------------
    throughput::fig5(&opts)?;

    // --- real numerics path with injected lag --------------------------------
    println!("\nReal numerics path (test model, consistent straggler on replica 0):");
    let mut table = Table::new(&[
        "method",
        "lag (s/step)",
        "sim time (s)",
        "tokens/sim-s",
        "steps r0/r1",
    ]);
    for method in [Method::Edit, Method::AEdit] {
        for lag in [0.0, 1.0, 2.0] {
            let mut o = opts.clone();
            o.steps = 24;
            o.tau = 4;
            let mut t = o.trainer(method, Quality::clean(), 6)?;
            t.cfg.t_warm = 0;
            if lag > 0.0 {
                t.cfg.straggler = Straggler::Consistent { lag, replica: 0 };
            }
            let summary = t.run()?;
            table.row(vec![
                method.name().into(),
                format!("{lag}"),
                format!("{:.1}", summary.sim_seconds),
                format!("{:.1}", summary.throughput),
                format!("{}/{}", t.replicas[0].inner_steps, t.replicas[1].inner_steps),
            ]);
        }
    }
    print!("{}", table.render());
    println!("note: A-EDiT's fast replicas take MORE inner steps under lag;");
    println!("      EDiT's replicas stay in lock-step and wait.");
    Ok(())
}
