//! Theorem 1 validation: with SGD as both inner and outer optimizer and
//! the η/√(tτ+p+1) inner schedule, the minimum expected squared
//! gradient norm converges at rate O(log T / √T).
//!
//! We run the EDiT update algebra (pseudo gradients + clip + outer SGD,
//! pure Rust, no PJRT) on a noisy strongly-convex quadratic
//!     L(θ) = ½ θᵀ A θ,  g = A θ + ζ,  ζ ~ N(0, σ²)
//! across N simulated workers, record min-so-far ‖∇L‖², and check the
//! empirical rate against the bound's shape.
//!
//! Run: cargo run --release --example theorem1

use edit_train::coordinator::penalty::{combine, PenaltyConfig};
use edit_train::coordinator::schedule::LrSchedule;
use edit_train::metrics::CsvWriter;
use edit_train::tensor;
use edit_train::util::prng::Rng;

const DIM: usize = 64;
const WORKERS: usize = 4;
const TAU: u64 = 8;
const OUTER_STEPS: u64 = 4000;
const ETA: f64 = 0.2;
const NU: f32 = 1.0; // outer SGD lr
const SIGMA: f32 = 0.05;

fn grad(a: &[f32], theta: &[f32], rng: &mut Rng, out: &mut [f32]) {
    for i in 0..theta.len() {
        out[i] = a[i] * theta[i] + SIGMA * rng.normal_f32();
    }
}

fn true_grad_sq(a: &[f32], theta: &[f32]) -> f64 {
    theta
        .iter()
        .zip(a)
        .map(|(&t, &ai)| (ai * t) as f64 * (ai * t) as f64)
        .sum()
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    // Diagonal curvature in [0.2, 1.0] — L-smooth with L = 1.
    let a: Vec<f32> = (0..DIM).map(|_| 0.2 + 0.8 * rng.f32()).collect();
    let mut anchor: Vec<f32> = (0..DIM).map(|_| rng.normal_f32()).collect();
    let schedule = LrSchedule::InvSqrt { lr: ETA };
    let penalty = PenaltyConfig::default();

    let mut csv = CsvWriter::create(
        "results/theorem1_rate.csv",
        &["outer_step", "min_grad_sq", "bound_shape"],
    )?;

    let mut min_grad_sq = f64::INFINITY;
    let mut checkpoints: Vec<(f64, f64)> = Vec::new(); // (T, min ||∇L||²)
    let mut workers: Vec<Vec<f32>> = vec![anchor.clone(); WORKERS];
    let mut scratch = vec![0.0f32; DIM];

    for t in 0..OUTER_STEPS {
        // Inner loop: τ SGD steps per worker on its own noise stream.
        for (w, theta) in workers.iter_mut().enumerate() {
            let mut wrng = rng.child((t as u64) << 8 | w as u64);
            for p in 0..TAU {
                let lr = schedule.at(t * TAU + p) as f32;
                grad(&a, theta, &mut wrng, &mut scratch);
                for i in 0..DIM {
                    theta[i] -= lr * scratch[i];
                }
            }
            min_grad_sq = min_grad_sq.min(true_grad_sq(&a, theta));
        }
        // EDiT sync: pseudo gradients + penalty combine + outer SGD.
        let deltas: Vec<Vec<f32>> = workers
            .iter()
            .map(|theta| {
                let mut d = vec![0.0f32; DIM];
                tensor::sub(&mut d, theta, &anchor);
                d
            })
            .collect();
        let norms: Vec<f64> = deltas.iter().map(|d| tensor::norm(d)).collect();
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let out = combine(&refs, &norms, &penalty);
        if !out.rollback {
            tensor::axpy(&mut anchor, NU, &out.delta);
        }
        for theta in workers.iter_mut() {
            theta.copy_from_slice(&anchor);
        }
        min_grad_sq = min_grad_sq.min(true_grad_sq(&a, &anchor));

        if (t + 1).is_power_of_two() || t + 1 == OUTER_STEPS {
            let big_t = (t + 1) as f64;
            let bound = (1.0 + (big_t * TAU as f64).ln()) / big_t.sqrt();
            csv.row_f64(&[big_t, min_grad_sq, bound])?;
            checkpoints.push((big_t, min_grad_sq));
            println!(
                "T = {:>5}: min ||∇L||² = {:.3e}   bound shape log(τT)/√T = {:.3e}",
                t + 1,
                min_grad_sq,
                bound
            );
        }
    }
    csv.flush()?;

    // Empirical rate: fit slope of log(min_grad_sq) vs log(T) over the
    // tail. Theorem: ≤ -0.5 (up to log factors); noise floor may flatten
    // the very end, so fit the middle region.
    let fit: Vec<(f64, f64)> = checkpoints
        .iter()
        .filter(|&&(t, _)| t >= 8.0 && t <= 1024.0)
        .map(|&(t, v)| (t.ln(), v.ln()))
        .collect();
    let n = fit.len() as f64;
    let (sx, sy): (f64, f64) = fit.iter().fold((0.0, 0.0), |(a, b), &(x, y)| (a + x, b + y));
    let (sxx, sxy): (f64, f64) = fit
        .iter()
        .fold((0.0, 0.0), |(a, b), &(x, y)| (a + x * x, b + x * y));
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    println!("\nempirical rate: min ||∇L||² ~ T^{slope:.2} (theorem: ≤ T^-0.5 · log)");
    assert!(
        slope < -0.4,
        "convergence rate too slow: slope {slope:.2} (want < -0.4)"
    );
    println!("theorem1 OK — rate consistent with O(log T / sqrt(T))");
    Ok(())
}
