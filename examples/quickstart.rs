//! Quickstart: the smallest end-to-end tour of the three-layer stack.
//!
//! 1. Load the AOT artifacts (L2 JAX model + L1 Pallas kernels, lowered
//!    to HLO text by `make artifacts`) into the PJRT CPU runtime.
//! 2. Train a few EDiT rounds on a 2×2 mesh over the synthetic corpus.
//! 3. Run one pseudo-gradient penalty combine through the AOT Pallas
//!    kernel (the L1 path the coordinator can use at sync time).
//!
//! Run: `cargo run --release --example quickstart`

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{MeshSpec, Method, TrainConfig, Trainer};
use edit_train::data::{Corpus, Quality};
use edit_train::runtime::Engine;
use edit_train::tensor;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // --- 1. runtime ---------------------------------------------------------
    let engine = Engine::load(&artifacts, "test")?;
    println!(
        "loaded '{}' on {}: {} params, {} modules",
        engine.manifest.model.name,
        engine.platform(),
        engine.manifest.total_params,
        engine.manifest.table.num_modules()
    );

    // --- 2. a short EDiT run ------------------------------------------------
    let corpus = Corpus::new(engine.manifest.model.vocab_size, 42, Quality::clean());
    let mesh = MeshSpec::new(2, 2); // 2-way sharding x 2 replicas
    let mut cfg = TrainConfig::paper_default(Method::Edit, mesh, 24);
    cfg.tau = 4;
    cfg.t_warm = 4;
    cfg.log_every = 1;
    let mut trainer = Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100()))?;
    let summary = trainer.run()?;
    println!(
        "EDiT: final loss {:.3}, val PPL {:.2}, {} syncs, {:.1} simulated s",
        summary.final_loss, summary.final_ppl, summary.syncs, summary.sim_seconds
    );

    // --- 3. the L1 penalty kernel through PJRT ------------------------------
    let engine = trainer.engine_mut();
    if engine.has_penalty_program(2) {
        let n = engine.manifest.total_params;
        let deltas: Vec<Vec<f32>> = (0..2)
            .map(|j| (0..n).map(|i| ((i + j) % 13) as f32 / 13.0 - 0.5).collect())
            .collect();
        let norms: Vec<f32> = deltas.iter().map(|d| tensor::norm(d) as f32).collect();
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let combined = engine.penalty_combine(&refs, &norms)?;
        println!(
            "penalty combine via Pallas HLO: |out| = {:.4} (phi = {})",
            tensor::norm(&combined),
            engine.manifest.penalty_phi
        );
    } else {
        println!(
            "penalty HLO not executable on this backend (stub runtime, or artifacts \
             exported without penalty programs); skipping the L1 kernel demo"
        );
    }
    println!("quickstart OK");
    Ok(())
}
