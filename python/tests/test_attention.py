"""Pallas flash-attention kernel vs the pure-jnp oracle.

The core L1 correctness signal: hypothesis sweeps shapes/blocks/masking
and asserts allclose for forward AND both backward kernels.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    _pick_block,
    flash_attention,
    vmem_bytes_estimate,
)
from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _check(b, h, s, d, causal, bq, bk, seed=0, fwd_tol=2e-5, bwd_tol=2e-4):
    q, k, v = (_rand((b, h, s, d), seed + i) for i in range(3))
    out = flash_attention(q, k, v, causal, None, bq, bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=fwd_tol, rtol=1e-4)

    def scalar_loss(fn):
        return lambda q, k, v: jnp.sum(jnp.tanh(fn(q, k, v)))

    g_pl = jax.grad(
        scalar_loss(lambda q, k, v: flash_attention(q, k, v, causal, None, bq, bk)),
        argnums=(0, 1, 2),
    )(q, k, v)
    g_ref = jax.grad(
        scalar_loss(lambda q, k, v: attention_ref(q, k, v, causal=causal)),
        argnums=(0, 1, 2),
    )(q, k, v)
    for got, want in zip(g_pl, g_ref):
        np.testing.assert_allclose(got, want, atol=bwd_tol, rtol=1e-3)


class TestForwardBackward:
    @pytest.mark.parametrize("causal", [True, False])
    def test_basic(self, causal):
        _check(2, 2, 64, 16, causal, 32, 16)

    def test_single_block(self):
        _check(1, 1, 32, 8, True, 32, 32)

    def test_block_larger_than_seq_shrinks(self):
        _check(1, 2, 16, 8, True, 128, 128)

    def test_uneven_blocks(self):
        # bq != bk exercises the rectangular masking path.
        _check(1, 2, 64, 8, True, 16, 32)

    def test_head_dim_one(self):
        _check(1, 1, 16, 2, True, 8, 8)

    def test_matches_under_jit(self):
        q, k, v = (_rand((1, 2, 32, 8), i) for i in range(3))
        f = jax.jit(lambda q, k, v: flash_attention(q, k, v, True, None, 16, 16))
        np.testing.assert_allclose(
            f(q, k, v), attention_ref(q, k, v, causal=True), atol=2e-5, rtol=1e-4
        )

    def test_custom_scale(self):
        q, k, v = (_rand((1, 1, 32, 8), i + 5) for i in range(3))
        out = flash_attention(q, k, v, True, 0.25, 16, 16)
        ref = attention_ref(q, k, v, causal=True, sm_scale=0.25)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)

    def test_value_and_grad_composes_with_matmul(self):
        # The kernel must differentiate correctly when composed into a
        # larger graph (as model.py does).
        q, k, v = (_rand((1, 2, 32, 8), i + 9) for i in range(3))
        w = _rand((8, 8), 42)

        def f(q, k, v, w):
            o = flash_attention(q, k, v, True, None, 16, 16)
            return jnp.sum((o @ w) ** 2)

        def f_ref(q, k, v, w):
            o = attention_ref(q, k, v, causal=True)
            return jnp.sum((o @ w) ** 2)

        got = jax.grad(f, argnums=(0, 1, 2, 3))(q, k, v, w)
        want = jax.grad(f_ref, argnums=(0, 1, 2, 3))(q, k, v, w)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=5e-4, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    logs=st.integers(3, 6),  # seq = 8..64
    logd=st.integers(1, 4),  # head_dim = 2..16
    causal=st.booleans(),
    bq=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 1000),
)
def test_hypothesis_sweep(b, h, logs, logd, causal, bq, bk, seed):
    _check(b, h, 2 ** logs, 2 ** logd, causal, bq, bk, seed=seed)


class TestPickBlock:
    def test_divides(self):
        for s in [8, 24, 96, 128, 384]:
            for r in [8, 64, 128, 100]:
                blk = _pick_block(s, r)
                assert s % blk == 0 and 1 <= blk <= max(r, 1)

    def test_exact(self):
        assert _pick_block(128, 128) == 128
        assert _pick_block(96, 128) == 96
        # halving from the request: 64 -> 32 (divides 96)
        assert _pick_block(96, 64) == 32


def test_vmem_estimate_within_tpu_budget():
    # The real-TPU viability claim: fwd working set fits v4/v5e VMEM (~16 MiB)
    # for the paper's context length (4096) at head_dim 128.
    assert vmem_bytes_estimate(4096, 128) < 16 * 1024 * 1024


def test_lse_not_materializing_full_matrix():
    # Long-seq sanity run: would OOM/N^2 blow up if the kernel materialized
    # the full attention matrix in one block. 1x1x512x8 stays fast & finite.
    q, k, v = (_rand((1, 1, 512, 8), i) for i in range(3))
    out = flash_attention(q, k, v, True, None, 64, 64)
    assert bool(jnp.all(jnp.isfinite(out)))
