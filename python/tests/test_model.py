"""L2 model: shapes, flat-layout contract, AdamW reference, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["test"]


def _tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(
        0, cfg.vocab_size, (cfg.batch_size, cfg.seq_len + 1)
    ).astype(np.int32)


class TestFlatLayout:
    def test_table_covers_vector_exactly(self):
        _, total, table = M.flatten_spec(CFG)
        offsets = sorted((off, size) for _, _, off, size in table)
        pos = 0
        for off, size in offsets:
            assert off == pos
            pos += size
        assert pos == total

    def test_table_matches_init_flat(self):
        _, total, _ = M.flatten_spec(CFG)
        assert M.init_flat(CFG).shape == (total,)

    def test_unravel_roundtrip(self):
        unravel, total, _ = M.flatten_spec(CFG)
        flat = M.init_flat(CFG, seed=3)
        from jax.flatten_util import ravel_pytree

        flat2, _ = ravel_pytree(unravel(flat))
        np.testing.assert_array_equal(flat, flat2)

    def test_stacked_tensors_marked(self):
        _, _, table = M.flatten_spec(CFG)
        for name, shape, _, _ in table:
            if name.startswith("layers."):
                assert shape[0] == CFG.num_layers

    def test_param_count_formula(self):
        # embed + head + L*(2 ln + 4 attn + 3 mlp) + ln_f
        d, f, v, nl = (
            CFG.hidden_size,
            CFG.intermediate_size,
            CFG.vocab_size,
            CFG.num_layers,
        )
        expected = v * d * 2 + d + nl * (2 * d + 4 * d * d + 2 * d * f + f * d)
        _, total, _ = M.flatten_spec(CFG)
        assert total == expected

    def test_deterministic_init(self):
        np.testing.assert_array_equal(
            M.init_flat(CFG, seed=1), M.init_flat(CFG, seed=1)
        )
        assert not np.array_equal(M.init_flat(CFG, 1), M.init_flat(CFG, 2))


class TestForward:
    def test_logit_shape(self):
        params = M.init_params(CFG)
        toks = _tokens(CFG)[:, :-1]
        logits = M.forward(CFG, params, toks)
        assert logits.shape == (
            CFG.batch_size,
            CFG.seq_len,
            CFG.vocab_size,
        )

    def test_causality(self):
        # Changing a future token must not change past logits.
        params = M.init_params(CFG)
        toks = _tokens(CFG)[:, :-1]
        logits1 = M.forward(CFG, params, toks)
        toks2 = toks.copy()
        toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab_size
        logits2 = M.forward(CFG, params, toks2)
        np.testing.assert_allclose(
            logits1[:, :-1], logits2[:, :-1], atol=1e-5
        )

    def test_initial_loss_near_uniform(self):
        params = M.init_params(CFG)
        loss = M.loss_fn(CFG, params, _tokens(CFG))
        assert abs(float(loss) - np.log(CFG.vocab_size)) < 1.0


class TestAdamW:
    def _numpy_adamw(self, cfg, p, m, v, g, lr, t):
        norm = np.sqrt((g.astype(np.float64) ** 2).sum())
        g = g * min(cfg.grad_clip / (norm + 1e-12), 1.0)
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh = m / (1 - cfg.beta1 ** t)
        vh = v / (1 - cfg.beta2 ** t)
        upd = mh / (np.sqrt(vh) + cfg.adam_eps) + cfg.weight_decay * p
        return p - lr * upd, m, v

    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        n = 257
        p, m, v, g = (rng.standard_normal(n).astype(np.float32) for _ in range(4))
        m = np.abs(m) * 0.01
        v = np.abs(v) * 0.01
        got = M.adamw_update(
            CFG,
            jnp.asarray(p),
            jnp.asarray(m),
            jnp.asarray(v),
            jnp.asarray(g),
            jnp.float32(1e-3),
            jnp.int32(3),
        )
        want = self._numpy_adamw(CFG, p, m, v, g, 1e-3, 3)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)

    def test_clip_engages(self):
        n = 64
        g = np.full(n, 100.0, np.float32)
        z = np.zeros(n, np.float32)
        p1, _, _ = M.adamw_update(
            CFG, jnp.asarray(z), jnp.asarray(z), jnp.asarray(z),
            jnp.asarray(g), jnp.float32(1.0), jnp.int32(1),
        )
        # Clipped grad has norm 1 -> per-element update bounded.
        assert float(jnp.max(jnp.abs(p1))) < 1.5


class TestPrograms:
    @pytest.fixture(scope="class")
    def progs(self):
        return M.build_programs(CFG)

    def test_train_step_decreases_loss(self, progs):
        flat = M.init_flat(CFG)
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        tok = _tokens(CFG)
        ts = jax.jit(progs["train_step"][0])
        losses = []
        for i in range(8):
            flat, m, v, loss = ts(flat, m, v, tok, jnp.float32(3e-3), jnp.int32(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5

    def test_grad_then_apply_equals_train(self, progs):
        flat = M.init_flat(CFG)
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        tok = _tokens(CFG)
        lr, st = jnp.float32(1e-3), jnp.int32(1)
        p1, m1, v1, loss1 = jax.jit(progs["train_step"][0])(flat, m, v, tok, lr, st)
        g, loss2 = jax.jit(progs["grad_step"][0])(flat, tok)
        p2, m2, v2 = jax.jit(progs["apply_step"][0])(flat, m, v, g, lr, st)
        assert abs(float(loss1) - float(loss2)) < 1e-6
        np.testing.assert_allclose(p1, p2, atol=1e-6)
        np.testing.assert_allclose(m1, m2, atol=1e-7)
        np.testing.assert_allclose(v1, v2, atol=1e-7)

    def test_eval_matches_loss(self, progs):
        flat = M.init_flat(CFG)
        tok = _tokens(CFG)
        ev = jax.jit(progs["eval_step"][0])(flat, tok)[0]
        _, loss = jax.jit(progs["grad_step"][0])(flat, tok)
        assert abs(float(ev) - float(loss)) < 1e-6

    def test_example_arg_shapes(self, progs):
        _, total, _ = M.flatten_spec(CFG)
        fn, args = progs["train_step"]
        assert args[0].shape == (total,)
        assert args[3].shape == (CFG.batch_size, CFG.seq_len + 1)
