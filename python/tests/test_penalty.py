"""Pallas pseudo-gradient-penalty kernels vs oracle + invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.penalty import (
    penalty_combine,
    softmax_neg_weights,
    sq_norms,
    weighted_sum_scaled,
)
from compile.kernels.ref import penalty_ref, sq_norms_ref, weighted_sum_ref

jax.config.update("jax_platform_name", "cpu")


def _deltas(w, n, seed, scale=1.0):
    return (
        jax.random.normal(jax.random.PRNGKey(seed), (w, n), jnp.float32) * scale
    )


class TestKernelsVsRef:
    def test_sq_norms(self):
        d = _deltas(4, 96, 0)
        np.testing.assert_allclose(
            sq_norms(d, chunk=32), sq_norms_ref(d), rtol=1e-5
        )

    def test_weighted_sum(self):
        d = _deltas(3, 60, 1)
        w = jnp.asarray([0.2, 0.5, 0.3], jnp.float32)
        np.testing.assert_allclose(
            weighted_sum_scaled(d, w, jnp.float32(1.0), chunk=10),
            weighted_sum_ref(d, w),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_combine_matches_ref(self):
        d = _deltas(4, 128, 2)
        norms = jnp.sqrt(sq_norms_ref(d))
        out, w, beta = penalty_combine(d, norms, phi=10.0, chunk=32)
        ro, rw, rb = penalty_ref(d, norms, 10.0)
        np.testing.assert_allclose(out, ro, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w, rw, rtol=1e-5)
        np.testing.assert_allclose(beta, rb, rtol=1e-5)

    def test_combine_with_anomaly(self):
        d = _deltas(4, 64, 3)
        norms = jnp.sqrt(sq_norms_ref(d)).at[1].set(jnp.inf)
        out, w, beta = penalty_combine(d, norms, phi=10.0, chunk=16)
        ro, rw, _ = penalty_ref(d, norms, 10.0)
        assert float(w[1]) == 0.0
        np.testing.assert_allclose(out, ro, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w, rw, rtol=1e-5)


class TestInvariants:
    def test_weights_simplex(self):
        norms = jnp.asarray([1.0, 2.0, 0.5, 3.0])
        w = softmax_neg_weights(norms)
        assert float(jnp.sum(w)) == 1.0 or abs(float(jnp.sum(w)) - 1.0) < 1e-6
        assert bool(jnp.all(w >= 0))

    def test_larger_norm_smaller_weight(self):
        norms = jnp.asarray([0.1, 5.0, 1.0])
        w = softmax_neg_weights(norms)
        assert float(w[0]) > float(w[2]) > float(w[1])

    def test_all_anomalous_zero(self):
        d = _deltas(3, 32, 4)
        norms = jnp.full((3,), jnp.inf)
        out, w, _ = penalty_combine(d, norms, phi=10.0, chunk=8)
        assert float(jnp.max(jnp.abs(out))) == 0.0
        assert float(jnp.sum(w)) == 0.0

    def test_clip_never_increases_norm(self):
        d = _deltas(2, 64, 5, scale=100.0)
        norms = jnp.sqrt(sq_norms_ref(d))
        out, _, beta = penalty_combine(d, norms, phi=1.0, chunk=16)
        assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-4
        assert float(beta) < 1.0

    def test_clip_inactive_below_threshold(self):
        d = _deltas(2, 64, 6, scale=1e-3)
        norms = jnp.sqrt(sq_norms_ref(d))
        _, _, beta = penalty_combine(d, norms, phi=10.0, chunk=16)
        assert float(beta) == 1.0

    def test_uniform_norms_give_uniform_weights(self):
        # Equal-norm workers must contribute equally (reduces to DiLoCo
        # uniform averaging) — the EDiT==DiLoCo limit the Rust tests use.
        d = jnp.ones((4, 16), jnp.float32)
        norms = jnp.sqrt(sq_norms_ref(d))
        _, w, _ = penalty_combine(d, norms, phi=1e9, chunk=16)
        np.testing.assert_allclose(w, jnp.full((4,), 0.25), rtol=1e-6)

    def test_huge_norms_stable(self):
        # Softmax(-G) must not underflow to all-zeros for large but finite
        # norms (the min-shift stabilization).
        d = _deltas(3, 32, 7)
        norms = jnp.asarray([1000.0, 1001.0, 1002.0])
        out, w, _ = penalty_combine(d, norms, phi=10.0, chunk=8)
        assert abs(float(jnp.sum(w)) - 1.0) < 1e-5
        assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=15, deadline=None)
@given(
    w=st.integers(2, 8),
    chunks=st.integers(1, 6),
    chunk=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 10_000),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
    n_anom=st.integers(0, 2),
)
def test_hypothesis_combine(w, chunks, chunk, seed, scale, n_anom):
    n = chunks * chunk
    d = _deltas(w, n, seed, scale=scale)
    norms = jnp.sqrt(sq_norms_ref(d))
    for i in range(min(n_anom, w - 1)):
        norms = norms.at[i].set(jnp.inf)
    out, wts, beta = penalty_combine(d, norms, phi=10.0, chunk=chunk)
    ro, rw, rb = penalty_ref(d, norms, 10.0)
    np.testing.assert_allclose(out, ro, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(wts, rw, rtol=1e-5, atol=1e-7)
    assert abs(float(beta) - float(rb)) < 1e-4 * max(1.0, float(rb))
