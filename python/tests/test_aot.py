"""AOT export contract: HLO text well-formedness + manifest consistency."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import penalty as P

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_eval_step_produces_hlo_text():
    cfg = M.CONFIGS["test"]
    fn, args = M.build_programs(cfg)["eval_step"]
    text = aot.lower_fn(fn, args)
    assert text.startswith("HloModule")
    assert "ROOT" in text


def test_lower_penalty_produces_hlo_text():
    fn, args = P.penalty_for_aot(2, 64, phi=10.0)
    text = aot.lower_fn(fn, args)
    assert text.startswith("HloModule")


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(ARTIFACTS, "test")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "test", "manifest.json")) as f:
            return json.load(f)

    def test_manifest_total_matches_model(self, manifest):
        _, total, _ = M.flatten_spec(M.CONFIGS["test"])
        assert manifest["total_params"] == total

    def test_tensor_table_contiguous(self, manifest):
        pos = 0
        for t in manifest["tensors"]:
            assert t["offset"] == pos
            assert t["size"] == int(np.prod(t["shape"]))
            pos += t["size"]
        assert pos == manifest["total_params"]

    def test_init_bin_matches_model_init(self, manifest):
        path = os.path.join(ARTIFACTS, "test", manifest["init_file"])
        data = np.fromfile(path, dtype="<f4")
        expect = np.asarray(
            M.init_flat(M.CONFIGS["test"], seed=manifest["init_seed"])
        )
        np.testing.assert_array_equal(data, expect)

    def test_all_program_files_exist(self, manifest):
        for fname in list(manifest["programs"].values()) + list(
            manifest["penalty_programs"].values()
        ):
            path = os.path.join(ARTIFACTS, "test", fname)
            assert os.path.isfile(path)
            with open(path) as f:
                assert f.read(9) == "HloModule"

    def test_golden_penalty_cases_valid(self):
        path = os.path.join(ARTIFACTS, "golden", "penalty.json")
        with open(path) as f:
            cases = json.load(f)
        assert len(cases) >= 3
        for case in cases:
            w, n = case["num_workers"], case["n"]
            assert len(case["deltas"]) == w * n
            assert len(case["expected"]) == n
            assert abs(sum(case["weights"]) - 1.0) < 1e-5 or sum(
                case["weights"]
            ) == 0.0
