"""AOT bridge: lower every exported program to HLO text + manifest.

Python runs ONCE (``make artifacts``); the Rust binary is self-contained
afterwards.  Interchange is HLO *text*, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config this writes ``artifacts/<config>/``:

  train_step.hlo.txt    fused inner step (fwd+bwd+clip+AdamW)
  grad_step.hlo.txt     grads+loss (DDP / warmup path)
  apply_step.hlo.txt    AdamW apply of externally averaged grads
  eval_step.hlo.txt     loss only
  penalty_w{N}.hlo.txt  Alg. 2 combine for sync groups of N workers
  init.bin              initial flat parameters (little-endian f32)
  manifest.json         flat layout table, shapes, hyperparameters

plus ``artifacts/golden/penalty.json`` — golden vectors the Rust unit
tests use to cross-check their pure-Rust penalty implementation against
the Pallas kernel.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import penalty as P

PENALTY_GROUP_SIZES = (2, 4, 8)


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (NOT proto serialization)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def export_config(cfg: M.ModelConfig, out_root: str, *, phi: float = 10.0,
                  group_sizes=PENALTY_GROUP_SIZES, seed: int = 0) -> dict:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)

    _, total, table = M.flatten_spec(cfg)
    programs = M.build_programs(cfg)

    files = {}
    for name, (fn, args) in programs.items():
        text = lower_fn(fn, args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[name] = fname
        print(f"  {cfg.name}/{fname}: {len(text)} chars")

    penalty_files = {}
    for n in group_sizes:
        fn, args = P.penalty_for_aot(n, total, phi=phi)
        text = lower_fn(fn, args)
        fname = f"penalty_w{n}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        penalty_files[str(n)] = fname
        print(f"  {cfg.name}/{fname}: {len(text)} chars")

    init = np.asarray(M.init_flat(cfg, seed=seed), dtype="<f4")
    init.tofile(os.path.join(out_dir, "init.bin"))

    manifest = {
        "config": {
            "name": cfg.name,
            "vocab_size": cfg.vocab_size,
            "num_layers": cfg.num_layers,
            "hidden_size": cfg.hidden_size,
            "intermediate_size": cfg.intermediate_size,
            "num_heads": cfg.num_heads,
            "seq_len": cfg.seq_len,
            "batch_size": cfg.batch_size,
            "beta1": cfg.beta1,
            "beta2": cfg.beta2,
            "adam_eps": cfg.adam_eps,
            "weight_decay": cfg.weight_decay,
            "grad_clip": cfg.grad_clip,
        },
        "total_params": total,
        "init_seed": seed,
        "penalty_phi": phi,
        "tensors": [
            {
                "name": name,
                "shape": list(shape),
                "offset": offset,
                "size": size,
                # Stacked per-layer tensors: leading dim == num_layers.
                "stacked": name.startswith("layers.")
                and len(shape) >= 1
                and shape[0] == cfg.num_layers,
            }
            for (name, shape, offset, size) in table
        ],
        "programs": files,
        "penalty_programs": penalty_files,
        "init_file": "init.bin",
        "token_shape": [cfg.batch_size, cfg.seq_len + 1],
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def export_golden(out_root: str, *, phi: float = 10.0, seed: int = 7) -> None:
    """Golden penalty vectors for the Rust cross-check tests."""
    rng = np.random.default_rng(seed)
    cases = []
    for w, n, anomalies in [(2, 16, []), (4, 64, [2]), (8, 32, [0, 5]),
                            (4, 48, [0, 1, 2, 3])]:
        deltas = rng.standard_normal((w, n)).astype(np.float32)
        norms = np.sqrt((deltas.astype(np.float64) ** 2).sum(-1)).astype(
            np.float32
        )
        norms[anomalies] = np.inf
        out, weights, beta = P.penalty_combine(
            jnp.asarray(deltas), jnp.asarray(norms), phi=phi, chunk=16
        )
        cases.append(
            {
                "phi": phi,
                "deltas": deltas.reshape(-1).tolist(),
                "num_workers": w,
                "n": n,
                "norms": ["inf" if not np.isfinite(x) else float(x)
                          for x in norms],
                "expected": np.asarray(out).reshape(-1).tolist(),
                "weights": np.asarray(weights).tolist(),
                "beta": float(beta),
            }
        )
    os.makedirs(os.path.join(out_root, "golden"), exist_ok=True)
    with open(os.path.join(out_root, "golden", "penalty.json"), "w") as f:
        json.dump(cases, f)
    print(f"  golden/penalty.json: {len(cases)} cases")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root directory")
    ap.add_argument("--configs", nargs="*", default=["test", "tiny"],
                    help=f"model presets to export (of {list(M.CONFIGS)})")
    ap.add_argument("--phi", type=float, default=10.0,
                    help="pseudo-gradient clip threshold baked into penalty")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for name in args.configs:
        cfg = M.CONFIGS[name]
        print(f"exporting config '{name}' ...")
        export_config(cfg, args.out, phi=args.phi)
    export_golden(args.out, phi=args.phi)
    print("done.")


if __name__ == "__main__":
    main()
