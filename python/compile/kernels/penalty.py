"""Pallas kernels for the pseudo-gradient penalty combine (Alg. 2).

The penalty pipeline at each EDiT synchronization is, per model sync
group of W workers over n sharded parameters:

  1. G_i   = ||Delta_i||_2                       (per-worker norms)
  2. w_i   = softmax(-G)_i  (anomalous G_i=inf -> w_i=0)
  3. bar   = sum_i w_i * Delta_i                 (weighted average)
  4. beta  = min(phi / (||bar|| + eps), 1)       (pseudo-gradient clip)
  5. out   = beta * bar

Steps 1/3/5 touch O(W*n) data and are the hot part; they are Pallas
kernels tiled over the parameter axis (grid over n/chunk; the W axis
rides along in VMEM, W is small).  Steps 2/4 are O(W) scalar math done
in plain jnp.  ``penalty_combine`` wires the whole pipeline into one
jittable function, which ``aot.py`` lowers to ``penalty_*.hlo.txt`` so
the Rust coordinator can execute the paper's contribution through the
same PJRT path as the model.  The EMA z-test anomaly *detection* is
stateful control logic and lives in the Rust coordinator; anomalies
arrive here as ``inf`` norms.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 65536


def _pick_chunk(n: int, requested: int) -> int:
    c = min(requested, n)
    while c > 1 and n % c != 0:
        c //= 2
    return max(c, 1)


def _sq_norm_kernel(x_ref, out_ref):
    """Partial squared norms for one parameter chunk: (W, C) -> (W,)."""
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(x * x, axis=-1)


def sq_norms(deltas, chunk: int = DEFAULT_CHUNK):
    """Per-worker squared L2 norms via a chunked Pallas reduction.

    deltas: f32[W, n] -> f32[W]
    """
    w, n = deltas.shape
    c = _pick_chunk(n, chunk)
    grid = (n // c,)
    partials = pl.pallas_call(
        _sq_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((w, c), lambda i: (0, i))],
        out_specs=pl.BlockSpec((None, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // c, w), jnp.float32),
        interpret=True,
    )(deltas)
    return jnp.sum(partials, axis=0)


def _wsum_scale_kernel(x_ref, w_ref, beta_ref, out_ref):
    """out[c] = beta * sum_i w[i] * x[i, c] for one chunk."""
    x = x_ref[...].astype(jnp.float32)
    wts = w_ref[...].astype(jnp.float32)
    beta = beta_ref[0]
    out_ref[...] = beta * (wts @ x)


def weighted_sum_scaled(deltas, weights, beta, chunk: int = DEFAULT_CHUNK):
    """beta * (weights @ deltas), tiled over the parameter axis.

    deltas: f32[W, n], weights: f32[W], beta: f32[] -> f32[n]
    """
    w, n = deltas.shape
    c = _pick_chunk(n, chunk)
    beta_arr = jnp.reshape(beta.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _wsum_scale_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((w, c), lambda i: (0, i)),
            pl.BlockSpec((w,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(deltas, weights, beta_arr)


def softmax_neg_weights(norms):
    """w = softmax(-G) with inf-norm (anomalous) workers masked to 0.

    Stabilized by subtracting the min finite norm; if every worker is
    anomalous, returns all-zeros (caller rolls back).
    """
    norms = norms.astype(jnp.float32)
    finite = jnp.isfinite(norms)
    gmin = jnp.min(jnp.where(finite, norms, jnp.inf))
    gmin = jnp.where(jnp.isfinite(gmin), gmin, 0.0)
    raw = jnp.where(finite, jnp.exp(-(norms - gmin)), 0.0)
    total = jnp.sum(raw)
    return jnp.where(total > 0, raw / jnp.maximum(total, 1e-30), 0.0)


@functools.partial(jax.jit, static_argnames=("phi", "eps", "chunk"))
def penalty_combine(deltas, norms, *, phi: float = 10.0, eps: float = 1e-8,
                    chunk: int = DEFAULT_CHUNK):
    """Full Alg. 2 combine: (deltas[W,n], norms[W]) -> (out[n], w[W], beta).

    ``norms`` are the per-worker pseudo-gradient norms after anomaly
    elimination (anomalous workers carry ``inf``).  Returns the clipped
    synchronized pseudo gradient, the averaging weights, and the clip
    coefficient beta.
    """
    weights = softmax_neg_weights(norms)
    # ||bar||^2 via the same chunked kernel (W=1 row).
    bar = weighted_sum_scaled(deltas, weights, jnp.float32(1.0), chunk=chunk)
    cnorm = jnp.sqrt(sq_norms(bar[None, :], chunk=chunk)[0])
    beta = jnp.minimum(phi / (cnorm + eps), 1.0)
    out = weighted_sum_scaled(deltas, weights, beta, chunk=chunk)
    return out, weights, beta


def penalty_for_aot(num_workers: int, n: int, phi: float = 10.0):
    """Build the (deltas, norms) -> (out, weights, beta) fn for AOT lowering."""

    def fn(deltas, norms):
        return penalty_combine(deltas, norms, phi=phi)

    specs = (
        jax.ShapeDtypeStruct((num_workers, n), jnp.float32),
        jax.ShapeDtypeStruct((num_workers,), jnp.float32),
    )
    return fn, specs
