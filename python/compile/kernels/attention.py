"""Pallas flash attention (forward + backward), TPU-idiom, interpret mode.

The paper's compute hot-spot is the transformer forward/backward; its
attention is re-thought for TPU Pallas rather than ported from CUDA
(DESIGN.md §8 Hardware-Adaptation):

  * CUDA threadblock tiling     -> BlockSpec grid over (batch*heads, q-blocks)
  * shared-memory staging       -> VMEM blocks (q/k/v tiles)
  * warp-level online softmax   -> per-block running (max, sum) carried in
                                   registers/VMEM, no HBM round-trip of QK^T
  * HBM<->SMEM double buffering -> grid-order prefetch implied by the
                                   BlockSpec index maps

Kernels run with ``interpret=True`` so the lowered HLO executes on the
CPU PJRT client (real-TPU lowering emits a Mosaic custom-call the CPU
plugin cannot run); block shapes are still chosen MXU-sized (multiples
of 128 when the sequence allows) so the same code is TPU-plausible.

The backward pass is implemented as two Pallas kernels (dq, then dk/dv)
wired through ``jax.custom_vjp`` using the standard flash-attention
recomputation trick: the forward saves only O and the per-row
log-sum-exp; the backward rebuilds P block-by-block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128

_NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() exactly 0 without NaNs


def _pick_block(seq_len: int, requested: int) -> int:
    """Largest power-of-two block <= requested that divides seq_len."""
    b = min(requested, seq_len)
    while b > 1 and seq_len % b != 0:
        b //= 2
    return max(b, 1)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, sm_scale, block_k,
                causal, block_q, seq_len):
    """One (batch*head, q-block) program of the online-softmax forward.

    Block shapes (VMEM):
      q_ref:   (block_q, d)     o_ref: (block_q, d)
      k_ref:   (seq_len, d)     lse_ref: (block_q,)
      v_ref:   (seq_len, d)
    """
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    block_d = q.shape[-1]

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, block_d), jnp.float32)

    num_kb = seq_len // block_k
    row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (block_q, block_k)
        if causal:
            col_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m + jnp.log(l_safe)).astype(lse_ref.dtype)


def _fwd(q, k, v, causal, sm_scale, block_q, block_k):
    batch, heads, seq, d = q.shape
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    bh = batch * heads
    qf = q.reshape(bh, seq, d)
    kf = k.reshape(bh, seq, d)
    vf = v.reshape(bh, seq, d)

    grid = (bh, seq // bq)
    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, block_k=bk, causal=causal,
        block_q=bq, seq_len=seq,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, seq), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(batch, heads, seq, d), lse.reshape(batch, heads, seq)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
                   sm_scale, block_k, causal, block_q, seq_len):
    """dq for one q-block: dq = sum_j dS_j @ K_j * scale."""
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)
    delta = delta_ref[...].astype(jnp.float32)
    row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    num_kb = seq_len // block_k

    def body(j, dq):
        k = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = (q @ k.T) * sm_scale
        if causal:
            col_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])  # softmax probs, rebuilt from lse
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    dq = jax.lax.fori_loop(
        0, num_kb, body, jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    )
    dq_ref[...] = (dq * sm_scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, sm_scale, block_q, causal, block_k,
                    seq_len):
    """dk/dv for one k-block: dv = P^T dO ; dk = dS^T Q * scale."""
    ki = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    col_ids = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    num_qb = seq_len // block_q
    d = k.shape[-1]

    def body(i, carry):
        dk, dv = carry
        q = q_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        do = do_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        lse = lse_ref[pl.ds(i * block_q, block_q)].astype(jnp.float32)
        delta = delta_ref[pl.ds(i * block_q, block_q)].astype(jnp.float32)
        s = (q @ k.T) * sm_scale  # (block_q, block_k)
        if causal:
            row_ids = i * block_q + jax.lax.iota(jnp.int32, block_q)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_new = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        dk_new = dk + ds.T @ q
        return dk_new, dv_new

    dk0 = jnp.zeros((block_k, d), jnp.float32)
    dv0 = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, num_qb, body, (dk0, dv0))
    dk_ref[...] = (dk * sm_scale).astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd(q, k, v, o, lse, do, causal, sm_scale, block_q, block_k):
    batch, heads, seq, d = q.shape
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    bh = batch * heads
    qf, kf, vf = (t.reshape(bh, seq, d) for t in (q, k, v))
    dof = do.reshape(bh, seq, d)
    lsef = lse.reshape(bh, seq)
    # delta_i = rowsum(dO_i * O_i) — cheap elementwise preprocess.
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    ).reshape(bh, seq)

    dq_kernel = functools.partial(
        _bwd_dq_kernel, sm_scale=sm_scale, block_k=bk, causal=causal,
        block_q=bq, seq_len=seq,
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(bh, seq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, bq), lambda b, i: (b, i)),
            pl.BlockSpec((None, bq), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,
    )(qf, kf, vf, dof, lsef, delta)

    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, sm_scale=sm_scale, block_q=bq, causal=causal,
        block_k=bk, seq_len=seq,
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(bh, seq // bk),
        in_specs=[
            pl.BlockSpec((None, seq, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, seq, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, seq), lambda b, j: (b, 0)),
            pl.BlockSpec((None, seq), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        ],
        interpret=True,
    )(qf, kf, vf, dof, lsef, delta)

    shape = (batch, heads, seq, d)
    return dq.reshape(shape), dk.reshape(shape), dv.reshape(shape)


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, sm_scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK, block_k: int = DEFAULT_BLOCK):
    """Tiled online-softmax attention with a Pallas fwd and bwd.

    Args:
      q, k, v: f32[batch, heads, seq, head_dim]; seq must be divisible by
        the (auto-shrunk) block sizes.
      causal: lower-triangular masking.
      sm_scale: defaults to 1/sqrt(head_dim).
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    out, _ = _fwd(q, k, v, causal, sm_scale, block_q, block_k)
    return out


def _flash_fwd(q, k, v, causal, sm_scale, block_q, block_k):
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    out, lse = _fwd(q, k, v, causal, sm_scale, block_q, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, sm_scale, block_q, block_k, res, do):
    q, k, v, out, lse = res
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    dq, dk, dv = _bwd(q, k, v, out, lse, do, causal, sm_scale, block_q, block_k)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def vmem_bytes_estimate(seq: int, head_dim: int, block_q: int = DEFAULT_BLOCK,
                        block_k: int = DEFAULT_BLOCK) -> int:
    """Rough per-program VMEM footprint of the forward kernel (f32 bytes).

    Used by DESIGN.md/EXPERIMENTS.md to argue real-TPU viability: the
    v5e/v4 VMEM budget is ~16 MiB/core.
    """
    bq = _pick_block(seq, block_q)
    f32 = 4
    q = bq * head_dim
    kv = 2 * seq * head_dim      # full K,V staged per program (this variant)
    acc = bq * head_dim
    stats = 2 * bq
    s = bq * _pick_block(seq, block_k)
    return f32 * (q + kv + acc + stats + s)
