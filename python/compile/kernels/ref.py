"""Pure-jnp reference oracles for the Pallas kernels.

Everything in this file is deliberately naive: materialize the full
attention matrix, use straight-line softmax, etc.  These are the
correctness ground truth that the Pallas kernels (and the Rust
re-implementations of the penalty math) are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Naive multi-head attention.

    Args:
      q, k, v: f32[batch, heads, seq, head_dim]
      causal: apply a lower-triangular mask.
      sm_scale: softmax scale; defaults to 1/sqrt(head_dim).

    Returns:
      f32[batch, heads, seq, head_dim]
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * sm_scale
    if causal:
        seq_q, seq_k = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    probs = jnp.exp(logits)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v).astype(q.dtype)


def penalty_ref(deltas, norms, phi: float, eps: float = 1e-8):
    """Reference pseudo-gradient penalty combine (Alg. 2 lines 10-12).

    Given per-worker pseudo gradients and their (possibly inf, for
    anomalous workers) norms, produce the synchronized, clipped pseudo
    gradient shared by every worker in the model sync group.

    Args:
      deltas: f32[num_workers, n] per-worker pseudo gradients.
      norms:  f32[num_workers] pseudo-gradient norms (inf == anomalous).
      phi:    clip threshold (paper uses 10).

    Returns:
      (combined f32[n], weights f32[num_workers], beta f32 scalar)
      If all workers are anomalous (sum of weights == 0) the combined
      update is all-zeros (the caller performs the parameter rollback).
    """
    norms = norms.astype(jnp.float32)
    # Stabilized softmax(-norms): exp(-(G_i - min_G)) / sum_j exp(-(G_j - min_G)).
    finite = jnp.isfinite(norms)
    gmin = jnp.min(jnp.where(finite, norms, jnp.inf))
    gmin = jnp.where(jnp.isfinite(gmin), gmin, 0.0)
    raw = jnp.where(finite, jnp.exp(-(norms - gmin)), 0.0)
    total = jnp.sum(raw)
    weights = jnp.where(total > 0, raw / jnp.maximum(total, 1e-30), 0.0)
    combined = jnp.einsum("w,wn->n", weights, deltas.astype(jnp.float32))
    cnorm = jnp.sqrt(jnp.sum(combined * combined))
    beta = jnp.minimum(phi / (cnorm + eps), 1.0)
    return combined * beta, weights, beta


def weighted_sum_ref(deltas, weights):
    """f32[w, n] x f32[w] -> f32[n]."""
    return jnp.einsum(
        "w,wn->n", weights.astype(jnp.float32), deltas.astype(jnp.float32)
    )


def sq_norms_ref(deltas):
    """Per-worker squared L2 norms: f32[w, n] -> f32[w]."""
    d = deltas.astype(jnp.float32)
    return jnp.sum(d * d, axis=-1)
