"""Layer 2: Llama-style decoder in JAX, built for AOT export to Rust.

The model is the paper's workload (Llama family, Table 3): RMSNorm,
SwiGLU MLP, rotary position embeddings, causal multi-head attention
(the Pallas flash-attention kernel from ``kernels.attention``), untied
LM head, cross-entropy loss over next-token prediction.

Export contract with the Rust coordinator (see ``aot.py``):

  * All parameters/optimizer moments travel as ONE flat f32 vector so
    the Rust side marshals exactly three big literals per step; the
    flatten order and the per-tensor/per-layer offsets are recorded in
    ``artifacts/<config>/manifest.json`` and drive the coordinator's
    layer-wise synchronization accounting.
  * Layer parameters are stacked on a leading L axis and the forward
    runs ``lax.scan`` over them, so the lowered HLO is O(1) in depth.
  * Four programs are exported per config:
      - train_step : fused fwd + bwd + grad-clip + AdamW inner update
                     (the local-SGD inner step, one PJRT call)
      - grad_step  : fwd + bwd only, returns grads (DDP/warmup path:
                     the coordinator all-reduces grads, then applies)
      - apply_step : grad-clip + AdamW given externally averaged grads
      - eval_step  : loss only
    LR and step index are runtime scalars so the Rust scheduler owns
    the schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.attention import flash_attention

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-style architecture hyperparameters (paper Table 3, scaled)."""

    name: str = "tiny"
    vocab_size: int = 512
    num_layers: int = 4
    hidden_size: int = 128
    intermediate_size: int = 352
    num_heads: int = 4
    seq_len: int = 128
    batch_size: int = 4
    # Inner AdamW hyperparameters (baked; lr/step are runtime inputs).
    beta1: float = 0.9
    beta2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    rope_theta: float = 10000.0
    # Pallas attention block sizes (auto-shrunk to divide seq_len).
    block_q: int = 128
    block_k: int = 128

    @property
    def head_dim(self) -> int:
        assert self.hidden_size % self.num_heads == 0
        return self.hidden_size // self.num_heads


# Model presets. The four paper scales (350M..7B) are represented
# analytically in the Rust simulator (rust/src/simulator); the presets
# here are the CPU-trainable scales used for the real convergence runs.
CONFIGS: Dict[str, ModelConfig] = {
    "test": ModelConfig(
        name="test", vocab_size=256, num_layers=2, hidden_size=32,
        intermediate_size=96, num_heads=2, seq_len=32, batch_size=2,
    ),
    "petite": ModelConfig(
        name="petite", vocab_size=512, num_layers=4, hidden_size=64,
        intermediate_size=176, num_heads=2, seq_len=128, batch_size=4,
    ),
    "tiny": ModelConfig(
        name="tiny", vocab_size=512, num_layers=4, hidden_size=128,
        intermediate_size=352, num_heads=4, seq_len=128, batch_size=4,
    ),
    "mini": ModelConfig(
        name="mini", vocab_size=1024, num_layers=6, hidden_size=256,
        intermediate_size=704, num_heads=8, seq_len=128, batch_size=4,
    ),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """muP-flavoured init: embeddings at sigma=0.02, hidden matrices scaled
    by 1/sqrt(fan_in), residual-output matrices further by 1/sqrt(2L) (the
    GPT-2/muP depth correction that keeps the residual stream O(1))."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 10)
    d, f, v, nl = (
        cfg.hidden_size, cfg.intermediate_size, cfg.vocab_size, cfg.num_layers
    )
    depth_scale = 1.0 / (2.0 * nl) ** 0.5

    def stack(k, shape, fan_in, residual=False):
        std = fan_in ** -0.5 * (depth_scale if residual else 1.0)
        return jax.random.normal(k, (nl,) + shape, jnp.float32) * std

    # NOTE: dict keys sorted alphabetically == jax pytree flatten order;
    # the manifest table in flatten_spec relies on that.
    return {
        "embed": jax.random.normal(ks[0], (v, d), jnp.float32) * 0.02,
        "layers": {
            "ln1": jnp.ones((nl, d), jnp.float32),
            "ln2": jnp.ones((nl, d), jnp.float32),
            "w1": stack(ks[5], (d, f), d),
            "w2": stack(ks[7], (f, d), f, residual=True),
            "w3": stack(ks[6], (d, f), d),
            "wk": stack(ks[2], (d, d), d),
            "wo": stack(ks[4], (d, d), d, residual=True),
            "wq": stack(ks[1], (d, d), d),
            "wv": stack(ks[3], (d, d), d),
        },
        "ln_f": jnp.ones((d,), jnp.float32),
        "head": jax.random.normal(ks[8], (d, v), jnp.float32) * (d ** -0.5),
    }


def flatten_spec(cfg: ModelConfig):
    """(unravel_fn, total_size, table) for the canonical flat layout.

    ``table`` is a list of (dotted-name, shape, offset, size) in flatten
    order — the manifest contract consumed by the Rust module table.
    """
    concrete = init_params(cfg, seed=0)
    leaves_with_path = jax.tree_util.tree_flatten_with_path(concrete)[0]
    table = []
    offset = 0
    for path, leaf in leaves_with_path:
        name = ".".join(p.key if hasattr(p, "key") else str(p) for p in path)
        size = 1
        for s in leaf.shape:
            size *= s
        table.append((name, tuple(leaf.shape), offset, size))
        offset += size

    flat, unravel = ravel_pytree(concrete)
    assert flat.shape[0] == offset, (flat.shape, offset)
    return unravel, offset, table


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _rms_norm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, theta: float):
    """Rotary embeddings over f32[b, h, s, hd] (hd even)."""
    half = x.shape[-1] // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(x.shape[-2], dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]  # (s, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _layer(cfg: ModelConfig, x, lp):
    """One decoder block; x: f32[b, s, d], lp: this layer's param slice."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim

    y = _rms_norm(x, lp["ln1"])
    q = (y @ lp["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (y @ lp["wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (y @ lp["wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    q, k = _rope(q, cfg.rope_theta), _rope(k, cfg.rope_theta)
    attn = flash_attention(q, k, v, True, None, cfg.block_q, cfg.block_k)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + attn @ lp["wo"]

    y = _rms_norm(x, lp["ln2"])
    gate = jax.nn.silu(y @ lp["w1"])
    x = x + (gate * (y @ lp["w3"])) @ lp["w2"]
    return x


def forward(cfg: ModelConfig, params: Params, tokens):
    """tokens i32[b, s] -> logits f32[b, s, vocab]."""
    x = params["embed"][tokens]

    def step(x, lp):
        return _layer(cfg, x, lp), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    x = _rms_norm(x, params["ln_f"])
    return x @ params["head"]


def loss_fn(cfg: ModelConfig, params: Params, tokens):
    """Next-token mean cross entropy; tokens i32[b, s+1]."""
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Inner optimizer (AdamW) over the flat vector
# ---------------------------------------------------------------------------


def _clip_by_global_norm(g, max_norm):
    norm = jnp.sqrt(jnp.sum(g * g))
    scale = jnp.minimum(max_norm / (norm + 1e-12), 1.0)
    return g * scale


def adamw_update(cfg: ModelConfig, flat_p, flat_m, flat_v, flat_g, lr, step):
    """One AdamW step over flat vectors. ``step`` is 1-based (i32)."""
    g = _clip_by_global_norm(flat_g, cfg.grad_clip)
    m = cfg.beta1 * flat_m + (1.0 - cfg.beta1) * g
    v = cfg.beta2 * flat_v + (1.0 - cfg.beta2) * g * g
    t = step.astype(jnp.float32)
    mhat = m / (1.0 - cfg.beta1 ** t)
    vhat = v / (1.0 - cfg.beta2 ** t)
    update = mhat / (jnp.sqrt(vhat) + cfg.adam_eps) + cfg.weight_decay * flat_p
    return flat_p - lr * update, m, v


# ---------------------------------------------------------------------------
# Exported programs
# ---------------------------------------------------------------------------


def build_programs(cfg: ModelConfig):
    """Return {name: (fn, example_args)} for every exported program."""
    unravel, total, _ = flatten_spec(cfg)
    b, s = cfg.batch_size, cfg.seq_len

    def _loss_flat(flat_p, tokens):
        return loss_fn(cfg, unravel(flat_p), tokens)

    def train_step(flat_p, flat_m, flat_v, tokens, lr, step):
        loss, g = jax.value_and_grad(_loss_flat)(flat_p, tokens)
        new_p, new_m, new_v = adamw_update(
            cfg, flat_p, flat_m, flat_v, g, lr, step
        )
        return new_p, new_m, new_v, loss

    def grad_step(flat_p, tokens):
        loss, g = jax.value_and_grad(_loss_flat)(flat_p, tokens)
        return g, loss

    def apply_step(flat_p, flat_m, flat_v, flat_g, lr, step):
        return adamw_update(cfg, flat_p, flat_m, flat_v, flat_g, lr, step)

    def eval_step(flat_p, tokens):
        return (_loss_flat(flat_p, tokens),)

    fp = jax.ShapeDtypeStruct((total,), jnp.float32)
    tok = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    st = jax.ShapeDtypeStruct((), jnp.int32)

    return {
        "train_step": (train_step, (fp, fp, fp, tok, lr, st)),
        "grad_step": (grad_step, (fp, tok)),
        "apply_step": (apply_step, (fp, fp, fp, fp, lr, st)),
        "eval_step": (eval_step, (fp, tok)),
    }


def init_flat(cfg: ModelConfig, seed: int = 0):
    """Initial flat parameter vector (the coordinator broadcasts this)."""
    flat, _ = ravel_pytree(init_params(cfg, seed))
    return flat
