//! Fused-kernel correctness: every fused op must match the naive
//! `kernels::reference` oracle across lengths that exercise the
//! remainder lanes (0, 1, LANES−1, LANES, LANES+1, large), and the
//! striped `ThreadComm` reductions must stay bitwise equal to the
//! sequential `group` reference.

use edit_train::collectives::{group, ThreadComm};
use edit_train::tensor::kernels::{self, reference, LANES};
use edit_train::tensor::{PayloadKind, ShardSpec, QUANT_CHUNK};
use edit_train::testing::{check, Gen};

/// Remainder-lane-exercising lengths plus a random bulk size.
fn edge_len(g: &mut Gen) -> usize {
    let fixed = [0, 1, LANES - 1, LANES, LANES + 1, 16 * LANES + 3];
    let pick = g.usize(0, fixed.len() + 1);
    if pick < fixed.len() {
        fixed[pick]
    } else {
        g.usize(1, 5000)
    }
}

#[test]
fn prop_elementwise_kernels_bitwise_match_reference() {
    check("fused-elementwise", 60, |g| {
        let n = edge_len(g);
        let x = g.vec_f32(n, 10.0);
        let a = g.vec_f32(n, 10.0);
        let alpha = g.f32(3.0);
        let beta = g.f32(2.0);

        let mut y1 = a.clone();
        let mut y2 = a.clone();
        kernels::axpy(&mut y1, alpha, &x);
        reference::axpy(&mut y2, alpha, &x);
        assert_eq!(y1, y2, "axpy n={n}");

        let mut s1 = vec![0.0f32; n];
        let mut s2 = vec![0.0f32; n];
        kernels::sub(&mut s1, &a, &x);
        reference::sub(&mut s2, &a, &x);
        assert_eq!(s1, s2, "sub n={n}");

        let mut z1 = a.clone();
        let mut z2 = a.clone();
        kernels::scale_axpy(&mut z1, alpha, beta, &x);
        let mut xs = x.clone();
        reference::scale(&mut xs, beta);
        reference::axpy(&mut z2, alpha, &xs);
        assert_eq!(z1, z2, "scale_axpy n={n}");
    });
}

#[test]
fn prop_reductions_match_reference_within_1e6_relative() {
    check("fused-reductions", 60, |g| {
        let n = edge_len(g);
        let a = g.vec_f32(n, 10.0);
        let b = g.vec_f32(n, 10.0);

        let want_sq = reference::sq_norm(&a);
        let got_sq = kernels::sq_norm(&a);
        assert!(
            (got_sq - want_sq).abs() <= 1e-6 * want_sq.max(1e-12),
            "sq_norm n={n}: {got_sq} vs {want_sq}"
        );

        // Dot can cancel; bound the tolerance by the magnitude sum.
        let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        let want = reference::dot(&a, &b);
        let got = kernels::dot(&a, &b);
        assert!(
            (got - want).abs() <= 1e-9 * mag.max(1.0),
            "dot n={n}: {got} vs {want}"
        );
    });
}

#[test]
fn prop_fused_sub_norm_matches_reference() {
    check("fused-sub-norm", 60, |g| {
        let n = edge_len(g);
        let a = g.vec_f32(n, 10.0);
        let b = g.vec_f32(n, 10.0);
        let mut out = vec![0.0f32; n];
        let sq = kernels::sub_sq_norm_into(&mut out, &a, &b);
        let mut want_out = vec![0.0f32; n];
        reference::sub(&mut want_out, &a, &b);
        assert_eq!(out, want_out, "n={n}");
        let want_sq = reference::sq_norm(&want_out);
        assert!(
            (sq - want_sq).abs() <= 1e-6 * want_sq.max(1e-12),
            "n={n}: {sq} vs {want_sq}"
        );
        // And bitwise against the fused two-pass norm (same lane fold).
        assert_eq!(sq.to_bits(), kernels::sq_norm(&out).to_bits(), "n={n}");
    });
}

#[test]
fn prop_fused_weighted_sum_matches_reference() {
    check("fused-weighted-sum", 60, |g| {
        let n = edge_len(g);
        let w_count = g.usize(1, 7);
        let rows_owned: Vec<Vec<f32>> = (0..w_count).map(|_| g.vec_f32(n, 5.0)).collect();
        let rows: Vec<&[f32]> = rows_owned.iter().map(|r| r.as_slice()).collect();
        let weights: Vec<f32> =
            (0..w_count).map(|_| if g.bool() { g.f32(1.0) } else { 0.0 }).collect();

        let mut out = vec![0.0f32; n];
        let sq = kernels::weighted_sum_sq_into(&mut out, &rows, &weights);
        let mut want = vec![0.0f32; n];
        reference::weighted_sum_into(&mut want, &rows, &weights);
        assert_eq!(out, want, "rows output must be bitwise (n={n} w={w_count})");
        let want_sq = reference::sq_norm(&want);
        assert!(
            (sq - want_sq).abs() <= 1e-6 * want_sq.max(1e-12),
            "n={n}: {sq} vs {want_sq}"
        );

        // Strided variant over a flat row-major matrix with padding.
        let pad = g.usize(0, 4);
        let stride = n + pad;
        let mut flat = vec![0.0f32; w_count * stride];
        for (j, row) in rows_owned.iter().enumerate() {
            flat[j * stride..j * stride + n].copy_from_slice(row);
        }
        let mut out_s = vec![0.0f32; n];
        let sq_s = kernels::weighted_sum_sq_strided(&mut out_s, &flat, stride, 0, &weights);
        assert_eq!(out_s, out, "strided output (n={n})");
        assert_eq!(sq_s.to_bits(), sq.to_bits(), "strided norm (n={n})");
    });
}

#[test]
fn prop_quant_dequant_fused_matches_reference_and_bounds_error() {
    check("quant-dequant-roundtrip", 60, |g| {
        let n = edge_len(g);
        let x0 = g.vec_f32(n, 5.0);
        let r0 = g.vec_f32(n, 0.05);
        for kind in [PayloadKind::F32, PayloadKind::Int8, PayloadKind::Bit1] {
            let (mut x1, mut r1) = (x0.clone(), r0.clone());
            let (mut x2, mut r2) = (x0.clone(), r0.clone());
            kernels::quant_dequant_ef(kind, &mut x1, &mut r1);
            reference::quant_dequant_ef(kind, &mut x2, &mut r2);
            assert_eq!(x1, x2, "{kind:?} dequant n={n}");
            assert_eq!(r1, r2, "{kind:?} residual n={n}");
            if kind == PayloadKind::F32 {
                // The identity payload: both buffers untouched.
                assert_eq!(x1, x0, "n={n}");
                assert_eq!(r1, r0, "n={n}");
                continue;
            }
            // v in the kernel's own op order (one f32 add per element).
            let v: Vec<f32> = x0.iter().zip(&r0).map(|(&a, &b)| a + b).collect();
            // The residual is exactly fl(v − d): nothing of v is lost
            // beyond the one subtraction — the error-feedback invariant.
            for i in 0..n {
                assert_eq!(
                    r1[i].to_bits(),
                    (v[i] - x1[i]).to_bits(),
                    "{kind:?} residual identity i={i} n={n}"
                );
            }
            if kind == PayloadKind::Int8 {
                // Round-trip error per element is at most half a
                // quantization step of its chunk (plus f32 rounding).
                for (c, vc) in v.chunks(QUANT_CHUNK).enumerate() {
                    let mx = vc.iter().fold(0.0f32, |m, &t| m.max(t.abs()));
                    let tol = (mx / 127.0) as f64 * 0.5 * 1.001 + 1e-9;
                    for (i, &vi) in vc.iter().enumerate() {
                        let d = x1[c * QUANT_CHUNK + i] as f64;
                        let err = (vi as f64 - d).abs();
                        assert!(
                            err <= tol,
                            "int8 chunk {c} elem {i} n={n}: err {err} > {tol}"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_error_feedback_sum_tracks_uncompressed_over_rounds() {
    // T quantized rounds with error feedback: the telescope
    // Σ_t d_t + r_T = Σ_t g_t is exact in real arithmetic (r_0 = 0, each
    // round folds its own quantization error into the next payload), so
    // the residual-corrected sum of what was actually sent must track
    // the uncompressed sum within f32 rounding noise — ~2 roundings per
    // element per round, far below one uncorrected quantization step.
    check("ef-tracking", 20, |g| {
        let n = edge_len(g);
        let t_rounds = g.usize(2, 10);
        for kind in [PayloadKind::Int8, PayloadKind::Bit1] {
            let mut residual = vec![0.0f32; n];
            let mut sum_true = vec![0.0f64; n]; // Σ g_t
            let mut sum_sent = vec![0.0f64; n]; // Σ d_t
            let mut vmax = 0.0f64;
            for _ in 0..t_rounds {
                let mut x = g.vec_f32(n, 1.0);
                for i in 0..n {
                    sum_true[i] += x[i] as f64;
                    vmax = vmax.max((x[i] as f64 + residual[i] as f64).abs());
                }
                kernels::quant_dequant_ef(kind, &mut x, &mut residual);
                for i in 0..n {
                    sum_sent[i] += x[i] as f64;
                    vmax = vmax.max((x[i] as f64).abs());
                }
            }
            let tol = 1e-5 * (1.0 + vmax) * t_rounds as f64;
            for i in 0..n {
                let corrected = sum_sent[i] + residual[i] as f64;
                let err = (sum_true[i] - corrected).abs();
                assert!(
                    err <= tol,
                    "{kind:?} i={i} T={t_rounds} n={n}: |{} - {corrected}| = {err} > {tol}",
                    sum_true[i]
                );
            }
        }
    });
}

#[test]
fn prop_striped_threaded_reductions_bitwise_match_sequential() {
    check("striped-threaded-bitwise", 12, |g| {
        let n = g.usize(2, 6);
        // Include lengths below the rank count (empty tail stripes).
        let len = if g.bool() { g.usize(0, n) } else { g.len() * 5 };
        let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, 1e4)).collect();
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();

        for op in 0..2 {
            let mut seq = bufs.clone();
            {
                let mut refs: Vec<&mut [f32]> =
                    seq.iter_mut().map(|b| b.as_mut_slice()).collect();
                if op == 0 {
                    group::all_reduce_mean(&mut refs);
                } else {
                    group::reduce_scatter_mean(&mut refs, &shards);
                }
            }
            let comms = ThreadComm::group(n);
            let mut threaded = vec![Vec::new(); n];
            let shards_ref = &shards;
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .zip(bufs.clone())
                    .map(|(c, mut buf)| {
                        s.spawn(move || {
                            if op == 0 {
                                c.all_reduce_mean(&mut buf);
                            } else {
                                c.reduce_scatter_mean(&mut buf, shards_ref);
                            }
                            buf
                        })
                    })
                    .collect();
                for (r, h) in handles.into_iter().enumerate() {
                    threaded[r] = h.join().unwrap();
                }
            });
            assert_eq!(seq, threaded, "op={op} n={n} len={len}");
        }
    });
}
