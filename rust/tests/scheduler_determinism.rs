//! Determinism and equivalence guarantees of the event-driven
//! per-replica execution core (`coordinator::engine::{clock,worker,sync}`),
//! exercised end-to-end on the synthetic stub engine (no artifacts
//! needed, so these run on every clean box):
//!
//!  * same seed + same config ⇒ bitwise-identical run (losses, comm,
//!    simulated time) across repeated runs;
//!  * 1 vs N worker threads ⇒ bitwise-identical runs (the scheduler's
//!    total event order, stateless straggler draws and replica-ordered
//!    folds make thread count unobservable);
//!  * A-EDiT on a perfectly homogeneous cluster coalesces every sync
//!    event and reduces exactly to EDiT;
//!  * under a consistent ~2× straggler, A-EDiT's anchor syncs beat
//!    EDiT's barriered wall-clock by ≥1.5× and workers stop sharing a
//!    post-sync clock (the ISSUE's acceptance criteria);
//!  * overlapped layer-wise sync (`overlap_sync`) on vs off ⇒ bitwise-
//!    identical runs across preset × payload × shard × thread count;
//!  * CO2's staleness queue flushes at end of run (regression for the
//!    historical silent drop);
//!  * elastic rescale drains the event state mid-schedule, survives
//!    scaling to a single replica and back, and carries CO2's in-flight
//!    staleness queue across the boundary.
#![cfg(not(feature = "pjrt"))]

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{
    MeshSpec, Method, Straggler, TrainConfig, Trainer,
};
use edit_train::data::{Corpus, Quality};
use edit_train::elastic;
use edit_train::runtime::{Engine, Manifest};

/// One shared synthetic-stub trainer recipe, built from an explicit
/// [`MethodSpec`] descriptor (the `TrainConfig::from_spec` path the
/// custom grammar uses); `trainer` delegates through the `Method`
/// preset table so the two construction paths stay comparable.
fn trainer_from_spec(
    spec: edit_train::coordinator::MethodSpec,
    label: &str,
    tweak: impl FnOnce(&mut TrainConfig),
) -> Trainer {
    let manifest = Manifest::synthetic("sched-det", 3, 128, 64, 64, 2, 8);
    let vocab = manifest.model.vocab_size;
    let engine = Engine::synthetic(manifest);
    let corpus = Corpus::new(vocab, 17, Quality::clean());
    let mut cfg = TrainConfig::from_spec(spec, label, MeshSpec::new(2, 4), 48);
    cfg.tau = 4;
    cfg.t_warm = if spec.warmup { 4 } else { 0 };
    cfg.eval_every_syncs = 0;
    tweak(&mut cfg);
    Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100())).unwrap()
}

fn trainer(method: Method, tweak: impl FnOnce(&mut TrainConfig)) -> Trainer {
    trainer_from_spec(method.spec(), method.name(), tweak)
}

/// Assert two finished trainers are bitwise-identical in every
/// determinism-relevant observable.
fn assert_bitwise_equal(a: &Trainer, b: &Trainer) {
    assert_eq!(a.tracker.losses, b.tracker.losses, "loss traces differ");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "sim time differs");
    assert_eq!(a.global_step, b.global_step);
    assert_eq!(a.syncs, b.syncs);
    assert_eq!(a.comm.ops, b.comm.ops);
    assert_eq!(a.comm.bytes, b.comm.bytes);
    assert_eq!(a.comm.seconds.to_bits(), b.comm.seconds.to_bits());
    assert_eq!(a.replicas.len(), b.replicas.len());
    for (j, (ra, rb)) in a.replicas.iter().zip(&b.replicas).enumerate() {
        assert_eq!(ra.params, rb.params, "replica {j} params");
        assert_eq!(ra.losses, rb.losses, "replica {j} losses");
        assert_eq!(ra.inner_steps, rb.inner_steps, "replica {j} steps");
        assert_eq!(ra.clock.to_bits(), rb.clock.to_bits(), "replica {j} clock");
    }
    assert_eq!(&a.anchor, &b.anchor);
}

#[test]
fn rerun_is_bitwise_identical() {
    for method in [Method::Edit, Method::AEdit, Method::Co2] {
        let mut a = trainer(method, |_| {});
        let mut b = trainer(method, |_| {});
        let sa = a.run().unwrap();
        let sb = b.run().unwrap();
        assert_bitwise_equal(&a, &b);
        assert_eq!(sa.final_loss.to_bits(), sb.final_loss.to_bits());
        assert_eq!(sa.tokens, sb.tokens);
        assert_eq!(sa.max_staleness, sb.max_staleness);
    }
}

#[test]
fn every_named_preset_runs_bitwise_reproducibly_through_the_spec_layer() {
    // The preset-equivalence suite: every named preset — the paper's
    // seven plus palsgd — runs (a) bitwise identical across reruns and
    // (b) bitwise identical whether the trainer is built through the
    // `Method` preset table (`paper_default`) or directly from its
    // `MethodSpec` descriptor (`from_spec`). Together with the
    // preset-axis matrix test in `coordinator::spec`, this pins the
    // named methods to the pre-MethodSpec seed behavior.
    for method in Method::NAMED {
        let mut via_method = trainer(method, |_| {});
        let mut rerun = trainer(method, |_| {});
        let mut via_spec = trainer_from_spec(method.spec(), method.name(), |_| {});
        let s1 = via_method.run().unwrap();
        let s2 = rerun.run().unwrap();
        let s3 = via_spec.run().unwrap();
        assert_bitwise_equal(&via_method, &rerun);
        assert_bitwise_equal(&via_method, &via_spec);
        assert_eq!(s1.final_loss.to_bits(), s2.final_loss.to_bits(), "{method:?}");
        assert_eq!(s1.final_loss.to_bits(), s3.final_loss.to_bits(), "{method:?}");
        assert_eq!(s1.label, method.name());
        assert!(s1.final_loss.is_finite(), "{method:?}");
    }
}

#[test]
fn custom_base_descriptor_is_bitwise_the_named_preset() {
    // `--method custom:base=edit` must be indistinguishable from
    // `--method edit` — the grammar is a veneer over the same spec.
    use edit_train::coordinator::MethodSpec;
    for method in [Method::Edit, Method::AEdit, Method::Co2, Method::Palsgd] {
        let descriptor = format!("custom:base={}", method.name());
        let (spec, label) = MethodSpec::parse(&descriptor).unwrap();
        assert_eq!(spec, method.spec(), "{method:?}");
        let mut named = trainer(method, |_| {});
        let mut custom = trainer_from_spec(spec, &label, |_| {});
        named.run().unwrap();
        custom.run().unwrap();
        assert_bitwise_equal(&named, &custom);
    }
}

#[test]
fn palsgd_prob_one_is_bitwise_aedit() {
    // The probabilistic trigger with p=1 fires every window, so the
    // event sets — and therefore the entire run — must be bitwise
    // A-EDiT: the new strategy is a strict generalization.
    use edit_train::coordinator::MethodSpec;
    let (p1, _) = MethodSpec::parse("custom:base=a-edit,trigger=prob:1.0").unwrap();
    let mut aedit = trainer(Method::AEdit, |_| {});
    let mut palsgd1 = trainer_from_spec(p1, "palsgd-p1", |_| {});
    aedit.run().unwrap();
    palsgd1.run().unwrap();
    assert_bitwise_equal(&aedit, &palsgd1);
}

#[test]
fn palsgd_skips_windows_and_stays_deterministic() {
    // With p = 0.5 over many short deadline windows × 4 replicas, some
    // windows must sync and some replica must skip (accruing anchor
    // staleness); reruns stay bitwise identical and the loss keeps
    // falling. τ_time ≈ 4 inner steps keeps the window count high
    // enough (~12 windows → 48 Bernoulli draws) that both events are
    // certain for any reasonable hash stream.
    let run = || {
        let mut t = trainer(Method::Palsgd, |c| {
            c.t_warm = 0;
            c.tau_time = 2.0;
        });
        let s = t.run().unwrap();
        (t, s)
    };
    let (ta, sa) = run();
    let (tb, sb) = run();
    assert_bitwise_equal(&ta, &tb);
    assert_eq!(sa.syncs, sb.syncs);
    assert!(sa.syncs > 0, "some window must draw a sync");
    assert!(
        sa.max_staleness >= 1,
        "some replica must skip a window (staleness {})",
        sa.max_staleness
    );
    assert!(sa.final_loss.is_finite());
    let first = ta.tracker.losses.first().unwrap().1;
    let last = ta.tracker.losses.last().unwrap().1;
    assert!(last < first, "loss should fall: {first} -> {last}");
}

#[test]
fn worker_thread_count_is_unobservable() {
    // Random straggler stresses the stateless lag draws; A-EDiT stresses
    // the event scheduler. Threads 1 vs 3 (uneven chunks over 4 lanes).
    for method in [Method::Edit, Method::AEdit] {
        let run = |threads: usize| {
            let mut t = trainer(method, |c| {
                c.worker_threads = threads;
                c.straggler = Straggler::Random { lag: 0.7 };
            });
            t.run().unwrap();
            t
        };
        let t1 = run(1);
        let t3 = run(3);
        assert_bitwise_equal(&t1, &t3);
        let t4 = run(4);
        assert_bitwise_equal(&t1, &t4);
    }
}

#[test]
fn aedit_homogeneous_cluster_matches_edit_exactly() {
    // No straggler: every replica accumulates the identical f64 clock,
    // all sync events coalesce into one full group per round, and the
    // anchor-sync numerics reduce to EDiT's barriered layer-wise sync.
    let mut edit = trainer(Method::Edit, |_| {});
    let mut aedit = trainer(Method::AEdit, |_| {});
    // τ_time worth exactly τ steps for every (unlagged) worker.
    aedit.cfg.tau_time = (aedit.cfg.tau as f64 - 0.5) * aedit.inner_step_seconds();
    let se = edit.run().unwrap();
    let sa = aedit.run().unwrap();
    assert_eq!(edit.tracker.losses, aedit.tracker.losses, "loss traces differ");
    assert_eq!(se.final_loss.to_bits(), sa.final_loss.to_bits());
    assert_eq!(se.sim_seconds.to_bits(), sa.sim_seconds.to_bits());
    assert_eq!(se.syncs, sa.syncs, "one coalesced sync per round");
    assert_eq!(sa.max_staleness, 0, "full coalescing ⇒ nobody is stale");
    for (re, ra) in edit.replicas.iter().zip(&aedit.replicas) {
        assert_eq!(re.params, ra.params);
        assert_eq!(re.losses, ra.losses);
    }
}

#[test]
fn aedit_beats_edit_barrier_under_consistent_straggler() {
    // The ISSUE acceptance criterion: one replica ~2× slower ⇒ A-EDiT's
    // simulated wall-clock per sample is ≥1.5× better than EDiT's, and
    // the A-EDiT workers no longer share a post-sync clock.
    let probe = trainer(Method::Edit, |c| c.t_warm = 0);
    let step_s = probe.inner_step_seconds();
    // 1.1× keeps the victim's clock incommensurate with the fast
    // group's (exact-tie coalescing must not accidentally re-barrier).
    let lag = 1.1 * step_s;
    let tweak = |c: &mut TrainConfig| {
        c.t_warm = 0;
        c.tau = 8;
        c.total_steps = 64;
        c.straggler = Straggler::Consistent { lag, replica: 0 };
    };
    let mut edit = trainer(Method::Edit, tweak);
    let mut aedit = trainer(Method::AEdit, tweak);
    aedit.cfg.tau_time = 8.0 * step_s;
    let se = edit.run().unwrap();
    let sa = aedit.run().unwrap();
    assert!(
        sa.throughput >= 1.5 * se.throughput,
        "A-EDiT {:.1} tok/sim-s vs EDiT {:.1} (ratio {:.3})",
        sa.throughput,
        se.throughput,
        sa.throughput / se.throughput
    );
    // No global barrier: the slow replica keeps its own clock.
    assert_ne!(
        aedit.replicas[0].clock.to_bits(),
        aedit.replicas[1].clock.to_bits(),
        "A-EDiT workers must not share a post-sync clock"
    );
    // The fast replicas (identical speed) still coalesce with each other.
    assert_eq!(
        aedit.replicas[1].clock.to_bits(),
        aedit.replicas[2].clock.to_bits()
    );
    // The slow replica ran fewer inner steps; the fast ones were never
    // throttled to its pace.
    assert!(aedit.replicas[0].inner_steps < aedit.replicas[1].inner_steps);
    // EDiT's barrier keeps everyone in lock-step instead.
    assert_eq!(edit.replicas[0].inner_steps, edit.replicas[1].inner_steps);
    // Anchor syncs happened per group ⇒ someone observed staleness.
    assert!(sa.max_staleness >= 1, "max_staleness {}", sa.max_staleness);
    assert_eq!(se.max_staleness, 0);
}

#[test]
fn shard_outer_on_off_bitwise_identical() {
    // The sharded-sync acceptance criterion: the ZeRO-1 path (outer
    // state reduce-scattered / all-gathered across range-aligned
    // shards) must reproduce the full-matrix reference BITWISE — on the
    // EDiT barrier path and on the A-EDiT anchor path, including when a
    // random straggler fragments the A-EDiT event groups into partial
    // member sets (and PALSGD's probabilistic draws thin them further).
    for method in [Method::Edit, Method::AEdit, Method::Palsgd] {
        for straggler in [Straggler::None, Straggler::Random { lag: 0.7 }] {
            let run = |shard: bool| {
                let mut t = trainer(method, |c| {
                    c.shard_outer = shard;
                    c.straggler = straggler;
                });
                t.run().unwrap();
                t
            };
            let on = run(true);
            let off = run(false);
            assert_bitwise_equal(&on, &off);
            assert!(on.scratch().sharded(), "{method:?}: sharding must engage");
            assert!(!off.scratch().sharded());
        }
    }
}

#[test]
fn overlap_sync_on_off_bitwise_identical() {
    // The nonblocking-sync acceptance criterion: the overlapped
    // layer-wise schedule (double-buffered `ModuleLane`s on the
    // full-matrix path, per-module combine interleaved into the scalar
    // sweep on the sharded path) must reproduce the blocking sweep
    // BITWISE on every preset × payload × shard × worker-thread
    // combination — it is a reordering of the same kernel calls, not a
    // different computation. A random straggler fragments the A-EDiT /
    // PALSGD event groups so partial member sets are covered too.
    use edit_train::coordinator::MethodSpec;
    for method in [Method::Edit, Method::AEdit, Method::Palsgd] {
        for payload in ["", ",payload=int8"] {
            for shard in [false, true] {
                for threads in [1usize, 3] {
                    let descriptor = format!("custom:base={}{payload}", method.name());
                    let (spec, label) = MethodSpec::parse(&descriptor).unwrap();
                    let run = |overlap: bool| {
                        let mut t = trainer_from_spec(spec, &label, |c| {
                            c.overlap_sync = overlap;
                            c.shard_outer = shard;
                            c.worker_threads = threads;
                            c.straggler = Straggler::Random { lag: 0.7 };
                        });
                        t.run().unwrap();
                        t
                    };
                    let on = run(true);
                    let off = run(false);
                    assert_bitwise_equal(&on, &off);
                }
            }
        }
    }
}

#[test]
fn shard_outer_threaded_fanout_is_unobservable() {
    // The sharded load/combine phases fan out across worker_threads
    // over the shard lanes; results must stay bitwise identical to the
    // sequential sweep (and the unsharded reference).
    for method in [Method::Edit, Method::AEdit] {
        let run = |threads: usize, shard: bool| {
            let mut t = trainer(method, |c| {
                c.shard_outer = shard;
                c.worker_threads = threads;
                c.straggler = Straggler::Random { lag: 0.7 };
            });
            t.run().unwrap();
            t
        };
        let seq = run(1, true);
        let par = run(3, true);
        assert_bitwise_equal(&seq, &par);
        let unsharded = run(1, false);
        assert_bitwise_equal(&seq, &unsharded);
    }
}

#[test]
fn co2_flushes_staleness_queue_at_end_of_run() {
    // 2 rounds of τ=4: the round-2 combine is still in the staleness
    // queue when the run ends; `run()` must land it (the historical
    // behavior silently dropped it).
    let tweak = |c: &mut TrainConfig| {
        c.total_steps = 8;
        c.tau = 4;
    };
    let mut flushed = trainer(Method::Co2, tweak);
    let s = flushed.run().unwrap();
    assert_eq!(s.syncs, 2);
    assert_eq!(s.flushed_updates, 1, "one in-flight update must flush");
    for r in &flushed.replicas {
        assert_eq!(r.params, flushed.anchor, "replicas adopt the flushed anchor");
    }

    // Same schedule driven by run_round() (no flush): the anchor lags
    // the flushed run by exactly the in-flight update.
    let mut unflushed = trainer(Method::Co2, tweak);
    unflushed.run_round().unwrap();
    unflushed.run_round().unwrap();
    assert_eq!(unflushed.syncs, 2);
    assert_ne!(unflushed.anchor, flushed.anchor, "flush must move the anchor");

    // DiLoCo (staleness 0) has nothing to flush.
    let mut diloco = trainer(Method::DiLoCo, tweak);
    let sd = diloco.run().unwrap();
    assert_eq!(sd.flushed_updates, 0);
}

#[test]
fn elastic_rescale_drains_event_core_state() {
    // A heterogeneous A-EDiT run rescaled mid-schedule: rescale is a
    // rendezvous (clocks re-align, queue drained) and training keeps
    // working at every size.
    let probe = trainer(Method::AEdit, |c| c.t_warm = 0);
    let step_s = probe.inner_step_seconds();
    let mut t = trainer(Method::AEdit, |c| {
        c.t_warm = 0;
        c.straggler = Straggler::Consistent { lag: 1.1 * step_s, replica: 0 };
    });
    t.cfg.tau_time = 4.0 * step_s;
    let phases = [
        elastic::Phase { replicas: 2, steps: 12 },
        elastic::Phase { replicas: 4, steps: 12 },
        elastic::Phase { replicas: 3, steps: 12 },
    ];
    let points = elastic::run_schedule(&mut t, &phases).unwrap();
    assert_eq!(points.len(), 3);
    assert_eq!(t.replicas.len(), 3);
    assert!(points.iter().all(|p| p.val_ppl.is_finite()));
    // Post-rescale rounds still learn and clocks stay monotone.
    assert!(t.sim_time > 0.0);
    for r in &t.replicas {
        assert!(r.clock <= t.sim_time + 1e-9);
    }
}

#[test]
fn elastic_rescale_to_one_and_back_is_deterministic_and_restores_total_steps() {
    // Degenerate elastic edges: scale down to a single replica (the
    // sharded outer path must fall back to full-matrix — there is
    // nothing to shard across) and back up to the full mesh (sharding
    // re-engages). The whole schedule is deterministic, and
    // `run_schedule` must hand back `total_steps` unchanged (it loans
    // the field to bound each phase; clobbering it was a real bug).
    let run = || {
        let mut t = trainer(Method::Edit, |c| {
            c.t_warm = 0;
            c.shard_outer = true;
        });
        let before = t.cfg.total_steps;
        let phases = [
            elastic::Phase { replicas: 1, steps: 12 },
            elastic::Phase { replicas: 4, steps: 12 },
        ];
        let points = elastic::run_schedule(&mut t, &phases).unwrap();
        assert_eq!(t.cfg.total_steps, before, "run_schedule must restore total_steps");
        assert_eq!(points.len(), 2);
        assert!(points.iter().all(|p| p.val_ppl.is_finite()));
        t
    };
    let ta = run();
    let tb = run();
    assert_bitwise_equal(&ta, &tb);
    assert_eq!(ta.replicas.len(), 4);
    assert!(ta.scratch().sharded(), "sharding re-engages after scaling back up");
}

#[test]
fn elastic_rescale_preserves_inflight_co2_queue() {
    // CO2's staleness-queue entries are full-parameter combines —
    // replica-count agnostic — so a rescale at a round boundary must
    // carry the in-flight update across and land it later, not drop it.
    let mut t = trainer(Method::Co2, |c| c.total_steps = 24);
    t.run_round().unwrap();
    t.run_round().unwrap();
    assert_eq!(t.pending_updates(), 1, "one combine must be in flight");
    t.rescale(3).unwrap();
    assert_eq!(t.pending_updates(), 1, "rescale must not drop the queue");
    let s = t.run().unwrap();
    assert_eq!(t.replicas.len(), 3);
    assert!(s.flushed_updates >= 1, "the queued update must land");
    assert!(s.final_loss.is_finite());
    for r in &t.replicas {
        assert_eq!(r.params, t.anchor, "end of run: replicas share the flushed anchor");
    }
}

#[test]
fn aedit_random_straggler_keeps_learning_and_desyncs_clocks() {
    // Random lag fragments the event groups round by round; the run
    // must stay finite, learn, and record per-worker staleness.
    let mut t = trainer(Method::AEdit, |c| {
        c.t_warm = 0;
        c.total_steps = 40;
        c.straggler = Straggler::Random { lag: 0.8 };
    });
    let s = t.run().unwrap();
    assert!(s.final_loss.is_finite());
    let first = t.tracker.losses.first().unwrap().1;
    let last = t.tracker.losses.last().unwrap().1;
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(s.syncs > 0);
    assert!(s.max_staleness >= 1, "fragmented groups imply staleness");
}
