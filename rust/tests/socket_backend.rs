//! Cross-backend equivalence suite: `SocketComm` (loopback TCP through
//! a rendezvous hub) against `ThreadComm` (in-process condvar gate).
//!
//! The claim under test is the fold-order contract of
//! `docs/WIRE_PROTOCOL.md` §5: at matched rank count and matched live
//! membership, every collective produces **bitwise identical** f32
//! results on both backends — including uneven shard remainders, the
//! 1-rank degenerate group, the int8 payload lane, and the crash path
//! (a worker severing TCP mid-run must be evicted exactly like a rank
//! marked failed in-process).

use std::time::Duration;

use edit_train::collectives::driver::{
    run_local_group, run_worker, run_worker_resumed, DriverConfig, DriverPayload,
    WorkerCheckpoint,
};
use edit_train::collectives::{
    Collective, ConnectOpts, Rendezvous, RendezvousConfig, SocketComm, ThreadComm,
};
use edit_train::fault::FaultPlan;
use edit_train::tensor::{ShardSpec, QUANT_CHUNK};

const T: Duration = Duration::from_secs(10);

/// Magnitude-staggered values: f32 addition order is observable, so any
/// fold-order deviation between backends changes bits.
fn staggered(rank: usize, len: usize, salt: f32) -> Vec<f32> {
    (0..len)
        .map(|i| [1e7f32, 3.0, -1e7, 0.011][rank % 4] * salt + (i as f32) * 0.125 - salt)
        .collect()
}

fn shard_table(len: usize, world: usize) -> Vec<(usize, usize)> {
    let spec = ShardSpec::new(len, world);
    (0..world).map(|r| spec.range(r)).collect()
}

/// Run one closure per rank over a loopback socket group, returning the
/// per-rank results indexed by the **assigned** rank (arrival order).
fn run_socket_group<T2, F>(world: usize, f: F) -> Vec<T2>
where
    T2: Send,
    F: Fn(&mut SocketComm) -> T2 + Sync,
{
    let hub = Rendezvous::bind(
        "127.0.0.1:0",
        RendezvousConfig { world, ..Default::default() },
    )
    .expect("bind rendezvous");
    let addr = hub.addr().to_string();
    let mut out: Vec<Option<T2>> = (0..world).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                let f = &f;
                s.spawn(move || {
                    let mut comm =
                        SocketComm::connect(&addr, ConnectOpts::default()).expect("join hub");
                    let rank = comm.rank();
                    let v = f(&mut comm);
                    comm.close();
                    (rank, v)
                })
            })
            .collect();
        for h in handles {
            let (rank, v) = h.join().expect("socket worker panicked");
            out[rank] = Some(v);
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Run one closure per rank over an in-process `ThreadComm` group.
fn run_thread_group<T2, F>(world: usize, f: F) -> Vec<T2>
where
    T2: Send,
    F: Fn(&ThreadComm) -> T2 + Sync,
{
    let comms = ThreadComm::group(world);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| {
                let f = &f;
                s.spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("thread worker panicked")).collect()
    })
}

/// The full op sequence at one (world, len): every collective the trait
/// offers, with rank-dependent staggered inputs. Returns the buffer
/// after each op, in order — the value the backends must agree on.
fn exercise<C: Collective + ?Sized>(c: &C, len: usize) -> Vec<Vec<f32>> {
    let world = c.size();
    let rank = c.rank();
    let shards = shard_table(len, world);
    let weights: Vec<f32> =
        (0..world).map(|r| if r == 1 { 0.0 } else { 0.3 + r as f32 * 0.21 }).collect();
    let mut outs = Vec::new();

    c.try_barrier(T).unwrap();

    let mut buf = staggered(rank, len, 1.0);
    c.try_all_reduce_mean(&mut buf, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 2.0);
    c.try_reduce_scatter_mean(&mut buf, &shards, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 3.0);
    c.try_reduce_scatter_sum(&mut buf, &shards, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 4.0);
    c.try_reduce_scatter_weighted(&mut buf, &shards, &weights, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 5.0);
    c.try_reduce_scatter_mean_q8(&mut buf, &shards, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 6.0);
    c.try_all_gather(&mut buf, &shards, T).unwrap();
    outs.push(buf);

    let mut buf = staggered(rank, len, 7.0);
    let root = world.min(2) - 1;
    c.try_broadcast(&mut buf, root, T).unwrap();
    outs.push(buf);

    outs
}

#[test]
fn all_ops_bitwise_identical_across_backends() {
    // Lengths chosen for uneven shard remainders (len % world != 0),
    // empty tail shards (len < world), and a quant-chunk remainder.
    for world in [1usize, 2, 3] {
        for len in [1usize, 5, QUANT_CHUNK + 7, 130] {
            let thread = run_thread_group(world, |c| exercise(c, len));
            let socket = run_socket_group(world, |c: &mut SocketComm| exercise(&*c, len));
            for rank in 0..world {
                for (i, (a, b)) in thread[rank].iter().zip(&socket[rank]).enumerate() {
                    let bits_a: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                    let bits_b: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(
                        bits_a, bits_b,
                        "world={world} len={len} rank={rank} op#{i} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn driver_digest_matches_across_backends() {
    // The acceptance property: a 2-process EDiT run over loopback
    // sockets ends at the exact anchor of the in-process reference —
    // for both wire payload lanes. params=257 gives uneven shards and
    // a quant-chunk remainder.
    for payload in [DriverPayload::F32, DriverPayload::Int8] {
        let cfg = DriverConfig { params: 257, rounds: 3, payload, ..Default::default() };
        let local = run_local_group(2, &cfg).unwrap();
        let socket = run_socket_group(2, |c: &mut SocketComm| run_worker(&*c, &cfg).unwrap());
        assert_eq!(socket[0].anchor, socket[1].anchor, "{payload:?}: ranks disagree");
        assert_eq!(socket[0].digest, local[0].digest, "{payload:?}: backend digests differ");
        assert_eq!(socket[0].anchor, local[0].anchor, "{payload:?}: backend anchors differ");
    }
}

#[test]
fn killed_worker_is_evicted_and_fault_path_is_backend_invariant() {
    // Rank 2 completes one round, then dies — abruptly (severed TCP, no
    // Goodbye) on the socket backend, via mark_failed in-process. The
    // survivors must detect the death at the next all-gather, evict, and
    // finish over the live pair with identical anchors on BOTH backends.
    let full = DriverConfig { params: 101, rounds: 3, ..Default::default() };
    let one = DriverConfig { rounds: 1, ..full.clone() };

    let comms = ThreadComm::group(3);
    let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
    let (f, o) = (&full, &one);
    let (t0, t1) = std::thread::scope(|s| {
        let h0 = s.spawn(move || run_worker(c0, f).unwrap());
        let h1 = s.spawn(move || run_worker(c1, f).unwrap());
        let h2 = s.spawn(move || {
            run_worker(c2, o).unwrap();
            c2.mark_failed(2);
        });
        h2.join().unwrap();
        (h0.join().unwrap(), h1.join().unwrap())
    });
    assert_eq!(t0.evictions, vec![2]);
    assert_eq!(t1.evictions, vec![2]);
    assert_eq!(t0.anchor, t1.anchor);

    let outs = run_socket_group(3, |c: &mut SocketComm| {
        if c.rank() == 2 {
            let out = run_worker(&*c, o).unwrap();
            c.kill();
            out
        } else {
            run_worker(&*c, f).unwrap()
        }
    });
    assert_eq!(outs[0].evictions, vec![2]);
    assert_eq!(outs[1].evictions, vec![2]);
    assert_eq!(outs[0].anchor, outs[1].anchor);
    assert_eq!(
        outs[0].digest, t0.digest,
        "crash-eviction numerics must not depend on the transport"
    );
}

#[test]
fn int8_payload_keeps_wire_ratio_on_real_frames() {
    // The compression gate, measured on actual Contribute frames (op
    // payload + header + shard table — not a theoretical count): the
    // f32 lane must cost >= 3.5x the int8 lane's tx bytes.
    let n = 4096usize;
    let ratios = run_socket_group(2, |c: &mut SocketComm| {
        let shards = shard_table(n, c.size());
        let mut buf = staggered(c.rank(), n, 1.0);
        let s0 = c.wire_stats();
        c.try_reduce_scatter_mean(&mut buf, &shards, T).unwrap();
        let s1 = c.wire_stats();
        c.try_reduce_scatter_mean_q8(&mut buf, &shards, T).unwrap();
        let s2 = c.wire_stats();
        ((s1.tx_bytes - s0.tx_bytes) as f64, (s2.tx_bytes - s1.tx_bytes) as f64)
    });
    for (rank, &(f32_tx, q8_tx)) in ratios.iter().enumerate() {
        let ratio = f32_tx / q8_tx;
        assert!(
            ratio >= 3.5,
            "rank {rank}: f32 {f32_tx} B vs int8 {q8_tx} B = {ratio:.2}x < 3.5x"
        );
    }
}

#[test]
fn netdrop_reconnect_digest_matches_clean_reference() {
    // The tentpole acceptance property: a seeded wire-chaos plan (rank 1
    // loses its link at round 1, rank 0 stalls 30ms at round 2) must
    // leave the final anchor bitwise identical to the uninterrupted
    // in-process reference — the drop is absorbed by reconnect + seq
    // replay, never by changing the numerics.
    let clean = DriverConfig { params: 257, rounds: 4, ..Default::default() };
    let reference = run_local_group(2, &clean).unwrap();
    let plan = FaultPlan::parse("netdrop@1:1,netdelay@2:0:30", clean.seed, 2).unwrap();
    let chaotic = DriverConfig { net_plan: plan, ..clean.clone() };
    let outs = run_socket_group(2, |c: &mut SocketComm| {
        let out = run_worker(&*c, &chaotic).unwrap();
        (out, c.wire_stats().reconnects)
    });
    assert_eq!(outs[0].0.anchor, outs[1].0.anchor, "ranks disagree after chaos");
    assert_eq!(
        outs[0].0.digest, reference[0].digest,
        "chaos must not change the digest"
    );
    assert!(outs[1].1 >= 1, "rank 1 never exercised the reconnect path");
}

#[test]
fn late_joiner_participates_from_next_round() {
    // Two founders start a world=2 run; a third worker dials in mid-run.
    // The hub parks it in the lobby, admits it at the next fresh round
    // barrier, and the driver's join-sync broadcast hands it the round
    // counter + anchor. Delay events at rounds 2 and 3 stretch the run
    // so the joiner reliably lands mid-run.
    let cfg = DriverConfig { params: 64, rounds: 8, ..Default::default() };
    let plan = FaultPlan::parse(
        "netdelay@2:0:150,netdelay@2:1:150,netdelay@3:0:150,netdelay@3:1:150",
        cfg.seed,
        2,
    )
    .unwrap();
    let founders = DriverConfig { net_plan: plan, ..cfg.clone() };

    let hub = Rendezvous::bind(
        "127.0.0.1:0",
        RendezvousConfig { world: 2, ..Default::default() },
    )
    .unwrap();
    let addr = hub.addr().to_string();
    let (outs, joiner) = std::thread::scope(|s| {
        let fh: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                let cfg = &founders;
                s.spawn(move || {
                    let comm = SocketComm::connect(&addr, ConnectOpts::default()).unwrap();
                    let out = run_worker(&comm, cfg).unwrap();
                    comm.close();
                    out
                })
            })
            .collect();
        let jh = {
            let addr = addr.clone();
            let cfg = &cfg;
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(200));
                let comm = SocketComm::connect(&addr, ConnectOpts::default()).unwrap();
                assert!(comm.late_joiner(), "expected admission as a late joiner");
                let out = run_worker(&comm, cfg).unwrap();
                comm.close();
                out
            })
        };
        let outs: Vec<_> = fh.into_iter().map(|h| h.join().unwrap()).collect();
        (outs, jh.join().unwrap())
    });
    assert_eq!(outs[0].anchor, outs[1].anchor, "founders disagree");
    assert_eq!(joiner.anchor, outs[0].anchor, "joiner must end on the group's anchor");
    assert!(
        joiner.rounds_done >= 1 && joiner.rounds_done < cfg.rounds,
        "joiner should run a strict mid-run suffix, ran {} of {} rounds",
        joiner.rounds_done,
        cfg.rounds,
    );
}

#[test]
fn kill_and_restore_replays_bitwise_over_sockets() {
    // Round-boundary checkpoint at round 3, then a brand-new hub and
    // restored workers finishing rounds 3..5: the final digest must be
    // bitwise identical to an uninterrupted 5-round reference.
    let dir = std::env::temp_dir().join(format!("edit-sock-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let clean = DriverConfig { params: 257, rounds: 5, ..Default::default() };
    let reference = run_local_group(2, &clean).unwrap();

    let phase1 = DriverConfig {
        rounds: 3,
        checkpoint_every: 3,
        checkpoint_dir: Some(dir.clone()),
        ..clean.clone()
    };
    run_socket_group(2, |c: &mut SocketComm| run_worker(&*c, &phase1).unwrap());

    let outs = run_socket_group(2, |c: &mut SocketComm| {
        let path = dir.join(format!("ckpt-rank{}-round3.bin", c.rank()));
        let ck = WorkerCheckpoint::load(&path).unwrap();
        ck.validate(&clean, c.rank(), c.size()).unwrap();
        run_worker_resumed(&*c, &clean, Some(&ck)).unwrap()
    });
    assert_eq!(outs[0].anchor, outs[1].anchor, "restored ranks disagree");
    assert_eq!(
        outs[0].digest, reference[0].digest,
        "restored run must replay bitwise"
    );
    assert_eq!(outs[0].rounds_done, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_timeout_after_hub_death_is_clean() {
    // A worker whose hub disappears mid-op must fail with a CommError,
    // not hang or panic.
    let hub = Rendezvous::bind(
        "127.0.0.1:0",
        RendezvousConfig { world: 2, ..Default::default() },
    )
    .unwrap();
    let addr = hub.addr().to_string();
    std::thread::scope(|s| {
        let h: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                s.spawn(move || {
                    let comm = SocketComm::connect(&addr, ConnectOpts::default()).unwrap();
                    comm.try_barrier(T).unwrap();
                    comm
                })
            })
            .collect();
        let comms: Vec<SocketComm> = h.into_iter().map(|h| h.join().unwrap()).collect();
        hub.shutdown();
        for comm in &comms {
            let mut buf = vec![1.0f32; 8];
            assert!(
                comm.try_all_reduce_mean(&mut buf, Duration::from_secs(5)).is_err(),
                "op against a dead hub must fail"
            );
        }
    });
}
