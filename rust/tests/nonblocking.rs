//! Nonblocking collective semantics (`start_*` / `CommHandle` /
//! `wait_handle`) on both backends:
//!
//!  * issue/wait round-trips produce the exact blocking results, in
//!    issue order, past the `PIPELINE_WINDOW` backpressure bound;
//!  * a `CommHandle` dropped without `wait()` must not deadlock the
//!    comm worker, leak an in-flight slot, or poison the next round —
//!    on `ThreadComm` the worker's reply send just fails; on
//!    `SocketComm` the abandoned op stays in the pipeline until its
//!    result frame arrives and is garbage-collected after resolution
//!    (`docs/WIRE_PROTOCOL.md` §4.2);
//!  * the overlapped multi-module driver schedule ends at the bitwise
//!    digest of the blocking schedule on BOTH transports, for both wire
//!    payload lanes.

use std::time::Duration;

use edit_train::collectives::driver::{run_local_group, run_worker, DriverConfig, DriverPayload};
use edit_train::collectives::{
    Collective, ConnectOpts, Rendezvous, RendezvousConfig, SocketComm, ThreadComm,
    PIPELINE_WINDOW,
};

const T: Duration = Duration::from_secs(10);

/// Run one closure per rank over a loopback socket group, returning the
/// per-rank results indexed by the assigned rank.
fn run_socket_group<T2, F>(world: usize, f: F) -> Vec<T2>
where
    T2: Send,
    F: Fn(&mut SocketComm) -> T2 + Sync,
{
    let hub = Rendezvous::bind(
        "127.0.0.1:0",
        RendezvousConfig { world, ..Default::default() },
    )
    .expect("bind rendezvous");
    let addr = hub.addr().to_string();
    let mut out: Vec<Option<T2>> = (0..world).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                let f = &f;
                s.spawn(move || {
                    let mut comm =
                        SocketComm::connect(&addr, ConnectOpts::default()).expect("join hub");
                    let rank = comm.rank();
                    let v = f(&mut comm);
                    comm.close();
                    (rank, v)
                })
            })
            .collect();
        for h in handles {
            let (rank, v) = h.join().expect("socket worker panicked");
            out[rank] = Some(v);
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Run one closure per rank over an in-process `ThreadComm` group.
fn run_thread_group<T2, F>(world: usize, f: F) -> Vec<T2>
where
    T2: Send,
    F: Fn(&ThreadComm) -> T2 + Sync,
{
    let comms = ThreadComm::group(world);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| {
                let f = &f;
                s.spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("thread worker panicked")).collect()
    })
}

/// Issue `ops` all-reduces through the nonblocking window (twice the
/// backpressure bound), then wait them in issue order; every result
/// must equal the blocking mean for its salt. Exercises queue-full
/// backpressure on both backends.
fn window_sweep<C: Collective + ?Sized>(c: &C, len: usize, ops: usize) -> Vec<Vec<f32>> {
    let world = c.size();
    c.try_barrier(T).unwrap();
    let handles: Vec<_> = (0..ops)
        .map(|i| {
            let buf = vec![c.rank() as f32 + i as f32; len];
            c.start_all_reduce_mean(buf, T)
        })
        .collect();
    let expected_base = (0..world).map(|r| r as f32).sum::<f32>() / world as f32;
    handles
        .into_iter()
        .enumerate()
        .map(|(i, h)| {
            let out = c.wait_handle(h).unwrap();
            assert_eq!(out.len(), len, "op {i}: length");
            for &x in &out {
                assert_eq!(
                    x.to_bits(),
                    (expected_base + i as f32).to_bits(),
                    "op {i}: wrong mean"
                );
            }
            out
        })
        .collect()
}

#[test]
fn window_backpressure_completes_in_issue_order_on_both_backends() {
    let ops = 2 * PIPELINE_WINDOW + 1;
    for world in [1usize, 2, 3] {
        let thread = run_thread_group(world, |c| window_sweep(c, 37, ops));
        let socket = run_socket_group(world, |c: &mut SocketComm| window_sweep(&*c, 37, ops));
        for rank in 0..world {
            assert_eq!(thread[rank], socket[rank], "world={world} rank={rank}");
        }
    }
}

/// Issue, drop without waiting, then keep using the group: the dropped
/// op still ran collectively (every rank issued it), the next blocking
/// op must flush it through and return correct bits, and a full driver
/// round afterwards must complete with clean membership.
fn drop_and_continue<C: Collective + ?Sized>(c: &C, cfg: &DriverConfig) -> (Vec<f32>, u64) {
    let world = c.size();
    c.try_barrier(T).unwrap();
    // Drop one mid-flight handle...
    drop(c.start_all_reduce_mean(vec![c.rank() as f32; 29], T));
    // ...and one of a pair, waiting only the second.
    let _first = c.start_all_reduce_mean(vec![c.rank() as f32 * 2.0; 29], T);
    let second = c.start_all_reduce_mean(vec![c.rank() as f32 + 10.0; 29], T);
    let out = c.wait_handle(second).unwrap();
    drop(_first);
    let expected = (0..world).map(|r| r as f32 + 10.0).sum::<f32>() / world as f32;
    for &x in &out {
        assert_eq!(x.to_bits(), expected.to_bits(), "post-drop op corrupted");
    }
    // A blocking op right after the drops: both backends flush the
    // pipeline first, so this is the slot-leak / deadlock probe.
    let mut buf = vec![c.rank() as f32; 17];
    c.try_all_reduce_mean(&mut buf, T).unwrap();
    // And an entire driver round on the same comm: membership stays
    // clean (no spurious evictions from the abandoned op).
    let outcome = run_worker(c, cfg).unwrap();
    assert!(outcome.evictions.is_empty(), "dropped handle poisoned membership");
    (buf, outcome.digest)
}

#[test]
fn dropped_handle_neither_deadlocks_nor_leaks_a_slot() {
    let cfg = DriverConfig { params: 193, rounds: 2, ..Default::default() };
    for world in [2usize, 3] {
        let thread = run_thread_group(world, |c| drop_and_continue(c, &cfg));
        let socket =
            run_socket_group(world, |c: &mut SocketComm| drop_and_continue(&*c, &cfg));
        for rank in 0..world {
            assert_eq!(thread[rank].0, socket[rank].0, "world={world} rank={rank}");
            assert_eq!(thread[rank].1, socket[rank].1, "world={world} rank={rank} digest");
        }
        // Sanity: the post-drop driver run matches a fresh group's.
        let fresh = run_local_group(world, &cfg).unwrap();
        assert_eq!(thread[0].1, fresh[0].digest, "world={world}");
    }
}

#[test]
fn evicted_peer_pending_pipelined_ops_drain_deterministically() {
    // Heartbeat/dead-peer detection × pipelining: rank 2 contributes to
    // the first 3 pipelined ops, then dies abruptly with 3 more already
    // issued by the survivors. The hub must (a) finish the fully
    // contributed ops over all three ranks, (b) hold the tail open
    // through the reconnect grace window (answering its op-timeout
    // nudges, which the clients meet by re-sending the same seq), and
    // (c) on eviction drain the victim's pending ops front-first over
    // the survivors — so both survivors see means over 3 ranks for the
    // first batch and means over 2 for the tail, bitwise.
    const OPS: usize = 6;
    const K: usize = 3; // ops rank 2 contributes to before dying
    let outs = run_socket_group(3, |c: &mut SocketComm| {
        let rank = c.rank();
        c.try_barrier(T).unwrap();
        let issued = if rank == 2 { K } else { OPS };
        let handles: Vec<_> = (0..issued)
            .map(|i| c.start_all_reduce_mean(vec![(rank * 2 + i) as f32; 11], T))
            .collect();
        let got: Vec<f32> =
            handles.into_iter().map(|h| c.wait_handle(h).unwrap()[0]).collect();
        if rank == 2 {
            c.kill();
        }
        got
    });
    for i in 0..K {
        // Ranks contribute r*2 + i; all three folded, mean = (6+3i)/3.
        let want = (6 + 3 * i) as f32 / 3.0;
        assert_eq!(outs[0][i].to_bits(), want.to_bits(), "op {i}: full fold");
        assert_eq!(outs[2][i].to_bits(), want.to_bits(), "op {i}: on the victim");
    }
    for i in K..OPS {
        // Only ranks 0 and 1 remain: mean = (2+2i)/2.
        let want = (2 + 2 * i) as f32 / 2.0;
        assert_eq!(outs[0][i].to_bits(), want.to_bits(), "op {i}: survivor fold");
    }
    assert_eq!(outs[0], outs[1], "survivors must agree bitwise");
}

#[test]
fn overlapped_driver_schedule_matches_blocking_on_both_backends() {
    // The end-to-end tentpole property over the real wire: a 4-module
    // overlapped EDiT run (pipelined frames in flight while the next
    // module computes) ends at the exact blocking digest, per payload
    // lane. params=257 gives uneven module and rank shards plus a
    // quant-chunk remainder.
    for payload in [DriverPayload::F32, DriverPayload::Int8] {
        let blocking = DriverConfig {
            params: 257,
            rounds: 3,
            modules: 4,
            payload,
            overlap: false,
            ..Default::default()
        };
        let overlapped = DriverConfig { overlap: true, ..blocking.clone() };
        let reference = run_local_group(2, &blocking).unwrap();
        let local = run_local_group(2, &overlapped).unwrap();
        assert_eq!(local[0].digest, reference[0].digest, "{payload:?}: thread backend");
        let socket = run_socket_group(2, |c: &mut SocketComm| {
            run_worker(&*c, &overlapped).unwrap()
        });
        assert_eq!(socket[0].anchor, socket[1].anchor, "{payload:?}: ranks disagree");
        assert_eq!(
            socket[0].digest, reference[0].digest,
            "{payload:?}: socket overlapped diverged from blocking reference"
        );
    }
}
