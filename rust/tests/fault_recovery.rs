//! Fault-tolerant elastic runtime acceptance suite (ISSUE 6):
//!
//!  * kill-at-round-k + checkpoint/restore replays **bitwise identical**
//!    to an uninterrupted run, for EDiT / A-EDiT / PALSGD, sharded and
//!    unsharded, with seeded crash+rejoin schedules active — and across
//!    a DDP warmup phase;
//!  * A-EDiT survives a mid-window crash under a consistent straggler
//!    and under a rollback storm (all-replica poison), and the faulty
//!    runs stay deterministic;
//!  * EDiT's barrier falls back to timeout-then-evict when a member
//!    dies (eviction counted, survivors keep stepping);
//!  * a rejoining replica adopts the current anchor with zeroed inner
//!    moments; a `join@r:N` live-appends a brand-new replica;
//!  * checkpoints survive a rescale boundary (the restore rescales the
//!    fresh trainer to the manifest's replica count);
//!  * malformed / mismatched checkpoint files are rejected.
#![cfg(not(feature = "pjrt"))]

use std::path::PathBuf;

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{MeshSpec, Method, Poison, Straggler, TrainConfig, Trainer};
use edit_train::data::{Corpus, Quality};
use edit_train::experiments::chaos::{kill_restore_pair, state_mismatches, CHAOS_METHODS};
use edit_train::experiments::ExpOpts;
use edit_train::fault::FaultPlan;
use edit_train::runtime::{Engine, Manifest};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("edit_train_fault_recovery");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Synthetic-stub trainer with the fault surface under direct control
/// (the `scheduler_determinism` recipe + a fault plan).
fn trainer(method: Method, plan: FaultPlan, tweak: impl FnOnce(&mut TrainConfig)) -> Trainer {
    trainer_spec(method.spec(), method.name(), plan, tweak)
}

/// [`trainer`] for an arbitrary strategy descriptor (the payload-axis
/// tests go through the `custom:` grammar).
fn trainer_spec(
    spec: edit_train::coordinator::MethodSpec,
    label: &str,
    plan: FaultPlan,
    tweak: impl FnOnce(&mut TrainConfig),
) -> Trainer {
    let manifest = Manifest::synthetic("fault-rec", 3, 128, 64, 64, 2, 8);
    let vocab = manifest.model.vocab_size;
    let engine = Engine::synthetic(manifest);
    let corpus = Corpus::new(vocab, 17, Quality::clean());
    let mut cfg = TrainConfig::from_spec(spec, label, MeshSpec::new(2, 4), 48);
    cfg.tau = 4;
    cfg.t_warm = 0;
    cfg.eval_every_syncs = 2;
    cfg.fault_plan = plan;
    tweak(&mut cfg);
    let mut t = Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100())).unwrap();
    // Time-based windows worth exactly τ unlagged steps, so every
    // strategy runs ~12 rounds and the fault plans' round keys land.
    t.cfg.tau_time = (t.cfg.tau as f64 - 0.5) * t.inner_step_seconds();
    t
}

/// Kill/restore pair over any builder: A runs start to finish; B runs
/// to round `kill`, checkpoints, restores into a FRESH trainer and
/// finishes. Both must be bitwise indistinguishable.
fn kill_restore_with(build: impl Fn() -> Trainer, kill: u64, ckpt: &PathBuf) -> (Trainer, Trainer) {
    let mut ta = build();
    ta.run().unwrap();
    let mut tb = build();
    while tb.rounds() < kill && tb.global_step < tb.cfg.total_steps {
        tb.run_round().unwrap();
    }
    tb.save_checkpoint(ckpt).unwrap();
    let mut tb2 = build();
    tb2.restore_checkpoint(ckpt).unwrap();
    tb2.run().unwrap();
    (ta, tb2)
}

fn assert_bitwise(a: &Trainer, b: &Trainer, what: &str) {
    let diffs = state_mismatches(a, b);
    assert!(diffs.is_empty(), "{what}: restore diverged:\n  {}", diffs.join("\n  "));
}

#[test]
fn kill_restore_is_bitwise_identical_for_every_preset() {
    // The headline acceptance criterion, through the same harness the
    // `edit-train chaos` CI leg drives: every preset × sharding mode,
    // under a live seeded crash+rejoin schedule.
    let opts = ExpOpts { steps: 48, tau: 4, seed: 11, ..ExpOpts::default() };
    for method in CHAOS_METHODS {
        for shard in [true, false] {
            let plan = FaultPlan::random(opts.seed, opts.mesh.replicas, 12, 2);
            assert!(!plan.is_empty());
            let ckpt = tmp(&format!("preset-{}-{}.bin", method.name(), shard));
            let (ta, tb, kill) =
                kill_restore_pair(&opts, method, shard, opts.seed, &plan, &ckpt).unwrap();
            assert!(kill >= 1);
            let tag = format!("{} shard={shard}", method.name());
            assert_bitwise(&ta, &tb, &tag);
            assert!(ta.summary().crashes >= 1, "{tag}: the schedule must actually fire");
        }
    }
}

#[test]
fn kill_restore_spans_a_ddp_warmup_phase() {
    // EDiT's spec warms up with lock-step DDP; the checkpoint lands
    // after warmup but the trajectory it must replay includes it.
    let build = || {
        trainer(Method::Edit, FaultPlan::parse("crash@3:1,join@5:1", 17, 4).unwrap(), |c| {
            c.t_warm = 4;
        })
    };
    let (ta, tb) = kill_restore_with(build, 2, &tmp("warmup.bin"));
    assert_bitwise(&ta, &tb, "warmup");
    assert!(ta.cfg.t_warm > 0);
    let s = ta.summary();
    assert_eq!((s.crashes, s.rejoins), (1, 1));
}

#[test]
fn aedit_survives_midwindow_crash_under_consistent_straggler() {
    // Replica 1 dies two steps into a window while replica 0 is a
    // consistent straggler: the victim's pending contribution is
    // excluded (degraded per-group sync, not a global abort), the
    // survivors keep their own clocks, and the whole faulty trajectory
    // still kill/restores bitwise.
    let build = || {
        trainer(Method::AEdit, FaultPlan::parse("crash@2:1+2,join@5:1", 17, 4).unwrap(), |c| {
            c.straggler = Straggler::Consistent { lag: 0.6, replica: 0 };
        })
    };
    let mut ta = build();
    let s = ta.run().unwrap();
    assert_eq!((s.crashes, s.rejoins), (1, 1));
    assert!(s.degraded_syncs >= 1, "the victim's windows must sync degraded");
    assert!(s.final_loss.is_finite());
    assert!(ta.alive().iter().all(|&a| a), "the victim rejoined");
    // The victim sat out rounds 2..5 while its (equal-speed) peers kept
    // stepping.
    assert!(
        ta.replicas[1].inner_steps < ta.replicas[2].inner_steps,
        "victim {} vs survivor {}",
        ta.replicas[1].inner_steps,
        ta.replicas[2].inner_steps
    );
    let (ra, rb) = kill_restore_with(build, 3, &tmp("aedit-straggler.bin"));
    assert_bitwise(&ra, &rb, "a-edit straggler");
}

#[test]
fn aedit_survives_rollback_storm_with_midwindow_crash() {
    // The Fig. 7c all-anomalous scenario (every replica's state drifts
    // for a sync round) stacked on a crash+rejoin: the detector's
    // rollback machinery and the fault harness must compose, stay
    // finite, and replay bitwise through a kill/restore.
    let build = || {
        trainer(Method::AEdit, FaultPlan::parse("crash@4:1+1,join@7:1", 17, 4).unwrap(), |c| {
            c.spec.penalty.warmup_syncs = 3;
            c.spec.penalty.alpha = 0.3;
            c.spec.penalty.phi = 0.3;
            c.poison = vec![
                Poison { replica: 2, from_sync: 4, to_sync: 6, strength: 1e-2 },
                Poison { replica: usize::MAX, from_sync: 7, to_sync: 8, strength: 1e-2 },
            ];
        })
    };
    let mut t = build();
    let s = t.run().unwrap();
    assert_eq!((s.crashes, s.rejoins), (1, 1));
    assert!(s.final_loss.is_finite());
    let (ra, rb) = kill_restore_with(build, 5, &tmp("aedit-storm.bin"));
    assert_bitwise(&ra, &rb, "a-edit rollback storm");
    // The storm actually happened on the replayed trajectory too.
    let (sa, sb) = (ra.summary(), rb.summary());
    assert_eq!(sa.anomalies, sb.anomalies);
    assert_eq!(sa.rollbacks, sb.rollbacks);
}

#[test]
fn edit_barrier_evicts_a_crashed_member() {
    // Step-synced EDiT: a dead member can never reach the barrier, so
    // the rendezvous times out, charges the evict grace period, and the
    // round commits over the survivors.
    let mut t = trainer(Method::Edit, FaultPlan::parse("crash@2:1", 17, 4).unwrap(), |_| {});
    let s = t.run().unwrap();
    assert_eq!(s.crashes, 1);
    assert!(s.evictions >= 1, "the barrier must evict");
    assert!(s.degraded_syncs >= 1, "post-crash rounds sync degraded");
    assert_eq!(s.rejoins, 0);
    assert!(!t.alive()[1], "nobody revived the victim");
    assert!(t.alive()[0] && t.alive()[2] && t.alive()[3]);
    assert!(
        t.replicas[1].inner_steps < t.replicas[0].inner_steps,
        "survivors kept stepping past the victim"
    );
    assert!(s.final_loss.is_finite());
}

#[test]
fn rejoining_replica_adopts_the_current_anchor() {
    // join@4 revives the victim at the start of round 4; an immediate
    // crash@4 with a zero step budget freezes it right there, so the
    // adopted state is directly observable: params == the anchor as of
    // round-4 start, inner moments zeroed.
    let plan = FaultPlan::parse("crash@1:1,join@4:1,crash@4:1", 17, 4).unwrap();
    let mut t = trainer(Method::Edit, plan, |_| {});
    while t.rounds() < 4 {
        t.run_round().unwrap();
    }
    let anchor_before = t.anchor.clone();
    t.run_round().unwrap();
    assert_eq!(t.replicas[1].params, anchor_before, "joiner must adopt the anchor");
    assert!(t.replicas[1].m.iter().all(|&x| x == 0.0), "inner moments zeroed");
    assert!(t.replicas[1].v.iter().all(|&x| x == 0.0));
    assert!(!t.alive()[1], "the round-4 crash froze it again");
    let s = t.summary();
    assert_eq!((s.crashes, s.rejoins), (2, 1));
    assert!(s.max_staleness >= 1, "slept-through anchor versions fold into staleness");
}

#[test]
fn join_at_cluster_size_live_appends_a_new_replica() {
    let build = || trainer(Method::Edit, FaultPlan::parse("join@2:4", 17, 4).unwrap(), |_| {});
    let mut t = build();
    let s = t.run().unwrap();
    assert_eq!(t.replicas.len(), 5, "the cluster grew mid-run");
    assert_eq!(t.alive().len(), 5);
    assert!(t.alive().iter().all(|&a| a));
    assert_eq!(s.rejoins, 1);
    assert!(s.final_loss.is_finite());
    // The joiner started late and from the anchor, so it stepped less.
    assert!(t.replicas[4].inner_steps < t.replicas[0].inner_steps);
    // Growth is deterministic, and kill/restore works across the join
    // boundary (the checkpoint carries 5 replicas into a 4-replica
    // fresh trainer, which rescales on restore).
    let (ra, rb) = kill_restore_with(build, 4, &tmp("append.bin"));
    assert_bitwise(&ra, &rb, "live append");
    assert_eq!(rb.replicas.len(), 5);
}

#[test]
fn checkpoint_restore_crosses_a_rescale_boundary() {
    // Rescale 4 -> 2, run, checkpoint, restore into a FRESH 4-replica
    // trainer: the restore must rescale down to the manifest's count
    // and then replay bitwise against an uninterrupted rescaled run.
    let build = || trainer(Method::Edit, FaultPlan::default(), |_| {});
    let run_rounds = |t: &mut Trainer, upto: u64| {
        while t.rounds() < upto && t.global_step < t.cfg.total_steps {
            t.run_round().unwrap();
        }
    };
    let mut ta = build();
    ta.rescale(2).unwrap();
    run_rounds(&mut ta, 6);

    let mut tb = build();
    tb.rescale(2).unwrap();
    run_rounds(&mut tb, 3);
    let ckpt = tmp("rescale.bin");
    tb.save_checkpoint(&ckpt).unwrap();
    let mut tc = build();
    assert_eq!(tc.replicas.len(), 4);
    tc.restore_checkpoint(&ckpt).unwrap();
    assert_eq!(tc.replicas.len(), 2, "restore adopts the checkpoint's replica count");
    run_rounds(&mut tc, 6);
    assert_bitwise(&ta, &tc, "rescale boundary");
}

#[test]
fn checkpoint_cadence_writes_round_files() {
    let dir = tmp("cadence");
    let _ = std::fs::remove_dir_all(&dir);
    let mut t = trainer(Method::Edit, FaultPlan::default(), |c| {
        c.checkpoint_every = 2;
        c.checkpoint_dir = Some(dir.clone());
    });
    t.run().unwrap();
    assert!(t.rounds() >= 4);
    for round in (2..=t.rounds()).step_by(2) {
        let path = dir.join(format!("ckpt-round-{round:06}.bin"));
        assert!(path.is_file(), "missing {}", path.display());
    }

    // The cadence without a directory is a configuration error.
    let mut bad = trainer(Method::Edit, FaultPlan::default(), |c| {
        c.checkpoint_every = 2;
        c.checkpoint_dir = None;
    });
    assert!(bad.run().is_err());
}

#[test]
fn kill_restore_carries_error_feedback_residuals() {
    // `payload=int8`: the error-feedback residual buffers are live
    // state — a restore that zeroed them would diverge from the
    // uninterrupted run at the very next sync, because every subsequent
    // quantization would miss the accumulated correction. Kill at
    // round 3 with residuals in flight (asserted nonzero), restore into
    // a fresh trainer, finish: bitwise, on both sync layouts, with a
    // crash+rejoin schedule active.
    let (spec, _) = edit_train::coordinator::MethodSpec::parse("custom:base=edit,payload=int8")
        .unwrap();
    for shard in [true, false] {
        let build = || {
            trainer_spec(
                spec,
                "edit-int8",
                FaultPlan::parse("crash@3:1,join@5:1", 17, 4).unwrap(),
                |c| c.shard_outer = shard,
            )
        };
        // The kill point genuinely has residuals in flight.
        let mut probe = build();
        while probe.rounds() < 3 {
            probe.run_round().unwrap();
        }
        let mut in_flight = Vec::new();
        probe.scratch().export_residuals_into(&mut in_flight);
        assert!(
            in_flight.iter().any(|&r| r != 0.0),
            "shard={shard}: no residual in flight at the kill point — the test is vacuous"
        );

        let ckpt = tmp(&format!("int8-residuals-{shard}.bin"));
        let (ta, tb) = kill_restore_with(build, 3, &ckpt);
        assert_bitwise(&ta, &tb, &format!("int8 payload shard={shard}"));
        assert!(ta.summary().crashes >= 1, "the schedule must actually fire");
        // And the residual buffers themselves landed bitwise equal.
        let (mut res_a, mut res_b) = (Vec::new(), Vec::new());
        ta.scratch().export_residuals_into(&mut res_a);
        tb.scratch().export_residuals_into(&mut res_b);
        assert!(!res_a.is_empty());
        assert_eq!(res_a, res_b, "shard={shard}: residuals diverged");
    }

    // Strategy mismatch: an int8 checkpoint carries residuals a
    // payload=f32 run has no slot for — rejected, not silently dropped.
    let ckpt = tmp("int8-into-f32.bin");
    let mut a = trainer_spec(
        spec,
        "edit-int8",
        FaultPlan::default(),
        |_| {},
    );
    while a.rounds() < 2 {
        a.run_round().unwrap();
    }
    a.save_checkpoint(&ckpt).unwrap();
    let mut b = trainer(Method::Edit, FaultPlan::default(), |_| {});
    let err = b.restore_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("sync_residuals"), "unexpected error: {err}");
}

#[test]
fn malformed_and_mismatched_checkpoints_are_rejected() {
    // Garbage bytes: bad magic.
    let garbage = tmp("garbage.bin");
    std::fs::write(&garbage, b"not a checkpoint").unwrap();
    let mut t = trainer(Method::Edit, FaultPlan::default(), |_| {});
    let err = t.restore_checkpoint(&garbage).unwrap_err().to_string();
    assert!(err.contains("magic"), "unexpected error: {err}");

    // A checkpoint from a different seed must not restore (the replay
    // guarantee is per-(seed, config) trajectory).
    let ckpt = tmp("seed-a.bin");
    let mut a = trainer(Method::Edit, FaultPlan::default(), |_| {});
    while a.rounds() < 2 {
        a.run_round().unwrap();
    }
    a.save_checkpoint(&ckpt).unwrap();
    let mut b = trainer(Method::Edit, FaultPlan::default(), |c| c.seed += 1);
    let err = b.restore_checkpoint(&ckpt).unwrap_err().to_string();
    assert!(err.contains("seed"), "unexpected error: {err}");
}
