//! Property coverage for the reduce-scatter / all-gather collectives
//! behind the sharded outer sync path: the threaded rendezvous
//! implementations must be **bitwise** equal to the sequential `group`
//! references (rank-0..n fold-order contract, `collectives::mod` docs)
//! across uneven shard remainders and the 1-rank degenerate case, and
//! the weighted reduce-scatter must reproduce the fused combine kernel
//! the scratch arena's shard lanes run (`kernels::weighted_sum_sq_strided`).

use edit_train::collectives::{group, ThreadComm};
use edit_train::tensor::{kernels, ShardSpec};
use edit_train::testing::{check, Gen};

fn shards_of(len: usize, n: usize) -> Vec<(usize, usize)> {
    let spec = ShardSpec::new(len, n);
    (0..n).map(|r| spec.range(r)).collect()
}

fn rand_bufs(g: &mut Gen, n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.vec_f32(len, 10.0)).collect()
}

/// Run `f` on every rank of an n-way ThreadComm over `bufs`, returning
/// the per-rank buffers afterwards.
fn run_threaded<F>(bufs: &[Vec<f32>], f: F) -> Vec<Vec<f32>>
where
    F: Fn(&ThreadComm, &mut Vec<f32>) + Send + Sync,
{
    let n = bufs.len();
    let comms = ThreadComm::group(n);
    let mut out = vec![Vec::new(); n];
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .zip(bufs.iter().cloned())
            .map(|(c, mut buf)| {
                s.spawn(move || {
                    f(&c, &mut buf);
                    buf
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            out[r] = h.join().unwrap();
        }
    });
    out
}

#[test]
fn prop_threaded_reduce_scatter_sum_bitwise() {
    check("threaded rs-sum == group rs-sum", 25, |g| {
        // n includes the 1-rank degenerate case; lengths exercise empty
        // tail shards and off-by-one remainders.
        let n = g.usize(1, 6);
        let len = g.usize(0, 3 * n + 7);
        let shards = shards_of(len, n);
        let bufs = rand_bufs(g, n, len);
        let mut seq = bufs.clone();
        {
            let mut refs: Vec<&mut [f32]> =
                seq.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_sum(&mut refs, &shards);
        }
        let sh = &shards;
        let got = run_threaded(&bufs, move |c, buf| c.reduce_scatter_sum(buf, sh));
        assert_eq!(got, seq, "n={n} len={len}");
    });
}

#[test]
fn prop_threaded_reduce_scatter_weighted_bitwise() {
    check("threaded rs-weighted == group rs-weighted", 25, |g| {
        let n = g.usize(1, 6);
        let len = g.usize(0, 3 * n + 5);
        let shards = shards_of(len, n);
        // Non-negative softmax-style weights with exact zeros mixed in
        // (the skip-zero fold must match).
        let weights: Vec<f32> =
            (0..n).map(|_| if g.bool() { g.rng.f32() } else { 0.0 }).collect();
        let bufs = rand_bufs(g, n, len);
        let mut seq = bufs.clone();
        {
            let mut refs: Vec<&mut [f32]> =
                seq.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_weighted(&mut refs, &shards, &weights);
        }
        let (sh, ws) = (&shards, &weights);
        let got =
            run_threaded(&bufs, move |c, buf| c.reduce_scatter_weighted(buf, sh, ws));
        assert_eq!(got, seq, "n={n} len={len} weights={weights:?}");
    });
}

#[test]
fn prop_threaded_all_gather_bitwise() {
    check("threaded ag == group ag", 25, |g| {
        let n = g.usize(1, 6);
        let len = g.usize(0, 3 * n + 6);
        let shards = shards_of(len, n);
        let bufs = rand_bufs(g, n, len);
        let mut seq = bufs.clone();
        {
            let mut refs: Vec<&mut [f32]> =
                seq.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::all_gather(&mut refs, &shards);
        }
        let sh = &shards;
        let got = run_threaded(&bufs, move |c, buf| c.all_gather(buf, sh));
        assert_eq!(got, seq, "n={n} len={len}");
    });
}

#[test]
fn prop_rs_sum_then_gather_is_sum_fold() {
    // reduce-scatter(sum) + all-gather must leave every rank with the
    // full rank-0..n fold — the decomposition the sharded sync path's
    // pricing and numerics rely on.
    check("rs-sum + ag == fold", 25, |g| {
        let n = g.usize(1, 5);
        let len = g.usize(1, 4 * n + 3);
        let shards = shards_of(len, n);
        let bufs = rand_bufs(g, n, len);
        // Sequential rank-0..n fold reference.
        let mut fold = bufs[0].clone();
        for b in &bufs[1..] {
            for (a, &x) in fold.iter_mut().zip(b) {
                *a += x;
            }
        }
        let mut work = bufs.clone();
        {
            let mut refs: Vec<&mut [f32]> =
                work.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_sum(&mut refs, &shards);
            group::all_gather(&mut refs, &shards);
        }
        if n == 1 {
            // Degenerate group: both ops are no-ops by contract.
            assert_eq!(work[0], bufs[0]);
            return;
        }
        for (r, b) in work.iter().enumerate() {
            assert_eq!(b, &fold, "rank {r}");
        }
    });
}

#[test]
fn prop_weighted_rs_matches_fused_combine_kernel() {
    // The scratch arena's shard-local combine
    // (`kernels::weighted_sum_sq_strided` over a lane's Δ rows) and the
    // weighted reduce-scatter collective are the same fold: ascending
    // member order, zero weights skipped, f32 accumulation from zero.
    check("rs-weighted == strided combine", 25, |g| {
        let members = g.usize(1, 5);
        let len = g.usize(1, 23);
        let shards = shards_of(len, members);
        let weights: Vec<f32> =
            (0..members).map(|_| if g.bool() { g.rng.f32() } else { 0.0 }).collect();
        let rows = rand_bufs(g, members, len);
        // Collective reference.
        let mut coll = rows.clone();
        {
            let mut refs: Vec<&mut [f32]> =
                coll.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_weighted(&mut refs, &shards, &weights);
        }
        // Kernel path: rows flattened into one strided matrix, combined
        // over each shard's region exactly like a lane part.
        let mut flat = Vec::with_capacity(members * len);
        for r in &rows {
            flat.extend_from_slice(r);
        }
        for (s, &(off, l)) in shards.iter().enumerate() {
            if l == 0 {
                continue;
            }
            let mut out = vec![0.0f32; l];
            kernels::weighted_sum_sq_strided(&mut out, &flat, len, off, &weights);
            assert_eq!(
                &coll[s][off..off + l],
                &out[..],
                "shard {s} off={off} len={l}"
            );
        }
    });
}
