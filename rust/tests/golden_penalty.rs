//! Cross-layer golden test: the pure-Rust penalty combine must agree
//! with the L1 Pallas kernel on the vectors exported by `aot.py`
//! (`artifacts/golden/penalty.json`).

use edit_train::coordinator::penalty::{combine, PenaltyConfig};
use edit_train::testing::assert_close;
use edit_train::util::json::Json;

#[test]
fn rust_penalty_matches_pallas_golden_vectors() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden/penalty.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipping: golden vectors not built (run `make artifacts`)");
        return;
    };
    let cases = Json::parse(&text).unwrap();
    let cases = cases.as_arr().unwrap();
    assert!(cases.len() >= 3);

    for (i, case) in cases.iter().enumerate() {
        let w = case.at(&["num_workers"]).unwrap().as_usize().unwrap();
        let n = case.at(&["n"]).unwrap().as_usize().unwrap();
        let phi = case.at(&["phi"]).unwrap().as_f64().unwrap();
        let flat: Vec<f32> = case
            .at(&["deltas"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(flat.len(), w * n);
        let norms: Vec<f64> = case
            .at(&["norms"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| match x {
                Json::Str(s) if s == "inf" => f64::INFINITY,
                other => other.as_f64().unwrap(),
            })
            .collect();
        let expected: Vec<f32> = case
            .at(&["expected"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        let exp_weights: Vec<f32> = case
            .at(&["weights"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        let exp_beta = case.at(&["beta"]).unwrap().as_f64().unwrap();

        let rows: Vec<&[f32]> = (0..w).map(|j| &flat[j * n..(j + 1) * n]).collect();
        let cfg = PenaltyConfig { phi, ..PenaltyConfig::default() };
        let out = combine(&rows, &norms, &cfg);

        let all_anom = norms.iter().all(|g| !g.is_finite());
        assert_eq!(out.rollback, all_anom, "case {i}");
        if out.rollback {
            // Pallas path emits zeros; Rust signals rollback with an
            // empty delta — both mean "keep θ_t".
            assert!(expected.iter().all(|&x| x == 0.0));
        } else {
            assert_close(&out.delta, &expected, 1e-5, 1e-4);
            assert!((out.beta - exp_beta).abs() < 1e-4 * exp_beta.max(1.0), "case {i}");
        }
        assert_close(&out.weights, &exp_weights, 1e-5, 1e-4);
    }
}
