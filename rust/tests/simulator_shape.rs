//! Paper-shape regression suite over the analytic simulator and method
//! matrix: these tests pin the qualitative claims of the evaluation
//! section so refactors cannot silently break the reproduction.

use edit_train::coordinator::Method;
use edit_train::simulator::{simulate, Scenario, ScaleSpec, SimConfig};
use edit_train::simulator::trace::sync_timeline;
use edit_train::testing::check;

fn tflops(method: Method, scenario: Scenario) -> f64 {
    simulate(&SimConfig::fig5(method, scenario)).tflops_per_gpu.unwrap()
}

#[test]
fn fig5_random_straggler_monotone_in_lag() {
    for method in [Method::Baseline, Method::Edit, Method::AEdit] {
        let mut prev = f64::INFINITY;
        for lag in [0.0, 1.5, 2.5, 3.5, 4.5] {
            let t = if lag == 0.0 {
                tflops(method, Scenario::Normal)
            } else {
                tflops(method, Scenario::RandomStraggler { lag })
            };
            assert!(t <= prev + 1e-9, "{method:?} lag {lag}: {t} > {prev}");
            prev = t;
        }
    }
}

#[test]
fn fig5_bandwidth_monotone_and_selective() {
    let mut prev = f64::INFINITY;
    for rep in [0u32, 10, 20, 30, 40] {
        let s = if rep == 0 {
            Scenario::Normal
        } else {
            Scenario::LimitedBandwidth { repeat: rep }
        };
        let b = tflops(Method::Baseline, s);
        assert!(b < prev + 1e-9);
        prev = b;
        // EDiT loses <1% even at the harshest derate.
        let e = tflops(Method::Edit, s);
        assert!(e > 0.99 * tflops(Method::Edit, Scenario::Normal));
    }
}

#[test]
fn fig5_aedit_dominates_edit_under_any_straggler() {
    check("aedit >= edit", 20, |g| {
        let lag = 0.5 + g.rng.f64() * 4.0;
        let s = if g.bool() {
            Scenario::RandomStraggler { lag }
        } else {
            Scenario::ConsistentStraggler { lag }
        };
        let e = tflops(Method::Edit, s);
        let a = tflops(Method::AEdit, s);
        assert!(a >= e - 1e-9, "lag {lag}: edit {e} > aedit {a}");
    });
}

#[test]
fn table2_throughput_decreases_with_scale() {
    let mut prev = f64::INFINITY;
    for scale in ScaleSpec::PAPER {
        let t = simulate(&SimConfig::table2(Method::Edit, scale))
            .tokens_per_sec
            .unwrap();
        assert!(t < prev);
        prev = t;
    }
}

#[test]
fn table2_tflops_increases_with_scale() {
    let mut prev = 0.0;
    for scale in ScaleSpec::PAPER {
        let t = simulate(&SimConfig::table2(Method::Edit, scale))
            .tflops_per_gpu
            .unwrap();
        assert!(t > prev);
        prev = t;
    }
}

#[test]
fn table2_edit_always_beats_baseline_when_both_fit() {
    check("edit > baseline", 16, |g| {
        let scale = ScaleSpec::PAPER[g.usize(0, 4)];
        let tau = [5u64, 16, 64, 128][g.usize(0, 4)];
        let mut cb = SimConfig::table2(Method::Baseline, scale);
        let mut ce = SimConfig::table2(Method::Edit, scale);
        cb.tau = tau;
        ce.tau = tau;
        let b = simulate(&cb);
        let e = simulate(&ce);
        assert!(!e.oom, "EDiT never OOMs in Table 2");
        if !b.oom {
            assert!(e.tflops_per_gpu.unwrap() > b.tflops_per_gpu.unwrap());
        }
    });
}

#[test]
fn oom_is_monotone_in_scale_per_method() {
    // Once a method OOMs at some scale it OOMs at every larger scale.
    for method in Method::ALL {
        let mut seen_oom = false;
        for scale in ScaleSpec::PAPER {
            let r = simulate(&SimConfig::table2(method, scale));
            if seen_oom {
                assert!(r.oom, "{method:?} {}", scale.name);
            }
            seen_oom |= r.oom;
        }
    }
}

#[test]
fn fig9_exposed_matches_stepmodel_ordering() {
    let exposed: Vec<(Method, f64)> = [
        Method::Co2,
        Method::Edit,
        Method::PostLocalSgd,
        Method::Co2Star,
        Method::DiLoCo,
    ]
    .iter()
    .map(|&m| (m, sync_timeline(m).exposed))
    .collect();
    // Strictly increasing in the paper's order (CO2 < EDiT < PLS < CO2* < DiLoCo-offloaded).
    for w in exposed.windows(2) {
        assert!(
            w[0].1 < w[1].1,
            "{:?} ({}) !< {:?} ({})",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }
}

#[test]
fn larger_tau_never_hurts_throughput() {
    check("tau monotone", 12, |g| {
        let scale = ScaleSpec::PAPER[g.usize(0, 4)];
        let mut c1 = SimConfig::table2(Method::Edit, scale);
        let mut c2 = c1.clone();
        let t1 = [2u64, 5, 16][g.usize(0, 3)];
        c1.tau = t1;
        c2.tau = t1 * 4;
        let r1 = simulate(&c1).tokens_per_sec.unwrap();
        let r2 = simulate(&c2).tokens_per_sec.unwrap();
        assert!(r2 >= r1 - 1e-9);
    });
}

#[test]
fn method_matrix_consistency() {
    // Structural invariants tying the spec axes to the simulator.
    for m in Method::ALL {
        let spec = m.spec();
        if spec.uses_penalty() {
            assert!(spec.shard_outer_state, "{m:?}: penalty implies sharded state");
            assert!(spec.layerwise(), "{m:?}");
        }
        if spec.outer_staleness > 0 {
            // CO2 family: overlapped sync -> zero exposed residual when
            // unsharded, CO2* pays shard handling.
            let tl = sync_timeline(m);
            if m == Method::Co2 {
                assert_eq!(tl.exposed, 0.0);
            } else {
                assert!(tl.exposed > 0.0);
            }
        }
    }
}

#[test]
fn palsgd_simulates_like_aedit_under_stragglers() {
    // The descriptor-registered strategy rides the asynchronous trigger
    // arm of the cluster model: under any straggler it must price
    // exactly like A-EDiT (same axes apart from the probability), and
    // strictly above barriered EDiT.
    for lag in [1.5, 3.5] {
        for s in [
            Scenario::RandomStraggler { lag },
            Scenario::ConsistentStraggler { lag },
        ] {
            let a = simulate(&SimConfig::fig5(Method::AEdit, s)).tflops_per_gpu.unwrap();
            let p = simulate(&SimConfig::fig5(Method::Palsgd, s)).tflops_per_gpu.unwrap();
            let e = simulate(&SimConfig::fig5(Method::Edit, s)).tflops_per_gpu.unwrap();
            assert_eq!(p.to_bits(), a.to_bits(), "lag {lag}");
            assert!(p > e, "lag {lag}: palsgd {p} <= edit {e}");
        }
    }
}

#[test]
fn custom_flat_sync_row_loses_the_layerwise_overlap() {
    // The §4.4 "w/o layer-wise sync" ablation row, priced analytically:
    // dropping sync=layer forfeits both the pipeline overlap (larger
    // exposed sync) and the ZeRO-3 composition (more memory).
    use edit_train::coordinator::MethodSpec;
    let (flat, label) =
        MethodSpec::parse("custom:base=edit,sync=flat").expect("grammar parses");
    let scale = ScaleSpec::by_name("350M").unwrap();
    let e = simulate(&SimConfig::table2(Method::Edit, scale));
    let f = simulate(&SimConfig::table2_spec(flat, label.as_str(), scale));
    assert!(!e.oom && !f.oom);
    assert!(
        f.tflops_per_gpu.unwrap() < e.tflops_per_gpu.unwrap(),
        "flat-sync row must pay exposed sync: {:?} vs {:?}",
        f.tflops_per_gpu,
        e.tflops_per_gpu
    );
    assert!(f.memory.total() > e.memory.total(), "flat row loses ZeRO-3");
    // Penalty-off keeps the layer-wise overlap: throughput unchanged.
    let (off, label_off) =
        MethodSpec::parse("custom:base=edit,penalty=off").expect("grammar parses");
    let e = simulate(&SimConfig::table2(Method::Edit, scale));
    let o = simulate(&SimConfig::table2_spec(off, label_off.as_str(), scale));
    assert_eq!(
        o.tflops_per_gpu.unwrap().to_bits(),
        e.tflops_per_gpu.unwrap().to_bits()
    );
}
