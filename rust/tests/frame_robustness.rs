//! Adversarial-input property suite for the wire framing layer
//! (`collectives::frame`): truncations at every byte boundary, random
//! bit flips, oversized length fields, and arbitrary chunk splits. The
//! contract under test is WIRE_PROTOCOL.md §2: a decoder facing corrupt
//! or partial bytes returns `Err`/`None` — it never panics, never
//! over-allocates from a forged length, and never loses the frame
//! boundary on input that is merely *incomplete*.

use std::io::{self, Read};

use edit_train::collectives::frame::{
    read_frame, read_frame_negotiating, write_frame, Frame, FrameBuffer, FrameKind,
    PayloadReader, PayloadWriter, HEADER_LEN, MAX_PAYLOAD, PROTOCOL_VERSION, RANK_UNASSIGNED,
};
use edit_train::util::prng::Rng;

/// A corpus covering every frame kind plus randomized payload shapes —
/// the valid inputs the corruption tests start from.
fn corpus(rng: &mut Rng) -> Vec<Frame> {
    let mut frames = vec![
        Frame::new(FrameKind::Hello, RANK_UNASSIGNED, 0, Vec::new()),
        {
            // Reconnect Hello: rank + generation + last-acked seq (§6.1).
            let mut p = PayloadWriter::default();
            p.u32(1).u64(3).u64(17);
            Frame::new(FrameKind::Hello, 1, 3, p.finish())
        },
        {
            // Welcome: rank + world + start_seq (§3.1, v2).
            let mut p = PayloadWriter::default();
            p.u32(2).u32(3).u64(9);
            Frame::new(FrameKind::Welcome, 2, 1, p.finish())
        },
        {
            // Contribute: op header + operand + shard table (§3.3).
            let mut p = PayloadWriter::default();
            p.u8(3).u64(5).f32s(&[1.5, -0.0, f32::NAN, f32::MIN_POSITIVE]).shards(&[
                (0, 2),
                (2, 2),
            ]);
            Frame::new(FrameKind::Contribute, 0, 2, p.finish())
        },
        {
            // Error: seq + code + rank + message (§3.5).
            let mut p = PayloadWriter::default();
            p.u64(7).u8(1).u32(2).text("peer 2 evicted");
            Frame::new(FrameKind::Error, RANK_UNASSIGNED, 2, p.finish())
        },
        Frame::new(FrameKind::Heartbeat, 0, 1, Vec::new()),
        Frame::new(FrameKind::Goodbye, 1, 1, Vec::new()),
        Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, 4, Vec::new()),
    ];
    for _ in 0..8 {
        let len = rng.range(0, 2000);
        let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let kinds = [FrameKind::Contribute, FrameKind::Result, FrameKind::Welcome];
        let kind = kinds[rng.range(0, kinds.len())];
        frames.push(Frame::new(kind, rng.below(4) as u32, rng.below(5), payload));
    }
    frames
}

fn encode(frame: &Frame) -> Vec<u8> {
    let mut wire = Vec::new();
    write_frame(&mut wire, frame).unwrap();
    wire
}

#[test]
fn truncation_at_every_boundary_is_an_error_not_a_panic() {
    let mut rng = Rng::new(0xF5A3);
    for frame in corpus(&mut rng) {
        let wire = encode(&frame);
        for cut in 0..wire.len() {
            let prefix = &wire[..cut];
            // Eager reader: a strict prefix can never parse completely.
            assert!(
                read_frame(&mut &prefix[..]).is_err(),
                "prefix of {cut}/{} bytes parsed as a whole frame",
                wire.len()
            );
            // Incremental assembler: a prefix is *incomplete*, not
            // corrupt — it must stay parked at `None` awaiting bytes.
            let mut fb = FrameBuffer::new();
            fb.fill_from(&mut &prefix[..]).unwrap();
            match fb.poll() {
                Ok(None) => {}
                Ok(Some(f)) => panic!("prefix of {cut} bytes yielded frame {:?}", f.1.kind),
                Err(e) => panic!("prefix of {cut} bytes treated as corrupt: {e}"),
            }
        }
    }
}

#[test]
fn random_bit_flips_never_panic_or_hang() {
    let mut rng = Rng::new(0xB17F);
    for frame in corpus(&mut rng) {
        let wire = encode(&frame);
        for _ in 0..64 {
            let mut bytes = wire.clone();
            let at = rng.range(0, bytes.len());
            bytes[at] ^= 1 << rng.below(8);
            // Any outcome is fine except a panic: a flip may land in the
            // payload (frame still decodes, different bytes), the magic/
            // kind/version/length (error), or an opcode (caller's
            // PayloadReader rejects it later).
            let _ = read_frame(&mut bytes.as_slice());
            let _ = read_frame_negotiating(&mut bytes.as_slice());
            let mut fb = FrameBuffer::new();
            fb.fill_from(&mut bytes.as_slice()).unwrap();
            let _ = fb.poll();
        }
    }
}

#[test]
fn forged_length_fields_fail_before_allocating() {
    // A corrupt length must be rejected by the MAX_PAYLOAD bound (or, if
    // within the bound but past the bytes on hand, surface as truncation
    // / remain incomplete) — never become a giant allocation.
    for forged in [MAX_PAYLOAD + 1, u32::MAX as usize, (1 << 31) + 5] {
        let mut wire = Vec::new();
        wire.extend_from_slice(b"EDTF");
        wire.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        wire.push(FrameKind::Contribute as u8);
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&0u64.to_le_bytes());
        wire.extend_from_slice(&(forged as u32).to_le_bytes());
        wire.extend_from_slice(&[0u8; 16]);
        assert!(read_frame(&mut wire.as_slice()).is_err(), "len={forged} accepted");
        let mut fb = FrameBuffer::new();
        fb.fill_from(&mut wire.as_slice()).unwrap();
        assert!(fb.poll().is_err(), "len={forged} accepted by FrameBuffer");
    }
    // In-bound length with missing bytes: eager read errors (the stream
    // ended), incremental stays incomplete.
    let mut wire = Vec::new();
    write_frame(&mut wire, &Frame::new(FrameKind::Result, 0, 1, vec![0u8; 64])).unwrap();
    wire.truncate(HEADER_LEN + 10);
    assert!(read_frame(&mut wire.as_slice()).is_err());
    let mut fb = FrameBuffer::new();
    fb.fill_from(&mut wire.as_slice()).unwrap();
    assert!(matches!(fb.poll(), Ok(None)));
}

#[test]
fn payload_reader_fuzz_never_panics() {
    let mut rng = Rng::new(0x9EAD);
    for _ in 0..400 {
        let len = rng.range(0, 64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let mut r = PayloadReader::new(&bytes);
        for _ in 0..12 {
            match rng.below(7) {
                0 => {
                    let _ = r.u8();
                }
                1 => {
                    let _ = r.u32();
                }
                2 => {
                    let _ = r.u64();
                }
                3 => {
                    let _ = r.f32s();
                }
                4 => {
                    let _ = r.i8s();
                }
                5 => {
                    let _ = r.shards();
                }
                _ => {
                    let _ = r.text();
                }
            }
        }
    }
    // Forged element counts with a near-empty tail: every counted
    // accessor must fail as truncation instead of reserving count*size.
    let mut p = PayloadWriter::default();
    p.u32(u32::MAX);
    let forged = p.finish();
    assert!(PayloadReader::new(&forged).f32s().is_err());
    assert!(PayloadReader::new(&forged).i8s().is_err());
    assert!(PayloadReader::new(&forged).shards().is_err());
}

/// `Read` adapter yielding the stream in random-sized chunks — models a
/// TCP socket handing back arbitrary segment boundaries.
struct Chunker<'a> {
    data: &'a [u8],
    pos: usize,
    rng: Rng,
}

impl Read for Chunker<'_> {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let max = (self.data.len() - self.pos).min(out.len()).max(1);
        let n = self.rng.range(1, max + 1);
        out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[test]
fn frame_buffer_reassembles_bitwise_across_any_chunking() {
    let mut rng = Rng::new(0xC4A2);
    let frames = corpus(&mut rng);
    let mut stream = Vec::new();
    for f in &frames {
        write_frame(&mut stream, f).unwrap();
    }
    for trial in 0..20u64 {
        let mut src = Chunker { data: &stream, pos: 0, rng: Rng::new(0x51D0 ^ trial) };
        let mut fb = FrameBuffer::new();
        let mut got = Vec::new();
        loop {
            while let Some((version, frame)) = fb.poll().unwrap() {
                assert_eq!(version, PROTOCOL_VERSION);
                got.push(frame);
            }
            if fb.fill_from(&mut src).unwrap() == 0 {
                break;
            }
        }
        while let Some((_, frame)) = fb.poll().unwrap() {
            got.push(frame);
        }
        assert_eq!(got, frames, "trial {trial}: reassembly diverged");
    }
}
