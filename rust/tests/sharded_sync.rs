//! Sharded outer-state sync path (`TrainConfig::shard_outer`):
//! per-rank memory accounting and the rollback-path equivalence, on the
//! synthetic stub engine (runs on a clean box).
//!
//! The bitwise shard-on/off equivalence on the straggler/thread
//! matrices lives in `tests/scheduler_determinism.rs`; this file covers
//! the two acceptance criteria that need direct state access: the
//! per-rank sync high-water ≈ full footprint ÷ N, and the all-anomalous
//! module rollback reproducing bitwise under sharding.
#![cfg(not(feature = "pjrt"))]

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{MeshSpec, Method, TrainConfig, Trainer};
use edit_train::data::{Corpus, Quality};
use edit_train::runtime::{Engine, Manifest};

fn trainer(method: Method, replicas: usize, tweak: impl FnOnce(&mut TrainConfig)) -> Trainer {
    // 8 near-uniform layers keep the range-aligned shard partition close
    // to the ideal ceil(P/N) split (the accounting bound below).
    let manifest = Manifest::synthetic("sharded-sync", 8, 64, 32, 64, 2, 8);
    let vocab = manifest.model.vocab_size;
    let engine = Engine::synthetic(manifest);
    let corpus = Corpus::new(vocab, 23, Quality::clean());
    let mut cfg = TrainConfig::paper_default(method, MeshSpec::new(2, replicas), 48);
    cfg.tau = 4;
    cfg.t_warm = if method.spec().warmup { 2 } else { 0 };
    cfg.eval_every_syncs = 0;
    tweak(&mut cfg);
    Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100())).unwrap()
}

#[test]
fn per_rank_sync_memory_is_full_over_n() {
    for replicas in [2usize, 3, 4] {
        let t = trainer(Method::Edit, replicas, |_| {});
        let scratch = t.scratch();
        assert!(scratch.sharded());
        assert_eq!(scratch.shard_parts(), replicas);
        // The shards partition the flat space contiguously.
        let mut pos = 0usize;
        for s in 0..replicas {
            let (off, len) = scratch.shard_range(s);
            assert_eq!(off, pos, "N={replicas} shard {s}");
            pos = off + len;
        }
        assert_eq!(pos, t.num_params());
        // The ISSUE's headline bound: each rank's anchor + outer-state
        // shard is ≈ the full copy ÷ N (within the range-aligned
        // partition's imbalance). NOTE: this 1.25 factor is a property
        // of near-uniform layouts like this 8-layer model — in general
        // the largest shard is floored at the largest single module
        // range, since ranges are never split (see the ROADMAP Perf
        // section for the paper-scale caveat).
        let p = t.num_params();
        let max_len = (0..replicas).map(|s| scratch.shard_range(s).1).max().unwrap();
        assert!(
            (max_len as f64) <= 1.25 * p as f64 / replicas as f64,
            "N={replicas}: largest anchor/momentum shard {max_len} of {p}"
        );
        // Per-rank sync high-water (Δ shard rows + combine buffer +
        // scalar partials + anchor/momentum shards) ≈ the full-matrix
        // footprint ÷ N. The allowance covers the partition imbalance
        // plus the structural (replicas+3)/(replicas+2) factor from the
        // per-lane combine buffer (the unsharded arena's combine buffer
        // is max-module-sized, not P-sized).
        let full = t.unsharded_sync_footprint();
        let per_rank = t.shard_sync_high_water();
        assert!(per_rank > 0);
        assert!(
            (per_rank as f64) <= 1.55 * full as f64 / replicas as f64,
            "N={replicas}: per-rank {per_rank} vs full {full}"
        );
        // And the shards add up to ~one full footprint — no hidden
        // replication across ranks.
        let total: usize = (0..replicas)
            .map(|s| {
                let (_, len) = scratch.shard_range(s);
                scratch.shard_rank_bytes(s) + 2 * len * 4
            })
            .sum();
        assert!(
            (total as f64) < 1.3 * full as f64,
            "N={replicas}: ranks total {total} vs full {full}"
        );
    }
}

#[test]
fn unsharded_trainer_reports_no_shard_state() {
    let t = trainer(Method::Edit, 3, |c| c.shard_outer = false);
    assert!(!t.scratch().sharded());
    assert_eq!(t.scratch().shard_parts(), 0);
    assert_eq!(t.shard_sync_high_water(), 0);
}

#[test]
fn uniform_averaging_methods_never_shard() {
    // shard_outer only applies to the layer-wise (penalty) methods; the
    // all-reduce-based baselines keep the full-matrix mean path.
    for method in [Method::DiLoCo, Method::Co2, Method::PostLocalSgd] {
        let t = trainer(method, 3, |_| {});
        assert!(!t.scratch().sharded(), "{method:?}");
    }
}

#[test]
fn rollback_storm_bitwise_identical_across_shard_modes() {
    // δ = -∞ makes every finite z-score anomalous once the z-test
    // leaves warm-up, forcing the all-anomalous rollback path on every
    // module of every sync. The sharded path must reproduce the
    // rollback semantics (θ pinned at the anchor, members re-adopting
    // it) bitwise.
    let tweak = |shard: bool| {
        move |c: &mut TrainConfig| {
            c.shard_outer = shard;
            c.spec.penalty.delta = f64::NEG_INFINITY;
            c.spec.penalty.warmup_syncs = 1;
        }
    };
    for method in [Method::Edit, Method::AEdit] {
        let mut on = trainer(method, 4, tweak(true));
        let mut off = trainer(method, 4, tweak(false));
        let s_on = on.run().unwrap();
        let s_off = off.run().unwrap();
        assert!(s_on.rollbacks > 0, "{method:?}: rollback path not exercised");
        assert_eq!(s_on.rollbacks, s_off.rollbacks);
        assert_eq!(s_on.anomalies, s_off.anomalies);
        assert_eq!(s_on.final_loss.to_bits(), s_off.final_loss.to_bits());
        assert_eq!(s_on.sim_seconds.to_bits(), s_off.sim_seconds.to_bits());
        assert_eq!(on.anchor, off.anchor);
        for (a, b) in on.replicas.iter().zip(&off.replicas) {
            assert_eq!(a.params, b.params);
        }
    }
}
