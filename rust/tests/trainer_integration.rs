//! End-to-end trainer integration over the real AOT artifacts: every
//! method trains the `test` model; algebraic limits are checked
//! (EDiT == DiLoCo when the penalty is disabled, τ=1 consistency,
//! determinism, elastic rescale). Skips without built artifacts.

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{
    LrSchedule, MeshSpec, Method, PenaltyConfig, Straggler, TrainConfig, Trainer,
};
use edit_train::data::{Corpus, Quality};
use edit_train::elastic;
use edit_train::runtime::Engine;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("test/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn trainer(method: Method, steps: u64, seed: u64) -> Trainer {
    let engine = Engine::load(artifacts_root(), "test").unwrap();
    let corpus = Corpus::new(engine.manifest.model.vocab_size, seed, Quality::clean());
    let mut cfg = TrainConfig::paper_default(method, MeshSpec::new(2, 2), steps);
    cfg.tau = 4;
    cfg.tau_time = 4.0 * cfg.base_step_time;
    cfg.t_warm = if method.spec().warmup { 4 } else { 0 };
    cfg.seed = seed;
    cfg.eval_every_syncs = 0;
    cfg.inner_lr = LrSchedule::Constant { lr: 2e-3 };
    Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100())).unwrap()
}

#[test]
fn every_method_learns() {
    if !have_artifacts() {
        return;
    }
    for method in Method::ALL {
        let mut t = trainer(method, 24, 11);
        let summary = t.run().unwrap();
        let first = t.tracker.losses.first().unwrap().1;
        // Compare the LAST recorded loss to the first: the tail-mean
        // summary metric mixes warmup and local phases at this tiny
        // scale (24 steps) and would dilute the signal.
        let last = t.tracker.losses.last().unwrap().1;
        // 24 tiny steps: Nesterov-outer methods drop fast; plain
        // averaging (PLS) and grad-averaged DDP move slower at this
        // scale (matches the paper's ordering — PLS is its weakest
        // method too). Thresholds per family:
        let min_drop = match method {
            Method::Baseline | Method::PostLocalSgd => 0.05,
            // CO2's one-round staleness delays its first effective update.
            Method::Co2 | Method::Co2Star => 0.08,
            _ => 0.12,
        };
        assert!(
            last < first - min_drop,
            "{}: first {first:.3} last {last:.3}",
            method.name(),
        );
        assert!(summary.final_loss.is_finite());
        assert!(summary.throughput > 0.0);
        if method.spec().is_local_sgd() {
            assert!(summary.syncs > 0, "{}", method.name());
        }
    }
}

#[test]
fn deterministic_reruns() {
    if !have_artifacts() {
        return;
    }
    let s1 = trainer(Method::Edit, 16, 5).run().unwrap();
    let s2 = trainer(Method::Edit, 16, 5).run().unwrap();
    assert_eq!(s1.final_loss, s2.final_loss);
    assert_eq!(s1.tokens, s2.tokens);
}

#[test]
fn edit_equals_diloco_when_penalty_disabled() {
    if !have_artifacts() {
        return;
    }
    // EDiT with penalty fully disabled and no warmup performs uniform
    // averaging per module == DiLoCo's global uniform averaging, with the
    // same Nesterov outer state (module-partitioned application of the
    // same elementwise update).
    let mut edit = trainer(Method::Edit, 16, 9);
    edit.cfg.spec.penalty = PenaltyConfig::disabled();
    edit.cfg.t_warm = 0;
    let se = edit.run().unwrap();
    let sd = trainer(Method::DiLoCo, 16, 9).run().unwrap();
    assert!(
        (se.final_loss - sd.final_loss).abs() < 1e-5,
        "edit {} vs diloco {}",
        se.final_loss,
        sd.final_loss
    );
}

#[test]
fn diloco_with_tau1_close_to_baseline_losses() {
    if !have_artifacts() {
        return;
    }
    // τ=1 with SGD-lr-1 outer (PLS) == averaging params every step. With
    // identical data order this tracks DDP closely (not exactly: grad
    // averaging vs param averaging after one AdamW step differ at 2nd
    // order). Check the curves stay close.
    let mut pls = trainer(Method::PostLocalSgd, 12, 3);
    pls.cfg.tau = 1;
    pls.cfg.t_warm = 0;
    let sp = pls.run().unwrap();
    let sb = trainer(Method::Baseline, 12, 3).run().unwrap();
    assert!(
        (sp.final_loss - sb.final_loss).abs() < 0.35,
        "pls {} vs ddp {}",
        sp.final_loss,
        sb.final_loss
    );
}

#[test]
fn warmup_phase_keeps_replicas_identical() {
    if !have_artifacts() {
        return;
    }
    let mut t = trainer(Method::Edit, 4, 7); // entirely within t_warm=4
    t.run().unwrap();
    let p0 = &t.replicas[0].params;
    for r in &t.replicas[1..] {
        assert_eq!(&r.params, p0);
    }
}

#[test]
fn straggler_increases_sim_time_not_loss_path() {
    if !have_artifacts() {
        return;
    }
    let fast = trainer(Method::Edit, 16, 13).run().unwrap();
    let mut slow_t = trainer(Method::Edit, 16, 13);
    slow_t.cfg.straggler = Straggler::Consistent { lag: 1.0, replica: 0 };
    let slow = slow_t.run().unwrap();
    // Step-synced EDiT: same numerics, more simulated time.
    assert_eq!(slow.final_loss, fast.final_loss);
    assert!(slow.sim_seconds > fast.sim_seconds + 5.0);
    assert!(slow.throughput < fast.throughput);
}

#[test]
fn aedit_fast_workers_do_more_steps_under_straggler() {
    if !have_artifacts() {
        return;
    }
    let mut t = trainer(Method::AEdit, 20, 17);
    t.cfg.t_warm = 0;
    t.cfg.straggler = Straggler::Consistent { lag: 2.0, replica: 0 };
    let summary = t.run().unwrap();
    let steps0 = t.replicas[0].inner_steps;
    let steps1 = t.replicas[1].inner_steps;
    assert!(
        steps1 > steps0,
        "fast replica should run more inner steps: {steps0} vs {steps1}"
    );
    // Event-driven anchor sync: the straggler keeps its own clock (no
    // global barrier) and somebody observed anchor staleness.
    assert_ne!(
        t.replicas[0].clock.to_bits(),
        t.replicas[1].clock.to_bits(),
        "A-EDiT workers must not share a post-sync clock"
    );
    assert!(summary.max_staleness >= 1);
}

#[test]
fn elastic_rescale_preserves_learning() {
    if !have_artifacts() {
        return;
    }
    let mut t = trainer(Method::Edit, 8, 19);
    t.cfg.t_warm = 0;
    let phases = [
        elastic::Phase { replicas: 1, steps: 8 },
        elastic::Phase { replicas: 3, steps: 8 },
        elastic::Phase { replicas: 2, steps: 8 },
    ];
    let points = elastic::run_schedule(&mut t, &phases).unwrap();
    assert_eq!(points.len(), 3);
    assert_eq!(t.replicas.len(), 2);
    assert_eq!(points[1].replicas, 3);
    // PPL improves over the schedule.
    assert!(points[2].val_ppl < points[0].val_ppl * 1.05);
    // All replicas share the synchronized state after the final round.
    let p0 = &t.anchor;
    for r in &t.replicas {
        assert_eq!(&r.params, p0);
    }
}

#[test]
fn probes_report_all_streams() {
    if !have_artifacts() {
        return;
    }
    let mut t = trainer(Method::Baseline, 4, 23);
    t.run().unwrap();
    let probes = t.probe_ppls().unwrap();
    assert_eq!(probes.len(), 8);
    for (name, ppl) in probes {
        assert!(ppl.is_finite() && ppl > 1.0, "{name}: {ppl}");
    }
}

#[test]
fn co2_staleness_delays_outer_update_and_flushes_at_end() {
    if !have_artifacts() {
        return;
    }
    // After the FIRST sync, CO2's anchor must still equal the init params
    // (its round-1 update is in flight), while DiLoCo's anchor moved.
    let mut co2 = trainer(Method::Co2, 4, 29); // one round of tau=4
    let init = {
        let e = Engine::load(artifacts_root(), "test").unwrap();
        e.init_params().unwrap()
    };
    co2.run_round().unwrap();
    assert_eq!(co2.syncs, 1);
    assert_eq!(co2.anchor, init, "CO2 anchor unchanged after first sync");

    // run() from here is a no-op for steps (global_step == total_steps)
    // but must flush the in-flight combined update instead of silently
    // dropping it.
    let summary = co2.run().unwrap();
    assert_eq!(summary.flushed_updates, 1);
    assert_ne!(co2.anchor, init, "flush lands the in-flight update");
    for r in &co2.replicas {
        assert_eq!(&r.params, &co2.anchor);
    }

    let mut diloco = trainer(Method::DiLoCo, 4, 29);
    let sd = diloco.run().unwrap();
    assert_ne!(diloco.anchor, init, "DiLoCo applies immediately");
    assert_eq!(sd.flushed_updates, 0);
}
