//! Integration: AOT artifacts → PJRT load → execute → numerics checks.
//!
//! Requires `make artifacts` (skips gracefully otherwise).
//!
//! `unused_mut` is allowed file-wide: the stub backend's step methods
//! take `&self` (so the trainer's parallel lanes can share the engine),
//! but the PJRT backend keeps `&mut self` for its executable cache, and
//! this file compiles against both.
#![allow(unused_mut)]

use edit_train::data::{Corpus, Quality, Split};
use edit_train::runtime::Engine;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine_or_skip() -> Option<Engine> {
    let root = artifacts_root();
    if !root.join("test/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&root, "test").expect("engine load"))
}

fn batch(engine: &Engine, step: u64) -> Vec<i32> {
    let [b, s1] = engine.manifest.token_shape;
    let corpus = Corpus::new(engine.manifest.model.vocab_size, 7, Quality::clean());
    corpus.batch_i32(Split::Train, 0, step, b, s1)
}

#[test]
fn train_step_reduces_loss() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut params = engine.init_params().unwrap();
    let n = params.len();
    assert_eq!(n, engine.manifest.total_params);
    let (mut m, mut v) = (vec![0.0; n], vec![0.0; n]);
    let tokens = batch(&engine, 0);
    let mut losses = Vec::new();
    for step in 1..=10 {
        let out = engine
            .train_step(&mut params, &mut m, &mut v, &tokens, 3e-3, step)
            .unwrap();
        losses.push(out.loss);
    }
    assert!(losses[9] < losses[0] - 0.5, "{losses:?}");
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn grad_apply_equals_fused_train_step() {
    let Some(mut engine) = engine_or_skip() else { return };
    let params0 = engine.init_params().unwrap();
    let n = params0.len();
    let tokens = batch(&engine, 1);

    // Fused path
    let mut p1 = params0.clone();
    let (mut m1, mut v1) = (vec![0.0; n], vec![0.0; n]);
    let out1 = engine.train_step(&mut p1, &mut m1, &mut v1, &tokens, 1e-3, 1).unwrap();

    // Split path
    let mut grads = vec![0.0; n];
    let out2 = engine.grad_step(&params0, &tokens, &mut grads).unwrap();
    let mut p2 = params0.clone();
    let (mut m2, mut v2) = (vec![0.0; n], vec![0.0; n]);
    engine.apply_step(&mut p2, &mut m2, &mut v2, &grads, 1e-3, 1).unwrap();

    assert!((out1.loss - out2.loss).abs() < 1e-6);
    let max_diff = p1
        .iter()
        .zip(&p2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-6, "max param diff {max_diff}");
}

#[test]
fn eval_step_matches_grad_loss_and_is_pure() {
    let Some(mut engine) = engine_or_skip() else { return };
    let params = engine.init_params().unwrap();
    let tokens = batch(&engine, 2);
    let mut grads = vec![0.0; params.len()];
    let g = engine.grad_step(&params, &tokens, &mut grads).unwrap();
    let e1 = engine.eval_step(&params, &tokens).unwrap();
    let e2 = engine.eval_step(&params, &tokens).unwrap();
    assert!((g.loss - e1).abs() < 1e-6);
    assert_eq!(e1, e2, "eval must be deterministic");
    // Near-uniform init loss ~ ln(V)
    let lnv = (engine.manifest.model.vocab_size as f32).ln();
    assert!((e1 - lnv).abs() < 1.0, "init loss {e1} vs ln(V) {lnv}");
}

#[test]
fn penalty_hlo_matches_rust_implementation() {
    let Some(mut engine) = engine_or_skip() else { return };
    let n = engine.manifest.total_params;
    let w = 2;
    if !engine.has_penalty_program(w) {
        // Only the stub backend may lack it; a PJRT build with artifacts
        // regressed its export pipeline if this trips.
        assert!(
            cfg!(not(feature = "pjrt")),
            "PJRT build with artifacts must expose a penalty HLO for w={w}"
        );
        eprintln!(
            "skipping: penalty HLO not executable on the stub backend (needs --features pjrt)"
        );
        return;
    }
    // Deterministic pseudo-grads
    let deltas: Vec<Vec<f32>> = (0..w)
        .map(|j| (0..n).map(|i| ((i * (j + 2)) % 17) as f32 / 17.0 - 0.5).collect())
        .collect();
    let norms: Vec<f32> = deltas
        .iter()
        .map(|d| edit_train::tensor::norm(d) as f32)
        .collect();
    let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
    let got = engine.penalty_combine(&refs, &norms).unwrap();

    let cfg = edit_train::coordinator::PenaltyConfig::default();
    let screened: Vec<f64> = norms.iter().map(|&x| x as f64).collect();
    let want = edit_train::coordinator::penalty::combine(&refs, &screened, &cfg);
    assert_eq!(got.len(), n);
    edit_train::testing::assert_close(&got, &want.delta, 2e-5, 2e-4);
}

#[test]
fn deterministic_across_engine_reloads() {
    let Some(mut e1) = engine_or_skip() else { return };
    let mut e2 = Engine::load(artifacts_root(), "test").unwrap();
    let tokens = batch(&e1, 3);
    let mut p1 = e1.init_params().unwrap();
    let mut p2 = e2.init_params().unwrap();
    let n = p1.len();
    let (mut m1, mut v1) = (vec![0.0; n], vec![0.0; n]);
    let (mut m2, mut v2) = (vec![0.0; n], vec![0.0; n]);
    for step in 1..=3 {
        let o1 = e1.train_step(&mut p1, &mut m1, &mut v1, &tokens, 1e-3, step).unwrap();
        let o2 = e2.train_step(&mut p2, &mut m2, &mut v2, &tokens, 1e-3, step).unwrap();
        assert_eq!(o1.loss, o2.loss);
    }
    assert_eq!(p1, p2);
}
