//! Cross-module property tests (first-party `testing::check` harness —
//! the vendored set has no proptest).

use edit_train::collectives::{group, CollOp, CostModel, ThreadComm, Topology};
use edit_train::coordinator::penalty::{combine, softmax_neg_weights, PenaltyConfig};
use edit_train::coordinator::{LrSchedule, MeshSpec};
use edit_train::data::{Corpus, Quality, Split};
use edit_train::tensor::{self, ShardSpec};
use edit_train::testing::{assert_close, check, Gen};
use edit_train::util::json::{Json, Obj};

fn rand_bufs(g: &mut Gen, n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.vec_f32(len, 10.0)).collect()
}

#[test]
fn prop_allreduce_mean_preserves_mean() {
    check("allreduce-preserves-mean", 40, |g| {
        let n = g.usize(1, 6);
        let len = g.len() * 3;
        let mut bufs = rand_bufs(g, n, len);
        let expect: Vec<f64> = (0..len)
            .map(|i| bufs.iter().map(|b| b[i] as f64).sum::<f64>() / n as f64)
            .collect();
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        for b in &bufs {
            for (got, want) in b.iter().zip(&expect) {
                assert!((*got as f64 - want).abs() < 1e-4, "{got} vs {want}");
            }
        }
    });
}

#[test]
fn prop_reduce_scatter_plus_gather_equals_allreduce() {
    check("rs+ag == ar", 30, |g| {
        let n = g.usize(1, 5);
        let len = g.len() * n * 2;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let mut a = rand_bufs(g, n, len);
        let mut b = a.clone();
        {
            let mut refs: Vec<&mut [f32]> = a.iter_mut().map(|x| x.as_mut_slice()).collect();
            group::all_reduce_mean(&mut refs);
        }
        {
            let mut refs: Vec<&mut [f32]> = b.iter_mut().map(|x| x.as_mut_slice()).collect();
            group::reduce_scatter_mean(&mut refs, &shards);
            group::all_gather(&mut refs, &shards);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_close(x, y, 1e-4, 1e-4);
        }
    });
}

#[test]
fn prop_threaded_matches_sequential_allreduce() {
    check("threaded == sequential", 10, |g| {
        let n = g.usize(2, 5);
        let len = g.len() * 4;
        let bufs = rand_bufs(g, n, len);
        let mut seq = bufs.clone();
        {
            let mut refs: Vec<&mut [f32]> = seq.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::all_reduce_mean(&mut refs);
        }
        let comms = ThreadComm::group(n);
        let mut threaded = vec![Vec::new(); n];
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(bufs)
                .map(|(c, mut buf)| {
                    s.spawn(move || {
                        c.all_reduce_mean(&mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                threaded[r] = h.join().unwrap();
            }
        });
        assert_eq!(seq, threaded, "bitwise equality required");
    });
}

#[test]
fn prop_penalty_combine_bounds() {
    check("penalty bounds", 40, |g| {
        let w = g.usize(2, 6);
        let n = g.len() * 4;
        let deltas: Vec<Vec<f32>> = (0..w).map(|_| g.vec_f32(n, 5.0)).collect();
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let mut norms: Vec<f64> = deltas.iter().map(|d| tensor::norm(d)).collect();
        // Random anomalies (never all).
        for i in 1..w {
            if g.bool() && g.bool() {
                norms[i] = f64::INFINITY;
            }
        }
        let phi = 0.5 + g.rng.f64() * 10.0;
        let cfg = PenaltyConfig { phi, ..PenaltyConfig::default() };
        let out = combine(&refs, &norms, &cfg);
        assert!(!out.rollback);
        // Clip bound
        assert!(tensor::norm(&out.delta) <= phi + 1e-3);
        // Convexity: combined delta inside the per-coordinate envelope of
        // the surviving deltas (pre-clip weighted mean is convex; clip
        // shrinks towards 0 which stays within [min(0,lo), max(0,hi)]).
        for i in (0..n).step_by((n / 7).max(1)) {
            let survivors: Vec<f32> = (0..w)
                .filter(|&j| norms[j].is_finite())
                .map(|j| deltas[j][i])
                .collect();
            let lo = survivors.iter().cloned().fold(f32::INFINITY, f32::min).min(0.0);
            let hi = survivors.iter().cloned().fold(f32::NEG_INFINITY, f32::max).max(0.0);
            assert!(
                out.delta[i] >= lo - 1e-4 && out.delta[i] <= hi + 1e-4,
                "coord {i}: {} not in [{lo}, {hi}]",
                out.delta[i]
            );
        }
    });
}

#[test]
fn prop_weights_monotone_in_norm() {
    check("weights monotone", 30, |g| {
        let w = g.usize(2, 8);
        let mut norms: Vec<f64> = (0..w).map(|_| g.rng.f64() * 20.0).collect();
        let weights = softmax_neg_weights(&norms, true);
        // Sort both by norm; weights must be non-increasing.
        let mut idx: Vec<usize> = (0..w).collect();
        idx.sort_by(|&a, &b| norms[a].partial_cmp(&norms[b]).unwrap());
        for pair in idx.windows(2) {
            assert!(
                weights[pair[0]] >= weights[pair[1]] - 1e-7,
                "norms {norms:?} weights {weights:?}"
            );
        }
        norms[0] = f64::INFINITY;
        assert_eq!(softmax_neg_weights(&norms, true)[0], 0.0);
    });
}

#[test]
fn prop_mesh_groups_consistent() {
    check("mesh groups", 40, |g| {
        let mesh = MeshSpec::new(g.usize(1, 9), g.usize(1, 9));
        let topo = Topology::a100();
        // Every worker appears in exactly one shard group and one sync
        // group; their intersection is that worker.
        for rank in 0..mesh.workers() {
            let (row, col) = mesh.coords(rank);
            assert!(mesh.shard_group(col).contains(&rank));
            assert!(mesh.sync_group(row).contains(&rank));
        }
        // Cost model symmetry: time depends on the group, not the rank
        // ordering within it.
        let cost = CostModel::new(topo);
        if mesh.replicas >= 2 {
            let fwd = mesh.sync_group(0);
            let mut rev = fwd.clone();
            rev.reverse();
            assert_eq!(
                cost.time(CollOp::AllReduce, 1 << 20, &fwd),
                cost.time(CollOp::AllReduce, 1 << 20, &rev)
            );
        }
    });
}

#[test]
fn prop_corpus_batches_deterministic_and_in_vocab() {
    check("corpus determinism", 20, |g| {
        let vocab = 1 << g.usize(4, 10);
        let seed = g.rng.next_u64();
        let noise = if g.bool() { 0.0 } else { 0.2 };
        let c1 = Corpus::new(vocab, seed, Quality { noise_prob: noise });
        let c2 = Corpus::new(vocab, seed, Quality { noise_prob: noise });
        let worker = g.usize(0, 64);
        let step = g.rng.next_u64() % 1000;
        let b1 = c1.batch_i32(Split::Train, worker, step, 2, 33);
        let b2 = c2.batch_i32(Split::Train, worker, step, 2, 33);
        assert_eq!(b1, b2);
        assert!(b1.iter().all(|&t| t >= 0 && (t as usize) < vocab));
    });
}

#[test]
fn prop_lr_schedules_positive_and_bounded() {
    check("lr schedule bounds", 30, |g| {
        let lr = 10f64.powi(-(g.usize(1, 6) as i32));
        let total = (g.len() as u64) * 50 + 10;
        let s = LrSchedule::paper_cosine(lr, total);
        for step in [0, 1, total / 2, total, total * 2] {
            let v = s.at(step);
            assert!(v > 0.0 && v <= lr * (1.0 + 1e-9), "step {step}: {v}");
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    check("json roundtrip", 30, |g| {
        // Random JSON tree, bounded depth.
        fn build(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize(0, 4) } else { g.usize(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f32(1000.0) as f64 * 8.0).round() / 8.0),
                3 => Json::Str(format!("s{}\"\\\n{}", g.usize(0, 100), g.usize(0, 10))),
                4 => Json::Arr((0..g.usize(0, 4)).map(|_| build(g, depth - 1)).collect()),
                _ => {
                    let mut o = Obj::new();
                    for i in 0..g.usize(0, 4) {
                        o.insert(format!("k{i}"), build(g, depth - 1));
                    }
                    Json::Obj(o)
                }
            }
        }
        let tree = build(g, 3);
        assert_eq!(Json::parse(&tree.to_string()).unwrap(), tree);
        assert_eq!(Json::parse(&tree.to_string_pretty()).unwrap(), tree);
    });
}

#[test]
fn prop_shard_spec_partitions() {
    check("shards partition", 40, |g| {
        let total = g.len() * 7;
        let parts = g.usize(1, 12);
        let spec = ShardSpec::new(total, parts);
        let mut sum = 0;
        for r in 0..parts {
            let (off, len) = spec.range(r);
            assert_eq!(off, spec.range(r).0);
            sum += len;
            for i in off..off + len {
                assert_eq!(spec.owner(i), r);
            }
        }
        assert_eq!(sum, total);
    });
}
