//! The steady-state zero-allocation invariant (see
//! `coordinator::scratch`): after warm-up, full trainer rounds —
//! τ inner steps per replica plus the synchronization, on both the
//! sharded (`shard_outer`) and full-matrix sync paths — must perform
//! zero heap allocations, up to the documented loss-trace bound
//! (`LOSS_TRACE_CAP` = 2^20 inner steps per replica; these runs stay
//! far below it). Asserted with a counting global allocator over the
//! deterministic stub engine (default build; the PJRT backend
//! allocates inside the XLA FFI, which is outside this contract).
//!
//! The fault-injection harness is compiled into every round
//! (`apply_fault_events` runs before the lanes even with an empty
//! plan), so this test also pins the ISSUE-6 requirement that the
//! inactive harness costs nothing: the per-round cap refill and crash
//! bookkeeping reuse preallocated vectors and must not allocate.
//!
//! Single-test file on purpose: the allocation counter is global, so no
//! other test may run concurrently in this binary.
#![cfg(not(feature = "pjrt"))]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{MeshSpec, Method, MethodSpec, TrainConfig, Trainer};
use edit_train::data::{Corpus, Quality};
use edit_train::runtime::{Engine, Manifest};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn trainer_spec(spec: MethodSpec, label: &str, shard_outer: bool) -> Trainer {
    let manifest = Manifest::synthetic("alloc-test", 3, 96, 40, 64, 2, 8);
    let vocab = manifest.model.vocab_size;
    let engine = Engine::synthetic(manifest);
    let corpus = Corpus::new(vocab, 11, Quality::clean());
    let mut cfg = TrainConfig::from_spec(spec, label, MeshSpec::new(2, 3), 10_000);
    cfg.tau = 4;
    cfg.t_warm = if spec.warmup { 2 } else { 0 };
    cfg.eval_every_syncs = 0;
    cfg.shard_outer = shard_outer;
    Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100())).unwrap()
}

fn trainer(method: Method, shard_outer: bool) -> Trainer {
    trainer_spec(method.spec(), method.name(), shard_outer)
}

/// Measure two 6-round windows, taking the min: a genuine per-round
/// allocation shows up in both; one-off ambient noise (test harness
/// bookkeeping) cannot fail the assertion.
fn min_window_allocs(t: &mut Trainer) -> usize {
    let mut allocs = usize::MAX;
    for _attempt in 0..2 {
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..6 {
            t.run_round().unwrap();
        }
        allocs = allocs.min(ALLOCS.load(Ordering::SeqCst) - before);
    }
    allocs
}

#[test]
fn trainer_rounds_allocation_free_in_steady_state() {
    // Edit/AEdit run twice: the sharded outer path (default; shard
    // lanes + range-order folds) and the full-matrix reference. AEdit
    // additionally covers the event-driven anchor-sync path (scheduler
    // queue + group buffers are reused); Palsgd covers the
    // probabilistic trigger (stateless draws, partial windows).
    // DiLoCo: uniform averaging. Co2: staleness queue (recycled
    // buffers). Baseline: pure DDP.
    for (method, shard_outer) in [
        (Method::Edit, true),
        (Method::Edit, false),
        (Method::AEdit, true),
        (Method::AEdit, false),
        (Method::Palsgd, true),
        (Method::DiLoCo, false),
        (Method::Co2, false),
        (Method::Baseline, false),
    ] {
        let mut t = trainer(method, shard_outer);
        // Warm-up: fills scratch capacities, the CO2 queue and the
        // tail-mean windows.
        for _ in 0..4 {
            t.run_round().unwrap();
        }
        let allocs = min_window_allocs(&mut t);
        assert_eq!(
            allocs,
            0,
            "{} (shard_outer={}): {} heap allocations in 6 steady-state rounds",
            method.name(),
            shard_outer,
            allocs
        );
        // The rounds actually did work: losses recorded, syncs advanced.
        assert!(t.global_step > 0);
        if method.spec().is_local_sgd() {
            // Palsgd's probabilistic windows sync less often; the other
            // local methods sync every round.
            let min_syncs = if method == Method::Palsgd { 1 } else { 8 };
            assert!(
                t.syncs >= min_syncs,
                "{}: {} syncs",
                method.name(),
                t.syncs
            );
        }
    }

    // Compressed sync payload (`payload=int8`): the error-feedback
    // residual buffers live in the scratch arena and the
    // quantize→dequantize sweep runs in place, so steady-state rounds
    // must stay allocation-free on both sync layouts too.
    for shard_outer in [true, false] {
        let (spec, _) = MethodSpec::parse("custom:base=edit,payload=int8").unwrap();
        let mut t = trainer_spec(spec, "edit-int8", shard_outer);
        for _ in 0..4 {
            t.run_round().unwrap();
        }
        let allocs = min_window_allocs(&mut t);
        assert_eq!(
            allocs, 0,
            "edit payload=int8 (shard_outer={shard_outer}): {allocs} heap allocations in 6 steady-state rounds"
        );
        assert!(t.syncs >= 8, "edit payload=int8: {} syncs", t.syncs);
    }

    // Overlapped layer-wise sync (`overlap_sync`, default on): the
    // full-matrix path pipelines through two double-buffered
    // `ModuleLane`s and the sharded path interleaves the per-module
    // combine into the scalar sweep. The lanes are owned by
    // `SyncScratch` (`take_overlap_lanes`/`put_overlap_lanes`) and
    // their buffers are recycled with clear/extend/resize, so steady
    // state must stay allocation-free with the pipeline engaged — and
    // with it disabled (the blocking reference sweep kept as the
    // bitwise twin must not regress either).
    for shard_outer in [true, false] {
        for overlap in [true, false] {
            let (spec, _) = MethodSpec::parse("custom:base=edit").unwrap();
            let mut t = trainer_spec(spec, "edit-overlap", shard_outer);
            t.cfg.overlap_sync = overlap;
            for _ in 0..4 {
                t.run_round().unwrap();
            }
            let allocs = min_window_allocs(&mut t);
            assert_eq!(
                allocs, 0,
                "edit overlap_sync={overlap} (shard_outer={shard_outer}): {allocs} heap allocations in 6 steady-state rounds"
            );
            assert!(t.syncs >= 8, "edit overlap_sync={overlap}: {} syncs", t.syncs);
        }
    }
}
