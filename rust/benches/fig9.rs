//! Fig. 9 regeneration bench: sync-boundary timeline construction and
//! rendering for every method.

use edit_train::bench::Bencher;
use edit_train::coordinator::Method;
use edit_train::experiments::{throughput, ExpOpts};
use edit_train::simulator::trace::sync_timeline;

fn main() {
    let mut b = Bencher::new();
    println!("== fig9 ==");
    let opts = ExpOpts::default();
    b.once("fig9 all timelines", || throughput::fig9(&opts).unwrap());
    b.bench("build one timeline (EDiT)", || {
        std::hint::black_box(sync_timeline(Method::Edit).exposed);
    });
    b.write_csv("results/bench_fig9.csv").unwrap();
}
