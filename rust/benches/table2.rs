//! Table 2 regeneration bench: the full methods × scales simulation
//! grid (and its per-cell latency).

use edit_train::bench::Bencher;
use edit_train::coordinator::Method;
use edit_train::experiments::{throughput, ExpOpts};
use edit_train::simulator::{simulate, ScaleSpec, SimConfig};

fn main() {
    let mut b = Bencher::new();
    println!("== table2 ==");
    // The table itself (also writes results/table2.csv).
    let opts = ExpOpts::default();
    let (_, secs) = b.once("table2 full grid", || throughput::table2(&opts).unwrap());
    assert!(secs < 30.0);
    // Per-cell simulation latency.
    let cfg = SimConfig::table2(Method::Edit, ScaleSpec::by_name("7B").unwrap());
    b.bench("simulate one cell (EDiT 7B)", || {
        std::hint::black_box(simulate(&cfg).tokens_per_sec);
    });
    b.write_csv("results/bench_table2.csv").unwrap();
}
