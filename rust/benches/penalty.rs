//! Pseudo-gradient-penalty hot path (Alg. 2): screen + combine across
//! worker counts and parameter sizes — the per-sync cost of the
//! paper's contribution in pure Rust.

use edit_train::bench::Bencher;
use edit_train::coordinator::penalty::{combine, AnomalyDetector, PenaltyConfig};
use edit_train::tensor;

fn main() {
    let mut b = Bencher::new();
    println!("== penalty ==");
    for &n in &[1usize << 12, 1 << 16, 1 << 20] {
        for &w in &[2usize, 4, 8] {
            let deltas: Vec<Vec<f32>> = (0..w)
                .map(|j| (0..n).map(|i| ((i * (j + 1)) % 101) as f32 / 101.0 - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
            let norms: Vec<f64> = deltas.iter().map(|d| tensor::norm(d)).collect();
            let cfg = PenaltyConfig::default();
            b.bench(&format!("combine w={w} n={n}"), || {
                let out = combine(&refs, &norms, &cfg);
                std::hint::black_box(out.beta);
            });
            b.bench(&format!("norms   w={w} n={n}"), || {
                let s: f64 = deltas.iter().map(|d| tensor::sq_norm(d)).sum();
                std::hint::black_box(s);
            });
        }
    }
    let mut det = AnomalyDetector::new(8, 5, PenaltyConfig::default());
    let norms = vec![1.0f64; 8];
    b.bench("detector screen w=8 modules=5", || {
        for m in 0..5 {
            std::hint::black_box(det.screen(m, &norms));
        }
        det.advance();
    });
    b.write_csv("results/bench_penalty.csv").unwrap();
}
