//! Pseudo-gradient-penalty hot path (Alg. 2): screen + combine across
//! worker counts and parameter sizes — the per-sync cost of the
//! paper's contribution in pure Rust.
//!
//! Each combine size is measured twice: through the fused kernels
//! (`tensor::kernels::weighted_sum_sq_into`, one sweep) and through the
//! naive reference ops (`kernels::reference`, the historical multi-pass
//! shape: weighted sum, then norm, then clip scale). The GB/s column is
//! the *logical* traffic (w input rows + 1 output row), so the fused
//! path's higher number is real bandwidth saved, and the final ratio
//! line records the acceptance-criteria speedup on 2^20-element vectors.

use edit_train::bench::Bencher;
use edit_train::coordinator::penalty::{
    combine, softmax_neg_weights, AnomalyDetector, PenaltyConfig,
};
use edit_train::tensor::{self, kernels};

/// The historical multi-pass combine, expressed over the reference ops.
fn combine_reference(deltas: &[&[f32]], norms: &[f64], cfg: &PenaltyConfig) -> f64 {
    let weights = softmax_neg_weights(norms, cfg.weighted_averaging);
    let len = deltas[0].len();
    let mut out = vec![0.0f32; len];
    kernels::reference::weighted_sum_into(&mut out, deltas, &weights);
    let mut beta = 1.0;
    if cfg.gradient_clip {
        let norm = kernels::reference::sq_norm(&out).sqrt();
        beta = (cfg.phi / (norm + cfg.eps)).min(1.0);
        if beta < 1.0 {
            kernels::reference::scale(&mut out, beta as f32);
        }
    }
    beta
}

fn main() {
    let mut b = Bencher::new();
    println!("== penalty ==");
    let mut headline: (f64, f64) = (0.0, 0.0); // (reference, fused) seconds
    for &n in &[1usize << 12, 1 << 16, 1 << 20] {
        for &w in &[2usize, 4, 8] {
            let deltas: Vec<Vec<f32>> = (0..w)
                .map(|j| (0..n).map(|i| ((i * (j + 1)) % 101) as f32 / 101.0 - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
            let norms: Vec<f64> = deltas.iter().map(|d| tensor::norm(d)).collect();
            let cfg = PenaltyConfig::default();
            let bytes = ((w + 1) * n * 4) as u64;
            let fused = b.bench_gbs(&format!("combine pure rust (fused) w={w} n={n}"), bytes, || {
                let out = combine(&refs, &norms, &cfg);
                std::hint::black_box(out.beta);
            });
            let naive = b.bench_gbs(&format!("combine reference (naive) w={w} n={n}"), bytes, || {
                std::hint::black_box(combine_reference(&refs, &norms, &cfg));
            });
            if n == 1 << 20 && w == 4 {
                headline = (naive.median, fused.median);
            }
            b.bench_gbs(&format!("norms fused   w={w} n={n}"), (w * n * 4) as u64, || {
                let s: f64 = deltas.iter().map(|d| kernels::sq_norm(d)).sum();
                std::hint::black_box(s);
            });
            b.bench_gbs(&format!("norms reference w={w} n={n}"), (w * n * 4) as u64, || {
                let s: f64 = deltas.iter().map(|d| kernels::reference::sq_norm(d)).sum();
                std::hint::black_box(s);
            });
        }
    }
    if headline.1 > 0.0 {
        println!(
            "penalty combine speedup (fused vs naive, w=4 n=2^20): {:.2}x",
            headline.0 / headline.1
        );
    }
    let mut det = AnomalyDetector::new(8, 5, PenaltyConfig::default());
    let norms = vec![1.0f64; 8];
    let mut screened = Vec::with_capacity(8);
    b.bench("detector screen w=8 modules=5", || {
        for m in 0..5 {
            det.screen_into(m, &norms, &mut screened);
            std::hint::black_box(screened.len());
        }
        det.advance();
    });
    b.write_csv("results/bench_penalty.csv").unwrap();
}
