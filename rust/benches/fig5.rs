//! Fig. 5 / Table 6 regeneration bench: straggler + bandwidth scenario
//! sweep on the analytic simulator.

use edit_train::bench::Bencher;
use edit_train::coordinator::Method;
use edit_train::experiments::{throughput, ExpOpts};
use edit_train::simulator::{simulate, Scenario, SimConfig};

fn main() {
    let mut b = Bencher::new();
    println!("== fig5 ==");
    let opts = ExpOpts::default();
    b.once("fig5/table6 full sweep", || throughput::fig5(&opts).unwrap());
    b.bench("one scenario cell", || {
        let r = simulate(&SimConfig::fig5(
            Method::AEdit,
            Scenario::ConsistentStraggler { lag: 3.5 },
        ));
        std::hint::black_box(r.tflops_per_gpu);
    });
    b.write_csv("results/bench_fig5.csv").unwrap();
}
