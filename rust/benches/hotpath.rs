//! Hot-path micro benches over the REAL runtime: PJRT train/eval step
//! latency, literal marshalling, the penalty HLO, and one full EDiT
//! sync — the numbers the §Perf pass in EXPERIMENTS.md tracks.
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use edit_train::bench::Bencher;
use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{MeshSpec, Method, TrainConfig, Trainer};
use edit_train::data::{Corpus, Quality, Split};
use edit_train::runtime::Engine;
use edit_train::tensor;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("test/manifest.json").exists() {
        println!("hotpath: artifacts not built; skipping (run `make artifacts`)");
        return;
    }
    let mut b = Bencher::new();
    println!("== hotpath (test model) ==");

    let mut engine = Engine::load(artifacts, "test").unwrap();
    engine.warmup().unwrap();
    let mut params = engine.init_params().unwrap();
    let n = params.len();
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let corpus = Corpus::new(engine.manifest.model.vocab_size, 3, Quality::clean());
    let [bs, s1] = engine.manifest.token_shape;
    let tokens = corpus.batch_i32(Split::Train, 0, 0, bs, s1);

    let mut step = 0;
    b.bench("pjrt train_step (fused fwd+bwd+adamw)", || {
        step += 1;
        let out = engine
            .train_step(&mut params, &mut m, &mut v, &tokens, 1e-4, step)
            .unwrap();
        std::hint::black_box(out.loss);
    });
    b.bench("pjrt eval_step", || {
        std::hint::black_box(engine.eval_step(&params, &tokens).unwrap());
    });
    let mut grads = vec![0.0f32; n];
    b.bench("pjrt grad_step", || {
        std::hint::black_box(engine.grad_step(&params, &tokens, &mut grads).unwrap());
    });

    // Penalty through the AOT Pallas HLO vs pure Rust.
    let deltas: Vec<Vec<f32>> = (0..2)
        .map(|j| (0..n).map(|i| ((i + j) % 7) as f32 / 7.0 - 0.5).collect())
        .collect();
    let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
    let normsf: Vec<f32> = deltas.iter().map(|d| tensor::norm(d) as f32).collect();
    let norms64: Vec<f64> = normsf.iter().map(|&x| x as f64).collect();
    b.bench("penalty combine via HLO (w=2)", || {
        std::hint::black_box(engine.penalty_combine(&refs, &normsf).unwrap());
    });
    let cfg = edit_train::coordinator::PenaltyConfig::default();
    b.bench("penalty combine pure rust (w=2)", || {
        std::hint::black_box(edit_train::coordinator::penalty::combine(
            &refs, &norms64, &cfg,
        ));
    });

    // One full outer round (τ inner steps x 2 replicas + EDiT sync).
    let corpus2 = Corpus::new(engine.manifest.model.vocab_size, 5, Quality::clean());
    let mut tc = TrainConfig::paper_default(Method::Edit, MeshSpec::new(2, 2), u64::MAX);
    tc.tau = 4;
    tc.t_warm = 0;
    tc.eval_every_syncs = 0;
    let engine2 = Engine::load(artifacts, "test").unwrap();
    let mut trainer =
        Trainer::new(engine2, corpus2, tc, CostModel::new(Topology::a100())).unwrap();
    b.bench("edit outer round (tau=4, 2 replicas)", || {
        trainer.run_round().unwrap();
    });

    b.write_csv("results/bench_hotpath.csv").unwrap();
}
