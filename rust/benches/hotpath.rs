//! Hot-path micro benches — the numbers the §Perf pass tracks.
//!
//! Six sections, from kernel to full round:
//!  1. fused kernel GB/s vs the naive reference ops (always runs);
//!  2. one full EDiT sync round over a synthetic 1M-param module table:
//!     the fused `SyncScratch` pipeline vs the historical
//!     collect-then-scatter reference shape (always runs; this is the
//!     acceptance-criteria "edit outer round" speedup);
//!  3. pure-Rust penalty combine at module shape (always runs);
//!  4. the engine step path over built artifacts (PJRT with
//!     `--features pjrt`, the deterministic stub otherwise; skips
//!     without `make artifacts`);
//!  5. blocking vs overlapped layer-wise driver rounds over a modeled
//!     1 ms link — the measured exposed-sync fraction, cross-validated
//!     against `StepModel::layerwise_exposed_ops` (always runs);
//!  6. full `Trainer::run_round` EDiT rounds on the synthetic stub
//!     engine (default build only — no artifacts needed).

use edit_train::bench::Bencher;
use edit_train::coordinator::penalty::{softmax_neg_weights, PenaltyConfig};
use edit_train::coordinator::{OuterOpt, OuterOptKind, SyncScratch};
use edit_train::runtime::Manifest;
use edit_train::tensor::{self, kernels, ModuleTable, PayloadKind};

fn kernel_benches(b: &mut Bencher) {
    println!("-- fused kernels (n=2^20) --");
    let n = 1usize << 20;
    let x: Vec<f32> = (0..n).map(|i| (i % 97) as f32 / 97.0 - 0.5).collect();
    let a: Vec<f32> = (0..n).map(|i| (i % 89) as f32 / 89.0 - 0.5).collect();
    let mut y = vec![0.0f32; n];
    let rw = (2 * n * 4) as u64; // read + write one vector
    let rr = (2 * n * 4) as u64; // read two vectors
    b.bench_gbs("kernel axpy fused", rw + (n * 4) as u64, || {
        kernels::axpy(&mut y, 1.0001, &x);
        std::hint::black_box(y[0]);
    });
    b.bench_gbs("kernel axpy reference", rw + (n * 4) as u64, || {
        kernels::reference::axpy(&mut y, 1.0001, &x);
        std::hint::black_box(y[0]);
    });
    b.bench_gbs("kernel sq_norm fused", (n * 4) as u64, || {
        std::hint::black_box(kernels::sq_norm(&x));
    });
    b.bench_gbs("kernel sq_norm reference", (n * 4) as u64, || {
        std::hint::black_box(kernels::reference::sq_norm(&x));
    });
    b.bench_gbs("kernel sub+norm fused (one pass)", rr + (n * 4) as u64, || {
        std::hint::black_box(kernels::sub_sq_norm_into(&mut y, &a, &x));
    });
    b.bench_gbs("kernel sub+norm reference (two pass)", rr + (n * 4) as u64, || {
        kernels::reference::sub(&mut y, &a, &x);
        std::hint::black_box(kernels::reference::sq_norm(&y));
    });
    // Compressed-payload kernel: error-feedback int8 quantize→dequantize
    // in one pass over the pseudo-gradient. Traffic: refresh y from x,
    // then read+write y and the residual — four vector touches.
    let mut residual = vec![0.0f32; n];
    let qb = (4 * n * 4) as u64;
    b.bench_gbs("kernel quant int8 ef fused", qb, || {
        y.copy_from_slice(&x);
        kernels::quant_dequant_ef(PayloadKind::Int8, &mut y, &mut residual);
        std::hint::black_box(y[0]);
    });
    residual.fill(0.0);
    b.bench_gbs("kernel quant int8 ef reference", qb, || {
        y.copy_from_slice(&x);
        kernels::reference::quant_dequant_ef(PayloadKind::Int8, &mut y, &mut residual);
        std::hint::black_box(y[0]);
    });
}

/// Synthetic module table at paper-like shape: 8 stacked layers of 128K
/// elements + 16K unstacked tail = ~1.06M params (≥ 2^20).
fn bench_table() -> ModuleTable {
    Manifest::synthetic("hotpath-bench", 8, 1 << 17, 1 << 14, 256, 2, 16).table
}

fn sync_round_benches(b: &mut Bencher) -> (f64, f64) {
    println!("-- edit outer round: fused scratch vs naive reference --");
    let table = bench_table();
    let p = table.total;
    let replicas = 4usize;
    let cfg = PenaltyConfig::default();
    let params: Vec<Vec<f32>> = (0..replicas)
        .map(|j| (0..p).map(|i| ((i * (j + 3)) % 211) as f32 / 211.0 - 0.5).collect())
        .collect();
    let anchor0: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0 - 0.5).collect();
    // Per-round traffic: read every replica row + anchor, write combine.
    let bytes = ((replicas + 2) * p * 4) as u64;

    // --- fused scratch pipeline (what Trainer::synchronize runs) -------
    let mut scratch = SyncScratch::new(&table, replicas, 0);
    let mut outer_f = OuterOpt::new(OuterOptKind::paper_nesterov(), p);
    let mut anchor_f = anchor0.clone();
    let fused = b.bench_gbs(
        &format!("edit outer round fused ({replicas} replicas, {p} params)"),
        bytes,
        || {
            for m in 0..table.num_modules() {
                scratch.load_module(m, |j| params[j].as_slice(), &anchor_f);
                scratch.adopt_norms_unscreened();
                if !scratch.compute_weights(true) {
                    continue;
                }
                let sq = scratch.combine_module(m);
                let beta = (cfg.phi / (sq.sqrt() + cfg.eps)).min(1.0);
                scratch.apply_module(m, &mut outer_f, &mut anchor_f, beta as f32);
            }
            std::hint::black_box(anchor_f[0]);
        },
    );

    // --- historical reference: multi-pass + collect-then-scatter -------
    let mut deltas: Vec<Vec<f32>> = vec![vec![0.0; p]; replicas]; // reused, as the old trainer did
    let mut outer_r = OuterOpt::new(OuterOptKind::paper_nesterov(), p);
    let mut anchor_r = anchor0.clone();
    let naive = b.bench_gbs(
        &format!("edit outer round reference ({replicas} replicas, {p} params)"),
        bytes,
        || {
            for (j, d) in deltas.iter_mut().enumerate() {
                kernels::reference::sub(d, &params[j], &anchor_r);
            }
            for m in 0..table.num_modules() {
                let ranges = table.module_ranges(m);
                let norms: Vec<f64> = (0..replicas)
                    .map(|j| table.module_sq_norm(&deltas[j], m).sqrt())
                    .collect();
                let weights = softmax_neg_weights(&norms, true);
                if weights.iter().all(|&w| w == 0.0) {
                    continue;
                }
                let mut module_sq = 0.0f64;
                let mut combined: Vec<(usize, Vec<f32>)> = Vec::with_capacity(ranges.len());
                for r in &ranges {
                    let mut out = vec![0.0f32; r.len];
                    let rows: Vec<&[f32]> = deltas
                        .iter()
                        .map(|d| &d[r.offset..r.offset + r.len])
                        .collect();
                    kernels::reference::weighted_sum_into(&mut out, &rows, &weights);
                    module_sq += kernels::reference::sq_norm(&out);
                    combined.push((r.offset, out));
                }
                let beta = (cfg.phi / (module_sq.sqrt() + cfg.eps)).min(1.0);
                for (off, mut delta) in combined {
                    if beta < 1.0 {
                        kernels::reference::scale(&mut delta, beta as f32);
                    }
                    outer_r.apply_range(&mut anchor_r, &delta, off);
                }
            }
            std::hint::black_box(anchor_r[0]);
        },
    );
    println!(
        "edit outer round speedup (fused vs naive reference): {:.2}x",
        naive.median / fused.median
    );
    (fused.median, naive.median)
}

fn engine_benches(b: &mut Bencher) {
    use edit_train::data::{Corpus, Quality, Split};
    use edit_train::runtime::Engine;

    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("test/manifest.json").exists() {
        println!("engine section: artifacts not built; skipping (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::load(artifacts, "test").unwrap();
    engine.warmup().unwrap();
    println!("-- engine steps on '{}' --", engine.platform());
    let mut params = engine.init_params().unwrap();
    let n = params.len();
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let corpus = Corpus::new(engine.manifest.model.vocab_size, 3, Quality::clean());
    let [bs, s1] = engine.manifest.token_shape;
    let tokens = corpus.batch_i32(Split::Train, 0, 0, bs, s1);

    let mut step = 0;
    b.bench("engine train_step (fused fwd+bwd+adamw)", || {
        step += 1;
        let out = engine
            .train_step(&mut params, &mut m, &mut v, &tokens, 1e-4, step)
            .unwrap();
        std::hint::black_box(out.loss);
    });
    b.bench("engine eval_step", || {
        std::hint::black_box(engine.eval_step(&params, &tokens).unwrap());
    });
    let mut grads = vec![0.0f32; n];
    b.bench("engine grad_step", || {
        std::hint::black_box(engine.grad_step(&params, &tokens, &mut grads).unwrap());
    });

    // Penalty through the AOT Pallas HLO vs pure Rust (PJRT builds only).
    let deltas: Vec<Vec<f32>> = (0..2)
        .map(|j| (0..n).map(|i| ((i + j) % 7) as f32 / 7.0 - 0.5).collect())
        .collect();
    let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
    let normsf: Vec<f32> = deltas.iter().map(|d| tensor::norm(d) as f32).collect();
    let norms64: Vec<f64> = normsf.iter().map(|&x| x as f64).collect();
    if engine.has_penalty_program(refs.len()) {
        b.bench("penalty combine via HLO (w=2)", || {
            std::hint::black_box(engine.penalty_combine(&refs, &normsf).unwrap());
        });
    } else {
        println!("penalty HLO unavailable on this backend; skipping");
    }
    let cfg = PenaltyConfig::default();
    b.bench("penalty combine pure rust (w=2)", || {
        std::hint::black_box(edit_train::coordinator::penalty::combine(
            &refs, &norms64, &cfg,
        ));
    });
}

/// Pure-Rust penalty combine at module shape — always runs (the HLO
/// variant in `engine_benches` needs built artifacts), so the penalty
/// row lands in the gated summary on every CI run.
fn penalty_benches(b: &mut Bencher) {
    use edit_train::coordinator::penalty;

    println!("-- penalty combine (pure rust, module shape) --");
    let p = 1usize << 17;
    let w = 4usize;
    let deltas: Vec<Vec<f32>> = (0..w)
        .map(|j| (0..p).map(|i| ((i * (j + 2)) % 191) as f32 / 191.0 - 0.5).collect())
        .collect();
    let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
    let norms: Vec<f64> = deltas.iter().map(|d| tensor::norm(d)).collect();
    let cfg = PenaltyConfig::default();
    // Traffic: read every replica row once, write the combined module.
    let bytes = ((w + 1) * p * 4) as u64;
    b.bench_gbs(&format!("penalty combine pure rust (w={w}, p={p})"), bytes, || {
        std::hint::black_box(penalty::combine(&refs, &norms, &cfg));
    });
}

/// Run a `world`-rank driver group on OS threads over a latency-shaped
/// in-process link (`ThreadComm::group_with_link_delay`): every data
/// collective sleeps `link` before completing, so the blocking schedule
/// pays it inline while the overlapped schedule hides it behind the
/// next module's inner steps.
fn run_driver_group(
    world: usize,
    link: std::time::Duration,
    cfg: &edit_train::collectives::driver::DriverConfig,
) -> Vec<edit_train::collectives::driver::DriverOutcome> {
    use edit_train::collectives::driver::run_worker;
    use edit_train::collectives::ThreadComm;

    let comms = ThreadComm::group_with_link_delay(world, link);
    let mut out = Vec::with_capacity(world);
    std::thread::scope(|s| {
        let handles: Vec<_> =
            comms.iter().map(|c| s.spawn(move || run_worker(c, cfg))).collect();
        for h in handles {
            out.push(
                h.join()
                    .expect("driver bench worker panicked")
                    .expect("driver bench round failed"),
            );
        }
    });
    out
}

/// Aggregate exposed-sync fraction across ranks: total time blocked in
/// collective calls over total wall clock.
fn exposed_fraction(outs: &[edit_train::collectives::driver::DriverOutcome]) -> f64 {
    let wait: f64 = outs.iter().map(|o| o.sync_wait.as_secs_f64()).sum();
    let elapsed: f64 = outs.iter().map(|o| o.elapsed.as_secs_f64()).sum();
    wait / elapsed.max(f64::MIN_POSITIVE)
}

/// Blocking vs overlapped layer-wise EDiT rounds end to end, on the
/// multi-module driver over a 1 ms modeled link. Three runs of the
/// identical workload: world=1 (collectives are local no-ops — isolates
/// the compute term), world=2 blocking, world=2 overlapped. The
/// digests of the blocking and overlapped runs must match bitwise; the
/// wall-clock gap is the measured overlap win, and the measured
/// exposed-sync fraction is cross-validated against the same
/// `StepModel::layerwise_exposed_ops` pipeline-stall model the trainer's
/// `CommPlan` prices (`exposed_sync_fraction.model_agreement`).
fn driver_overlap_benches(b: &mut Bencher) -> edit_train::util::json::Obj {
    use edit_train::collectives::driver::{DriverConfig, DriverPayload};
    use edit_train::collectives::{CostModel, Topology};
    use edit_train::coordinator::MeshSpec;
    use edit_train::simulator::stepmodel::StepModel;
    use edit_train::tensor::ShardSpec;
    use edit_train::util::json::Obj;
    use std::time::Duration;

    println!("-- layer-wise driver rounds: blocking vs overlapped (1ms modeled link) --");
    let world = 2usize;
    let link = Duration::from_millis(1);
    let cfg = DriverConfig {
        params: 1 << 18,
        rounds: 4,
        inner_steps: 12,
        modules: 4,
        payload: DriverPayload::F32,
        overlap: false,
        ..Default::default()
    };
    let rounds = cfg.rounds as f64;

    let (solo, _) = b.once("driver rounds x4 modules, world=1 (compute only)", || {
        run_driver_group(1, Duration::ZERO, &cfg)
    });
    let compute_round = solo[0].elapsed.as_secs_f64() / rounds;

    let (blocking, _) = b.once("driver rounds x4 modules blocking (2 ranks, 1ms link)", || {
        run_driver_group(world, link, &cfg)
    });
    let over_cfg = DriverConfig { overlap: true, ..cfg.clone() };
    let (overlapped, _) =
        b.once("driver rounds x4 modules overlapped (2 ranks, 1ms link)", || {
            run_driver_group(world, link, &over_cfg)
        });

    // The whole point: the overlapped schedule is a reordering, not a
    // different computation.
    assert_eq!(
        blocking[0].digest, overlapped[0].digest,
        "overlapped driver schedule diverged from blocking"
    );
    for o in blocking.iter().chain(&overlapped) {
        assert_eq!(o.digest, blocking[0].digest, "ranks disagree");
    }

    let round_max = |outs: &[edit_train::collectives::driver::DriverOutcome]| {
        outs.iter().map(|o| o.elapsed.as_secs_f64()).fold(0.0f64, f64::max) / rounds
    };
    let (blk_s, ovl_s) = (round_max(&blocking), round_max(&overlapped));
    let (blk_frac, ovl_frac) = (exposed_fraction(&blocking), exposed_fraction(&overlapped));

    // Analytic mirror of the bench link: pure latency (sleep `link` per
    // data op, bytes effectively free), one shard lane per rank, the
    // measured world=1 round as the hideable compute term.
    let mspec = ShardSpec::new(cfg.params, cfg.modules);
    let module_bytes: Vec<usize> =
        (0..cfg.modules).map(|m| mspec.range(m).1 * 4).collect();
    let model = StepModel {
        mesh: MeshSpec::new(1, world),
        cost: CostModel::new(Topology::flat(
            1e15,
            link.as_secs_f64() / (world as f64 - 1.0),
        )),
        param_bytes: cfg.params * 4,
        compute: compute_round,
        cpu_offload: false,
    };
    let analytic_exposed = model.layerwise_exposed_ops(&module_bytes, true);
    let analytic_frac = analytic_exposed / (analytic_exposed + compute_round);
    let speedup = blk_s / ovl_s.max(f64::MIN_POSITIVE);
    let agreement = ovl_frac / analytic_frac.max(f64::MIN_POSITIVE);
    println!(
        "exposed sync fraction: blocking {blk_frac:.3}, overlapped {ovl_frac:.3}, \
         analytic {analytic_frac:.3} (agreement {agreement:.2}); round speedup {speedup:.2}x"
    );

    let mut o = Obj::new();
    o.insert("blocking", blk_frac);
    o.insert("overlapped", ovl_frac);
    o.insert("hidden_fraction", 1.0 - ovl_frac / blk_frac.max(f64::MIN_POSITIVE));
    o.insert("analytic_exposed_fraction", analytic_frac);
    o.insert("model_agreement", agreement);
    o.insert("overlap_speedup", speedup);
    o.insert("blocking_round_s", blk_s);
    o.insert("overlapped_round_s", ovl_s);
    o.insert("compute_round_s", compute_round);
    o
}

/// Full EDiT rounds (τ inner steps × replicas + fused sync) through the
/// Trainer on the synthetic stub engine — no artifacts required.
#[cfg(not(feature = "pjrt"))]
fn trainer_round_benches(b: &mut Bencher) {
    use edit_train::collectives::{CostModel, Topology};
    use edit_train::coordinator::{MeshSpec, Method, TrainConfig, Trainer};
    use edit_train::data::{Corpus, Quality};
    use edit_train::runtime::Engine;

    println!("-- full trainer rounds (stub engine) --");
    let vocab = 256usize;
    // Three configurations of the same round: the sharded outer path
    // (default; ZeRO-1 lanes), the sharded path with the lane fan-out on
    // 2 worker threads, and the full-matrix reference — all bitwise
    // identical in results, compared here on wall-clock.
    for (label, shard, threads) in [
        ("edit round e2e sharded (tau=4, 2 replicas)", true, 1usize),
        ("edit round e2e sharded, 2 threads", true, 2),
        ("edit round e2e unsharded reference", false, 1),
    ] {
        let engine = Engine::synthetic(Manifest::synthetic(
            "hotpath-round",
            4,
            1 << 14,
            1 << 13,
            256,
            2,
            16,
        ));
        let corpus = Corpus::new(vocab, 5, Quality::clean());
        let mut tc = TrainConfig::paper_default(Method::Edit, MeshSpec::new(2, 2), u64::MAX);
        tc.tau = 4;
        tc.t_warm = 0;
        tc.eval_every_syncs = 0;
        tc.shard_outer = shard;
        tc.worker_threads = threads;
        let mut trainer =
            Trainer::new(engine, corpus, tc, CostModel::new(Topology::a100())).unwrap();
        b.bench(label, || {
            trainer.run_round().unwrap();
        });
    }
}

/// Bytes-on-wire per sync round, measured from the trainer's own comm
/// accounting (`Trainer::comm`): two identical EDiT runs on the stub
/// engine, differing only in the payload axis, each driven for two
/// rounds. Deterministic — the per-round byte charge is a function of
/// the comm plan, not of wall clock — so the reduction ratio is exact
/// and CI-gateable.
#[cfg(not(feature = "pjrt"))]
fn sync_bytes_benches() -> Option<(f64, f64)> {
    use edit_train::collectives::{CostModel, Topology};
    use edit_train::coordinator::{MeshSpec, MethodSpec, TrainConfig, Trainer};
    use edit_train::data::{Corpus, Quality};
    use edit_train::runtime::Engine;

    println!("-- sync bytes on wire (per round, trainer comm accounting) --");
    let rounds = 2u64;
    let mut per_round = [0.0f64; 2];
    for (slot, spec_str) in [(0usize, "custom:base=edit"), (1, "custom:base=edit,payload=int8")] {
        let engine = Engine::synthetic(Manifest::synthetic(
            "hotpath-wire",
            4,
            1 << 14,
            1 << 13,
            256,
            2,
            16,
        ));
        let corpus = Corpus::new(256, 5, Quality::clean());
        let (spec, _) = MethodSpec::parse(spec_str).unwrap();
        let mut tc = TrainConfig::from_spec(spec, spec_str, MeshSpec::new(2, 2), u64::MAX);
        tc.tau = 1;
        tc.t_warm = 0;
        tc.eval_every_syncs = 0;
        let mut trainer =
            Trainer::new(engine, corpus, tc, CostModel::new(Topology::a100())).unwrap();
        for _ in 0..rounds {
            trainer.run_round().unwrap();
        }
        per_round[slot] = trainer.comm.bytes as f64 / rounds as f64;
    }
    let (f32_b, int8_b) = (per_round[0], per_round[1]);
    println!(
        "sync bytes/round: f32 {:.0} B, int8 {:.0} B  ({:.2}x reduction)",
        f32_b,
        int8_b,
        f32_b / int8_b
    );
    Some((f32_b, int8_b))
}

#[cfg(feature = "pjrt")]
fn sync_bytes_benches() -> Option<(f64, f64)> {
    println!("sync bytes section: stub-engine only; skipping under pjrt");
    None
}

/// Machine-readable perf snapshot (`results/bench_summary.json`): the
/// kernel-layer GB/s, the fused-vs-naive outer-round speedup, the
/// end-to-end trainer round times, and the compressed-payload
/// bytes-on-wire reduction. The CI full leg uploads it as a build
/// artifact and diffs it against `BENCH_BASELINE.json` (see
/// `examples/bench_gate.rs`) so the perf trajectory is tracked — and
/// gated — across PRs.
fn write_summary_json(
    b: &Bencher,
    fused_s: f64,
    naive_s: f64,
    wire: Option<(f64, f64)>,
    overlap: edit_train::util::json::Obj,
) -> anyhow::Result<()> {
    use edit_train::util::json::{Json, Obj};
    let mut kernels = Obj::new();
    let mut rounds = Obj::new();
    let mut penalty = Obj::new();
    for s in b.results() {
        if s.name.starts_with("kernel ") {
            if let Some(gbs) = s.gb_per_s() {
                kernels.insert(s.name.clone(), gbs);
            }
        }
        if s.name.starts_with("penalty ") {
            if let Some(gbs) = s.gb_per_s() {
                penalty.insert(s.name.clone(), gbs);
            }
        }
        if s.name.starts_with("edit round e2e") {
            rounds.insert(s.name.clone(), s.median);
        }
    }
    let mut outer = Obj::new();
    outer.insert("fused_median_s", fused_s);
    outer.insert("reference_median_s", naive_s);
    outer.insert("speedup", naive_s / fused_s);
    let mut root = Obj::new();
    root.insert("schema", 2i64);
    root.insert("bench", "hotpath");
    root.insert("fast_mode", std::env::var("EDIT_BENCH_FAST").is_ok());
    root.insert("kernel_gb_per_s", kernels);
    root.insert("penalty_gb_per_s", penalty);
    root.insert("edit_outer_round", outer);
    root.insert("e2e_round_seconds", rounds);
    root.insert("exposed_sync_fraction", overlap);
    if let Some((f32_b, int8_b)) = wire {
        let mut w = Obj::new();
        w.insert("f32_bytes_per_round", f32_b);
        w.insert("int8_bytes_per_round", int8_b);
        w.insert("reduction", f32_b / int8_b);
        root.insert("sync_bytes_on_wire", w);
    }
    std::fs::write("results/bench_summary.json", Json::Obj(root).to_string_pretty())?;
    println!("summary -> results/bench_summary.json");
    Ok(())
}

fn main() {
    std::fs::create_dir_all("results").ok();
    let mut b = Bencher::new();
    println!("== hotpath ==");
    kernel_benches(&mut b);
    let (fused_s, naive_s) = sync_round_benches(&mut b);
    penalty_benches(&mut b);
    engine_benches(&mut b);
    let overlap = driver_overlap_benches(&mut b);
    #[cfg(not(feature = "pjrt"))]
    trainer_round_benches(&mut b);
    let wire = sync_bytes_benches();
    b.write_csv("results/bench_hotpath.csv").unwrap();
    write_summary_json(&b, fused_s, naive_s, wire, overlap).unwrap();
}
