//! Collective-substrate micro benches: sequential reference vs the
//! striped threaded rendezvous across sizes (the L3 hot-loop
//! primitives), plus the nonblocking issue/compute pipeline over a
//! modeled link. GB/s is the logical payload (n ranks × len × 4 bytes).
//!
//! Writes `results/bench_collectives.json` with the pipelined-vs-
//! blocking round medians; the CI bench gate diffs it alongside the
//! hotpath summary (see `examples/bench_gate.rs`).

use edit_train::bench::{Bencher, Stats};
use edit_train::collectives::{group, Collective, ThreadComm};
use edit_train::tensor::{kernels, ShardSpec};
use std::time::Duration;

/// Blocking vs pipelined module sweep over a latency-shaped link: each
/// of `modules` iterations pays one reduce-scatter plus one compute
/// chunk. The blocking schedule runs them back to back; the pipelined
/// one issues the collective through the nonblocking window and runs
/// the compute chunk before waiting, so the modeled 500 µs wire latency
/// hides behind it.
fn pipelined_benches(b: &mut Bencher) -> (Stats, Stats, Stats) {
    let n = 2usize;
    let modules = 6usize;
    let len = 1usize << 14;
    let link = Duration::from_micros(500);
    let timeout = Duration::from_secs(10);
    let bytes = (n * modules * len * 4) as u64;
    let spec = ShardSpec::new(len, n);
    let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
    // Compute chunk comparable to the link latency (memory-bound sweep).
    let x: Vec<f32> = (0..(1usize << 17)).map(|i| (i % 13) as f32).collect();

    let blocking = b.bench_gbs(
        &format!("pipelined rs blocking  n={n} m={modules} (500µs link)"),
        bytes,
        || {
            let comms = ThreadComm::group_with_link_delay(n, link);
            std::thread::scope(|s| {
                for c in comms {
                    let (sh, xs) = (&shards, &x);
                    s.spawn(move || {
                        let mut acc = 0.0f64;
                        for _ in 0..modules {
                            let mut buf = vec![c.rank() as f32; len];
                            c.try_reduce_scatter_mean(&mut buf, sh, timeout).unwrap();
                            acc += kernels::sq_norm(xs);
                        }
                        std::hint::black_box(acc);
                    });
                }
            });
        },
    );
    let run_overlapped = |b: &mut Bencher, name: String, q8: bool| {
        b.bench_gbs(&name, bytes, || {
            let comms = ThreadComm::group_with_link_delay(n, link);
            std::thread::scope(|s| {
                for c in comms {
                    let (sh, xs) = (&shards, &x);
                    s.spawn(move || {
                        let mut acc = 0.0f64;
                        let mut pending = None;
                        for _ in 0..modules {
                            let buf = vec![c.rank() as f32; len];
                            let h = if q8 {
                                c.start_reduce_scatter_mean_q8(buf, sh, timeout)
                            } else {
                                c.start_reduce_scatter_mean(buf, sh, timeout)
                            };
                            acc += kernels::sq_norm(xs);
                            if let Some(p) = pending.take() {
                                std::hint::black_box(c.wait_handle(p).unwrap());
                            }
                            pending = Some(h);
                        }
                        if let Some(p) = pending.take() {
                            std::hint::black_box(c.wait_handle(p).unwrap());
                        }
                        std::hint::black_box(acc);
                    });
                }
            });
        })
    };
    let overlapped = run_overlapped(
        b,
        format!("pipelined rs overlapped n={n} m={modules} (500µs link)"),
        false,
    );
    let overlapped_q8 = run_overlapped(
        b,
        format!("pipelined rs overlapped q8 n={n} m={modules} (500µs link)"),
        true,
    );
    println!(
        "pipelined round speedup (overlapped vs blocking): {:.2}x",
        blocking.median / overlapped.median
    );
    (blocking, overlapped, overlapped_q8)
}

fn write_summary_json(blocking: &Stats, overlapped: &Stats, q8: &Stats) -> anyhow::Result<()> {
    use edit_train::util::json::{Json, Obj};
    let mut p = Obj::new();
    p.insert("blocking_median_s", blocking.median);
    p.insert("overlapped_median_s", overlapped.median);
    p.insert("overlapped_q8_median_s", q8.median);
    p.insert("speedup", blocking.median / overlapped.median);
    let mut root = Obj::new();
    root.insert("schema", 1i64);
    root.insert("bench", "collectives");
    root.insert("fast_mode", std::env::var("EDIT_BENCH_FAST").is_ok());
    root.insert("pipelined_reduce_scatter", p);
    std::fs::write(
        "results/bench_collectives.json",
        Json::Obj(root).to_string_pretty(),
    )?;
    println!("summary -> results/bench_collectives.json");
    Ok(())
}

fn main() {
    std::fs::create_dir_all("results").ok();
    let mut b = Bencher::new();
    println!("== collectives ==");
    for &len in &[1usize << 10, 1 << 14, 1 << 18] {
        for &n in &[2usize, 4, 8] {
            let bytes = (n * len * 4) as u64;
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|r| vec![r as f32; len]).collect();
            b.bench_gbs(&format!("seq all_reduce_mean n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::all_reduce_mean(&mut refs);
            });
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            b.bench_gbs(&format!("seq reduce_scatter  n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::reduce_scatter_mean(&mut refs, &shards);
            });
            // Compressed payload lane (payload=int8): GB/s is still the
            // logical f32 payload so the row is comparable to the
            // uncompressed one — the wire moves ~3.8x fewer bytes.
            b.bench_gbs(&format!("seq reduce_scatter q8 n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::reduce_scatter_mean_q8(&mut refs, &shards);
            });
        }
    }
    // Striped threaded rendezvous round-trip (thread spawn included —
    // the interesting trend is across len at fixed n).
    for &len in &[1usize << 14, 1 << 18] {
        let n = 4;
        let bytes = (n * len * 4) as u64;
        b.bench_gbs(&format!("striped threaded all_reduce n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.all_reduce_mean(&mut buf);
                    });
                }
            });
        });
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        b.bench_gbs(&format!("striped threaded reduce_scatter n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            let sh = &shards;
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.reduce_scatter_mean(&mut buf, sh);
                    });
                }
            });
        });
        b.bench_gbs(&format!("striped threaded reduce_scatter q8 n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            let sh = &shards;
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.reduce_scatter_mean_q8(&mut buf, sh);
                    });
                }
            });
        });
    }
    let (blocking, overlapped, q8) = pipelined_benches(&mut b);
    b.write_csv("results/bench_collectives.csv").unwrap();
    write_summary_json(&blocking, &overlapped, &q8).unwrap();
}
