//! Collective-substrate micro benches: sequential reference vs the
//! striped threaded rendezvous across sizes (the L3 hot-loop
//! primitives). GB/s is the logical payload (n ranks × len × 4 bytes).

use edit_train::bench::Bencher;
use edit_train::collectives::{group, ThreadComm};
use edit_train::tensor::ShardSpec;

fn main() {
    let mut b = Bencher::new();
    println!("== collectives ==");
    for &len in &[1usize << 10, 1 << 14, 1 << 18] {
        for &n in &[2usize, 4, 8] {
            let bytes = (n * len * 4) as u64;
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|r| vec![r as f32; len]).collect();
            b.bench_gbs(&format!("seq all_reduce_mean n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::all_reduce_mean(&mut refs);
            });
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            b.bench_gbs(&format!("seq reduce_scatter  n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::reduce_scatter_mean(&mut refs, &shards);
            });
            // Compressed payload lane (payload=int8): GB/s is still the
            // logical f32 payload so the row is comparable to the
            // uncompressed one — the wire moves ~3.8x fewer bytes.
            b.bench_gbs(&format!("seq reduce_scatter q8 n={n} len={len}"), bytes, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::reduce_scatter_mean_q8(&mut refs, &shards);
            });
        }
    }
    // Striped threaded rendezvous round-trip (thread spawn included —
    // the interesting trend is across len at fixed n).
    for &len in &[1usize << 14, 1 << 18] {
        let n = 4;
        let bytes = (n * len * 4) as u64;
        b.bench_gbs(&format!("striped threaded all_reduce n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.all_reduce_mean(&mut buf);
                    });
                }
            });
        });
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        b.bench_gbs(&format!("striped threaded reduce_scatter n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            let sh = &shards;
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.reduce_scatter_mean(&mut buf, sh);
                    });
                }
            });
        });
        b.bench_gbs(&format!("striped threaded reduce_scatter q8 n={n} len={len}"), bytes, || {
            let comms = ThreadComm::group(n);
            let sh = &shards;
            std::thread::scope(|s| {
                for c in comms {
                    s.spawn(move || {
                        let mut buf = vec![c.rank() as f32; len];
                        c.reduce_scatter_mean_q8(&mut buf, sh);
                    });
                }
            });
        });
    }
    b.write_csv("results/bench_collectives.csv").unwrap();
}
