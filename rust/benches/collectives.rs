//! Collective-substrate micro benches: sequential reference vs threaded
//! rendezvous across sizes (the L3 hot-loop primitives).

use edit_train::bench::Bencher;
use edit_train::collectives::{group, ThreadComm};
use edit_train::tensor::ShardSpec;

fn main() {
    let mut b = Bencher::new();
    println!("== collectives ==");
    for &len in &[1usize << 10, 1 << 14, 1 << 18] {
        for &n in &[2usize, 4, 8] {
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|r| vec![r as f32; len]).collect();
            b.bench(&format!("seq all_reduce_mean n={n} len={len}"), || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::all_reduce_mean(&mut refs);
            });
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            b.bench(&format!("seq reduce_scatter  n={n} len={len}"), || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                group::reduce_scatter_mean(&mut refs, &shards);
            });
        }
    }
    // Threaded rendezvous round-trip (4 ranks, mid size).
    let n = 4;
    let len = 1 << 14;
    b.bench(&format!("threaded all_reduce  n={n} len={len}"), || {
        let comms = ThreadComm::group(n);
        std::thread::scope(|s| {
            for c in comms {
                s.spawn(move || {
                    let mut buf = vec![c.rank() as f32; len];
                    c.all_reduce_mean(&mut buf);
                });
            }
        });
    });
    b.write_csv("results/bench_collectives.csv").unwrap();
}
