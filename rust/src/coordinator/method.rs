//! The method zoo: EDiT, A-EDiT, and every baseline the paper
//! evaluates (Table 2 / Fig. 4).  All methods run on the same local-SGD
//! engine; this enum captures where they differ (DESIGN.md §4).

use super::outer::OuterOptKind;
use super::penalty::PenaltyConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Standard synchronous mini-batch DDP ("Baseline").
    Baseline,
    /// Lin et al. 2019: DDP warmup, then plain parameter averaging.
    PostLocalSgd,
    /// Douillard et al. 2023: pseudo-gradient averaging + Nesterov outer.
    DiLoCo,
    /// Sun et al. 2023: DiLoCo numerics with staleness-1 outer update
    /// (communication hidden behind the next round); FULL outer state
    /// per worker.
    Co2,
    /// Memory-efficient CO2: sharded outer state, extra non-overlapped
    /// communication (identical numerics to CO2).
    Co2Star,
    /// This paper: layer-wise sync + pseudo-gradient penalty + sharded
    /// outer state.
    Edit,
    /// Asynchronous EDiT: time-based sync interval (§3.3).
    AEdit,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Baseline,
        Method::PostLocalSgd,
        Method::DiLoCo,
        Method::Co2,
        Method::Co2Star,
        Method::Edit,
        Method::AEdit,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::PostLocalSgd => "post-local-sgd",
            Method::DiLoCo => "diloco",
            Method::Co2 => "co2",
            Method::Co2Star => "co2*",
            Method::Edit => "edit",
            Method::AEdit => "a-edit",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        let s = s.to_ascii_lowercase();
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s || m.name().replace('-', "_") == s)
            .or(match s.as_str() {
                "pls" => Some(Method::PostLocalSgd),
                "co2star" | "co2s" => Some(Method::Co2Star),
                "aedit" => Some(Method::AEdit),
                _ => None,
            })
    }

    /// Does this method run periodic (local-SGD) synchronization at all?
    pub fn is_local_sgd(&self) -> bool {
        !matches!(self, Method::Baseline)
    }

    /// Time-based (rather than step-based) sync trigger (§3.3).
    pub fn time_based_sync(&self) -> bool {
        matches!(self, Method::AEdit)
    }

    /// Paper's outer optimizer for this method.
    pub fn default_outer(&self) -> OuterOptKind {
        match self {
            Method::Baseline => OuterOptKind::averaging(), // unused
            Method::PostLocalSgd => OuterOptKind::averaging(),
            _ => OuterOptKind::paper_nesterov(),
        }
    }

    /// Pseudo-gradient penalty active? (EDiT family only.)
    pub fn uses_penalty(&self) -> bool {
        matches!(self, Method::Edit | Method::AEdit)
    }

    /// Layer-wise (per-module) synchronization during forward pass.
    pub fn layerwise_sync(&self) -> bool {
        matches!(self, Method::Edit | Method::AEdit)
    }

    /// Outer update applied with one round of staleness (CO2 overlap).
    pub fn outer_staleness(&self) -> usize {
        match self {
            Method::Co2 | Method::Co2Star => 1,
            _ => 0,
        }
    }

    /// Outer-optimizer state sharded across the shard group (vs a full
    /// copy per worker)? Drives the memory model (Table 2 OOM column).
    pub fn outer_state_sharded(&self) -> bool {
        matches!(self, Method::Co2Star | Method::Edit | Method::AEdit)
    }

    /// Extra full parameter copy (θ_t anchor) sharded?
    pub fn anchor_sharded(&self) -> bool {
        self.outer_state_sharded() // same storage policy in all methods
    }

    /// DDP warmup phase length applies (two-phase training, Alg. 1).
    pub fn uses_warmup(&self) -> bool {
        matches!(self, Method::PostLocalSgd | Method::Edit | Method::AEdit)
    }

    /// Penalty config for this method (disabled for non-EDiT methods).
    pub fn default_penalty(&self) -> PenaltyConfig {
        if self.uses_penalty() {
            PenaltyConfig::default()
        } else {
            PenaltyConfig::disabled()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("PLS"), Some(Method::PostLocalSgd));
        assert_eq!(Method::parse("co2star"), Some(Method::Co2Star));
        assert_eq!(Method::parse("aedit"), Some(Method::AEdit));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn paper_property_matrix() {
        use Method::*;
        assert!(!Baseline.is_local_sgd());
        assert!(Edit.uses_penalty() && AEdit.uses_penalty());
        assert!(!DiLoCo.uses_penalty());
        assert_eq!(Co2.outer_staleness(), 1);
        assert_eq!(DiLoCo.outer_staleness(), 0);
        assert!(Co2Star.outer_state_sharded() && !Co2.outer_state_sharded());
        assert!(Edit.outer_state_sharded());
        assert!(AEdit.time_based_sync() && !Edit.time_based_sync());
        assert!(PostLocalSgd.uses_warmup() && !DiLoCo.uses_warmup());
    }

    #[test]
    fn outer_defaults() {
        assert_eq!(Method::PostLocalSgd.default_outer(), OuterOptKind::averaging());
        assert_eq!(Method::Edit.default_outer(), OuterOptKind::paper_nesterov());
    }
}
