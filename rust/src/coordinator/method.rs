//! The method zoo as a **named-preset table**: every method the paper
//! evaluates (Table 2 / Fig. 4) plus `palsgd`, each defined purely as a
//! [`MethodSpec`] row in [`Method::spec`] (see `coordinator::spec` for
//! the axes). All behavior — engine dispatch, simulator pricing, memory
//! accounting — reads the spec; this enum survives only for CLI
//! parsing, reporting labels and the experiment harness tables.

use super::outer::OuterOptKind;
use super::penalty::PenaltyConfig;
use super::spec::{MethodSpec, PayloadKind, SyncGranularity, SyncTrigger};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Standard synchronous mini-batch DDP ("Baseline").
    Baseline,
    /// Lin et al. 2019: DDP warmup, then plain parameter averaging.
    PostLocalSgd,
    /// Douillard et al. 2023: pseudo-gradient averaging + Nesterov outer.
    DiLoCo,
    /// Sun et al. 2023: DiLoCo numerics with staleness-1 outer update
    /// (communication hidden behind the next round); FULL outer state
    /// per worker.
    Co2,
    /// Memory-efficient CO2: sharded outer state, extra non-overlapped
    /// communication (identical numerics to CO2).
    Co2Star,
    /// This paper: layer-wise sync + pseudo-gradient penalty + sharded
    /// outer state.
    Edit,
    /// Asynchronous EDiT: time-based sync interval (§3.3).
    AEdit,
    /// Probabilistic time-based sync riding the A-EDiT event core
    /// (Naganuma et al., *Pseudo-Asynchronous Local SGD*, 2025): each
    /// deadline window, a replica anchor-syncs only with probability p.
    Palsgd,
}

impl Method {
    /// The paper's seven methods — the rows/columns of its tables.
    pub const ALL: [Method; 7] = [
        Method::Baseline,
        Method::PostLocalSgd,
        Method::DiLoCo,
        Method::Co2,
        Method::Co2Star,
        Method::Edit,
        Method::AEdit,
    ];

    /// Every named preset the CLI accepts (the paper's seven plus the
    /// descriptor-registered extensions).
    pub const NAMED: [Method; 8] = [
        Method::Baseline,
        Method::PostLocalSgd,
        Method::DiLoCo,
        Method::Co2,
        Method::Co2Star,
        Method::Edit,
        Method::AEdit,
        Method::Palsgd,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::PostLocalSgd => "post-local-sgd",
            Method::DiLoCo => "diloco",
            Method::Co2 => "co2",
            Method::Co2Star => "co2*",
            Method::Edit => "edit",
            Method::AEdit => "a-edit",
            Method::Palsgd => "palsgd",
        }
    }

    /// Comma-separated list of every accepted method name (CLI errors).
    pub fn name_list() -> String {
        let names: Vec<&str> = Method::NAMED.iter().map(|m| m.name()).collect();
        names.join(", ")
    }

    pub fn parse(s: &str) -> Option<Method> {
        let s = s.to_ascii_lowercase();
        Method::NAMED
            .iter()
            .copied()
            .find(|m| m.name() == s || m.name().replace('-', "_") == s)
            .or(match s.as_str() {
                "pls" => Some(Method::PostLocalSgd),
                "co2star" | "co2s" => Some(Method::Co2Star),
                "aedit" => Some(Method::AEdit),
                "pal-sgd" => Some(Method::Palsgd),
                _ => None,
            })
    }

    /// The preset table: one [`MethodSpec`] row per named method. This
    /// is the ONLY place a named method's behavior is defined — every
    /// consumer dispatches on the returned axes.
    pub fn spec(&self) -> MethodSpec {
        use SyncGranularity::{Flat, Layerwise};
        let disabled = PenaltyConfig::disabled();
        match self {
            Method::Baseline => MethodSpec {
                trigger: SyncTrigger::None,
                granularity: Flat,
                outer: OuterOptKind::averaging(), // unused: never syncs
                outer_staleness: 0,
                penalty: disabled,
                shard_outer_state: false,
                shard_anchor: false,
                warmup: false,
                payload: PayloadKind::F32,
            },
            Method::PostLocalSgd => MethodSpec {
                trigger: SyncTrigger::Step,
                granularity: Flat,
                outer: OuterOptKind::averaging(),
                outer_staleness: 0,
                penalty: disabled,
                shard_outer_state: false,
                shard_anchor: false,
                warmup: true,
                payload: PayloadKind::F32,
            },
            Method::DiLoCo => MethodSpec {
                trigger: SyncTrigger::Step,
                granularity: Flat,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 0,
                penalty: disabled,
                shard_outer_state: false,
                shard_anchor: false,
                warmup: false,
                payload: PayloadKind::F32,
            },
            Method::Co2 => MethodSpec {
                trigger: SyncTrigger::Step,
                granularity: Flat,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 1,
                penalty: disabled,
                shard_outer_state: false,
                shard_anchor: false,
                warmup: false,
                payload: PayloadKind::F32,
            },
            Method::Co2Star => MethodSpec {
                trigger: SyncTrigger::Step,
                granularity: Flat,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 1,
                penalty: disabled,
                shard_outer_state: true,
                shard_anchor: true,
                warmup: false,
                payload: PayloadKind::F32,
            },
            Method::Edit => MethodSpec {
                trigger: SyncTrigger::Step,
                granularity: Layerwise,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 0,
                penalty: PenaltyConfig::default(),
                shard_outer_state: true,
                shard_anchor: true,
                warmup: true,
                payload: PayloadKind::F32,
            },
            Method::AEdit => MethodSpec {
                trigger: SyncTrigger::Time,
                granularity: Layerwise,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 0,
                penalty: PenaltyConfig::default(),
                shard_outer_state: true,
                shard_anchor: true,
                warmup: true,
                payload: PayloadKind::F32,
            },
            Method::Palsgd => MethodSpec {
                trigger: SyncTrigger::Probabilistic { prob: 0.5 },
                granularity: Layerwise,
                outer: OuterOptKind::paper_nesterov(),
                outer_staleness: 0,
                penalty: PenaltyConfig::default(),
                shard_outer_state: true,
                shard_anchor: true,
                warmup: true,
                payload: PayloadKind::F32,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::NAMED {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("PLS"), Some(Method::PostLocalSgd));
        assert_eq!(Method::parse("co2star"), Some(Method::Co2Star));
        assert_eq!(Method::parse("aedit"), Some(Method::AEdit));
        assert_eq!(Method::parse("pal-sgd"), Some(Method::Palsgd));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn all_is_the_papers_seven() {
        assert_eq!(Method::ALL.len(), 7);
        assert!(!Method::ALL.contains(&Method::Palsgd));
        assert!(Method::NAMED.contains(&Method::Palsgd));
        for m in Method::ALL {
            assert!(Method::NAMED.contains(&m));
        }
    }

    #[test]
    fn name_list_mentions_every_preset() {
        let list = Method::name_list();
        for m in Method::NAMED {
            assert!(list.contains(m.name()), "{list}");
        }
    }

    #[test]
    fn every_preset_spec_validates() {
        for m in Method::NAMED {
            m.spec().validate().unwrap_or_else(|e| panic!("{m:?}: {e}"));
        }
    }
}
