//! The local-SGD training engine (Alg. 1) — one engine, every strategy
//! a [`MethodSpec`] can describe.
//!
//! This file is the thin facade over the event-driven execution core:
//!
//!  * [`clock`]  — the deterministic discrete-event scheduler (min-heap
//!                 on per-replica simulated clocks, stable tie-break by
//!                 replica index, bitwise-equal clocks coalesce);
//!  * `worker`   — the per-replica lane state machine (fill batch →
//!                 inner step → straggler lag → sync eligibility), with
//!                 optional parallel worker threads;
//!  * `sync`     — the two synchronization paths: barrier sync for the
//!                 step-synced methods and per-replica **anchor sync**
//!                 for A-EDiT (no global barrier), plus the precomputed
//!                 `CommPlan` with layer-wise overlap accounting.
//!
//! Numerics model (see [`super::spec`] for the strategy axes the engine
//! dispatches on): each *column* of the M×N mesh (a model
//! shard group) keeps bitwise-identical parameters at every inner step
//! (per-step gradient averaging inside the column), so the engine
//! simulates one logical replica per column.  Each replica executes the
//! fused AOT train step (fwd+bwd+AdamW — Layers 2/1) through PJRT, and
//! the coordinator (Layer 3) owns everything across replicas: warmup
//! DDP, periodic synchronization, the pseudo-gradient penalty, outer
//! optimization, rollbacks, elastic rescaling, and the simulated-clock
//! accounting that turns collective volumes into throughput numbers via
//! the shared α-β cost model.
//!
//! Virtual time: every replica carries a clock (seconds).  Inner steps
//! advance it by `StepModel::inner_step` plus injected straggler lag.
//! Step-synced methods barrier at `max(clocks) + sync_exposed`.  A-EDiT
//! replaces the fixed-τ trigger with a deadline of `τ_time` seconds and
//! **per-replica** anchor syncs ordered by the event scheduler: a worker
//! whose clock passes its deadline synchronizes against the shared
//! anchor without waiting for peers, so fast replicas genuinely run
//! more inner steps per round and never inherit a straggler's clock
//! (§3.3).  On a perfectly homogeneous cluster all sync events coalesce
//! and A-EDiT reduces exactly to EDiT.
//!
//! Determinism: every stochastic input is a stateless function of
//! `(seed, replica, inner_step)` and all cross-replica effects are
//! ordered by the scheduler's total event order, so runs are bitwise
//! reproducible — including across `worker_threads` counts
//! (`tests/scheduler_determinism.rs`).
//!
//! Hot-path discipline: all per-round buffers live in the
//! [`SyncScratch`] arena / per-replica lanes and all per-round
//! communication charges and step timings are precomputed in the
//! `CommPlan`, so full rounds perform **zero heap allocations** in
//! steady state (asserted by `tests/sync_steady_state.rs`).

use anyhow::Result;

use crate::collectives::CommStats;
use crate::data::{Corpus, Split};
use crate::metrics::{RunTracker, Timeline};
use crate::runtime::Engine;
use crate::simulator::stepmodel::StepModel;
use crate::tensor::ModuleTable;

use super::mesh::MeshSpec;
use super::method::Method;
use super::outer::OuterOpt;
use super::penalty::AnomalyDetector;
use super::schedule::LrSchedule;
use super::scratch::SyncScratch;
use super::spec::MethodSpec;

mod checkpoint;
pub mod clock;
mod sync;
mod worker;

/// Upper bound on the per-replica loss-trace reservation (entries; 16 B
/// each ⇒ 16 MB per replica). Up to this many inner steps the trace
/// never reallocates — the boundary of the steady-state zero-allocation
/// invariant for very long runs.
pub const LOSS_TRACE_CAP: u64 = 1 << 20;

/// Straggler injection (paper §4.3, Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Straggler {
    None,
    /// Each replica independently lags by `lag` seconds with probability
    /// 1/N per inner step (stateless draw — see `worker::straggler_lag`).
    Random { lag: f64 },
    /// A fixed replica lags by `lag` seconds each inner step.
    Consistent { lag: f64, replica: usize },
}

/// Fault injection: a "sick worker" whose state diverges (perturbed by
/// Gaussian noise each inner step) for a window of sync rounds — the
/// scenario behind the paper's Fig. 7b/c per-worker loss spikes.
/// Exercises anomaly elimination / weighted suppression / clipping /
/// rollback end to end.
///
/// Note on the fault model: with AdamW as the inner optimizer,
/// low-quality *data* barely moves the pseudo-gradient norm at our
/// compressed scale (Adam normalizes per-coordinate step sizes), so the
/// harness injects the downstream symptom directly — a worker whose
/// parameters drift anomalously — which is what the z-test screens for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Poison {
    /// Poisoned replica, or `usize::MAX` for ALL replicas (rollback path).
    pub replica: usize,
    pub from_sync: u64,
    pub to_sync: u64,
    /// Std-dev of the per-step parameter perturbation.
    pub strength: f32,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Strategy descriptor — the single source of truth for every
    /// behavioral axis (sync trigger/granularity, outer optimizer,
    /// staleness, penalty stages, sharding policy, warmup).
    pub spec: MethodSpec,
    /// Display name for logs and summaries ("edit", "palsgd",
    /// "custom:base=edit,penalty=off", ...).
    pub label: String,
    pub mesh: MeshSpec,
    /// Synchronization interval in inner steps (τ).
    pub tau: u64,
    /// Time-based interval for A-EDiT/PALSGD (τ_time, simulated seconds).
    pub tau_time: f64,
    /// Warmup (mini-batch DDP) inner steps, Alg. 1's t_warm.
    pub t_warm: u64,
    /// Experiment length in global inner steps.
    pub total_steps: u64,
    pub inner_lr: LrSchedule,
    pub seed: u64,
    /// Evaluate validation PPL every this many syncs (0 = never).
    pub eval_every_syncs: u64,
    pub eval_batches: usize,
    pub straggler: Straggler,
    pub poison: Vec<Poison>,
    /// Pure compute seconds per inner step per worker (virtual clock).
    pub base_step_time: f64,
    /// Print a progress line every N syncs (0 = silent).
    pub log_every: u64,
    /// OS threads running replica inner loops concurrently (1 =
    /// sequential; results are bitwise identical either way). Also fans
    /// the sharded sync's load/combine phases out over the shard lanes.
    pub worker_threads: usize,
    /// Record per-replica sync events into [`Trainer::timeline`].
    pub trace_timeline: bool,
    /// ZeRO-1-style sharded outer state for the layer-wise methods
    /// (EDiT/A-EDiT): each of the N sync-group ranks owns a contiguous
    /// range-aligned shard of the flat space; pseudo-gradients are
    /// reduce-scattered into it, the penalty statistics and outer
    /// update run shard-locally, and the updated anchor shards are
    /// all-gathered back. Bitwise identical to the full-matrix
    /// reference path; per-rank sync memory ≈ full ÷ N for near-uniform
    /// module tables (ranges are never split, so the largest shard is
    /// floored at the largest single module range). Defaults to the
    /// spec's `shard_outer_state` axis (on for the layer-wise presets;
    /// `custom:...,shard=off` turns it off coherently); engages only
    /// for layer-wise strategies with N > 1 (a single replica keeps the
    /// full-matrix path — there is nothing to shard across).
    pub shard_outer: bool,
    /// Software-pipeline the layer-wise sync sweep: module `m`'s
    /// combine/apply/adopt completes while module `m+1` is loaded and
    /// screened, through double-buffered
    /// [`ModuleLane`](crate::coordinator::scratch::ModuleLane)s
    /// (full-matrix path) or the per-module shard combine (sharded
    /// path). This is the trainer-side twin of the driver's nonblocking
    /// issue/wait schedule; results are bitwise identical to the
    /// sequential sweep on every preset × payload × shard combination
    /// (tests/scheduler_determinism.rs). Default on; turn off to force
    /// the historical strictly-sequential order.
    pub overlap_sync: bool,
    /// Deterministic fault schedule (crash / hang / rejoin events keyed
    /// on the local-round counter; see [`crate::fault`]). Empty by
    /// default — the harness is compiled in but completely inactive, so
    /// the steady-state zero-allocation invariant is unaffected.
    /// Requires a layer-wise local-SGD strategy (the membership-aware
    /// sync paths); `Trainer::new` rejects other combinations.
    pub fault_plan: crate::fault::FaultPlan,
    /// Simulated seconds a step-synced barrier waits for a missing
    /// member before evicting it (charged once per round with a crash;
    /// the A-EDiT anchor path has no barrier and never pays it).
    pub evict_timeout: f64,
    /// Write a checkpoint every N local rounds (0 = never). Requires
    /// `checkpoint_dir`.
    pub checkpoint_every: u64,
    /// Directory for periodic checkpoints (`ckpt-round-NNNNNN.bin`).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Collective transport. The single-process trainer simulates its
    /// cluster in-process and only accepts
    /// [`CommBackend::Thread`](crate::collectives::CommBackend::Thread);
    /// `CommBackend::Socket` selects the multi-process deployment,
    /// which runs one `edit-train worker --join <addr>` process per
    /// rank against an `edit-train rendezvous` hub instead of this
    /// entrypoint (`Trainer::new` rejects it with that pointer).
    pub backend: crate::collectives::CommBackend,
}

impl TrainConfig {
    /// Paper-shaped defaults scaled to the CPU-trainable regime, for a
    /// named preset.
    pub fn paper_default(method: Method, mesh: MeshSpec, total_steps: u64) -> Self {
        Self::from_spec(method.spec(), method.name(), mesh, total_steps)
    }

    /// Paper-shaped defaults for an arbitrary strategy descriptor (the
    /// `custom:` grammar path; named presets go through
    /// [`Self::paper_default`]).
    pub fn from_spec(
        spec: MethodSpec,
        label: impl Into<String>,
        mesh: MeshSpec,
        total_steps: u64,
    ) -> Self {
        Self {
            label: label.into(),
            mesh,
            tau: 16,
            tau_time: 16.0 * 0.5,
            t_warm: if spec.warmup { 16 } else { 0 },
            total_steps,
            inner_lr: LrSchedule::paper_cosine(
                if spec.is_local_sgd() { 1.5e-3 } else { 3e-3 },
                total_steps,
            ),
            seed: 42,
            eval_every_syncs: 4,
            eval_batches: 4,
            straggler: Straggler::None,
            poison: Vec::new(),
            base_step_time: 0.5,
            log_every: 0,
            worker_threads: 1,
            trace_timeline: false,
            // Runtime ZeRO-1 toggle follows the strategy's sharding
            // axis, so `custom:...,shard=off` really runs unsharded
            // (bitwise identical numerics, full-matrix memory). Flat
            // strategies never engage it regardless.
            shard_outer: spec.shard_outer_state,
            overlap_sync: true,
            fault_plan: crate::fault::FaultPlan::default(),
            // Two step-times of grace before a straggling member is
            // declared dead at a barrier.
            evict_timeout: 2.0 * 0.5,
            checkpoint_every: 0,
            checkpoint_dir: None,
            backend: crate::collectives::CommBackend::Thread,
            spec,
        }
    }
}

/// One logical replica (= one model shard group / mesh column).
#[derive(Debug, Clone)]
pub struct Replica {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based AdamW step counter (bias correction).
    pub adam_t: i32,
    /// Virtual clock, seconds.
    pub clock: f64,
    /// Inner steps completed (also the data-stream cursor).
    pub inner_steps: u64,
    /// (global_step, loss) trace — Fig. 7b/c per-worker curves.
    pub losses: Vec<(u64, f32)>,
}

impl Replica {
    fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        Self {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_t: 0,
            clock: 0.0,
            inner_steps: 0,
            losses: Vec::new(),
        }
    }
}

/// End-of-run summary (the numbers the experiment tables consume).
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// The run's method label (`TrainConfig::label`).
    pub label: String,
    pub final_loss: f64,
    pub final_ppl: f64,
    pub sim_seconds: f64,
    pub tokens: u64,
    /// tokens / simulated second across the whole cluster.
    pub throughput: f64,
    pub syncs: u64,
    pub anomalies: u64,
    pub rollbacks: u64,
    /// Largest number of anchor versions any replica missed between two
    /// of its consecutive syncs (0 for fully step-synced runs).
    pub max_staleness: u64,
    /// CO2 staleness-queue updates applied by the end-of-run flush.
    pub flushed_updates: u64,
    /// Fault-plan crash events that fired.
    pub crashes: u64,
    /// Fault-plan join events that fired (revive or live append).
    pub rejoins: u64,
    /// Members evicted from a timed-out step-synced barrier (always 0
    /// on the A-EDiT anchor path — no barrier to time out).
    pub evictions: u64,
    /// Syncs that ran with at least one replica dead (degraded
    /// membership — the survivors kept syncing without the victim).
    pub degraded_syncs: u64,
    pub comm: CommStats,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    engine: Engine,
    corpus: Corpus,
    table: ModuleTable,
    pub replicas: Vec<Replica>,
    /// θ_t — last synchronized parameters (identical across replicas).
    pub anchor: Vec<f32>,
    outer: OuterOpt,
    detector: AnomalyDetector,
    /// CO2 staleness queue of combined-but-unapplied updates.
    pending: std::collections::VecDeque<Vec<f32>>,
    step_model: StepModel,
    pub tracker: RunTracker,
    pub comm: CommStats,
    pub sim_time: f64,
    pub global_step: u64,
    pub syncs: u64,
    pjrt_calls: u64,
    /// `EDIT_DEBUG_NORMS` read once at construction (the per-module
    /// env lookup used to sit inside the sync hot loop).
    debug_norms: bool,
    /// Per-replica loss-trace capacity reserved up front so steady-state
    /// recording never reallocates.
    loss_capacity: usize,
    plan: sync::CommPlan,
    // --- event core state (reused across rounds; see `clock`/`worker`) --
    lanes: Vec<worker::Lane>,
    events: clock::EventQueue,
    /// Scratch member list for coalesced event groups.
    group_buf: Vec<usize>,
    /// Scratch member list for barrier syncs — rebuilt from the alive
    /// set each round (capacity pinned to the replica count, so the
    /// rebuild never allocates in steady state).
    member_buf: Vec<usize>,
    /// Monotonic anchor-update counter (staleness bookkeeping).
    anchor_version: u64,
    /// Deadline windows completed (time-based triggers) — keys the
    /// stateless probabilistic sync draws (PALSGD).
    sync_windows: u64,
    /// Per replica: anchor version after its last sync.
    last_sync_version: Vec<u64>,
    max_staleness: u64,
    flushed_updates: u64,
    // --- fault-tolerance state (see `crate::fault`) ---------------------
    /// Local rounds completed (the fault plan's round key; warmup DDP
    /// steps do not count — plan events simply wait for round 0).
    rounds: u64,
    /// Next unconsumed event in `cfg.fault_plan` (sorted by round).
    fault_cursor: usize,
    /// Liveness per replica; dead replicas take no steps and are
    /// excluded from sync membership until a Join revives them.
    alive: Vec<bool>,
    /// Per-round per-lane step budget: `u64::MAX` for alive replicas, a
    /// crash event's `after_steps` for this round's victims, 0 for the
    /// dead. Refilled in place each round — no allocation.
    fault_caps: Vec<u64>,
    /// Victims of this round's crash events (committed after the lanes
    /// run, so a victim's partial steps still happen).
    pending_crash: Vec<usize>,
    crashes: u64,
    rejoins: u64,
    evictions: u64,
    degraded_syncs: u64,
    /// One-shot flag: the next barrier prices the evict timeout.
    evict_charge: bool,
    /// Per-replica sync-event trace (filled when `cfg.trace_timeline`).
    pub timeline: Timeline,
    // reusable scratch
    grad_buf: Vec<f32>,
    grad_acc: Vec<f32>,
    scratch: SyncScratch,
}

impl Trainer {
    pub fn new(
        engine: Engine,
        corpus: Corpus,
        cfg: TrainConfig,
        cost: crate::collectives::CostModel,
    ) -> Result<Self> {
        anyhow::ensure!(
            corpus.language.vocab() == engine.manifest.model.vocab_size,
            "corpus vocab {} != model vocab {}",
            corpus.language.vocab(),
            engine.manifest.model.vocab_size
        );
        anyhow::ensure!(
            cfg.fault_plan.is_empty() || (cfg.spec.is_local_sgd() && cfg.spec.layerwise()),
            "fault plan requires a layer-wise local-SGD strategy (edit / a-edit / palsgd): \
             the flat uniform-averaging sync has no membership-aware combine to degrade to"
        );
        anyhow::ensure!(
            cfg.backend == crate::collectives::CommBackend::Thread,
            "backend=socket selects the multi-process deployment: start a hub with \
             `edit-train rendezvous --bind <addr> --world N` and one \
             `edit-train worker --join <addr>` process per rank instead of `train`"
        );
        let init = engine.init_params()?;
        let n = init.len();
        let table = engine.manifest.table.clone();
        // Loss-trace reservation: total_steps plus one round of A-EDiT
        // slack (fast replicas run up to 4τ extra steps). The cap bounds
        // memory for open-ended runs (total_steps = u64::MAX) at 16 MB
        // per replica; it is also the stated bound of the zero-allocation
        // invariant — runs past LOSS_TRACE_CAP inner steps reallocate the
        // trace amortized (see `coordinator::scratch` docs).
        let loss_capacity = cfg
            .total_steps
            .saturating_add(cfg.tau.saturating_mul(4))
            .min(LOSS_TRACE_CAP) as usize;
        let replicas: Vec<Replica> = (0..cfg.mesh.replicas)
            .map(|_| {
                let mut r = Replica::new(init.clone());
                r.losses.reserve(loss_capacity);
                r
            })
            .collect();
        let detector =
            AnomalyDetector::new(cfg.mesh.replicas, table.num_modules(), cfg.spec.penalty);
        let step_model = StepModel {
            mesh: cfg.mesh,
            cost,
            param_bytes: n * 4,
            compute: cfg.base_step_time,
            cpu_offload: false,
        };
        let [b, s1] = engine.manifest.token_shape;
        let token_cap = b * s1;
        let mut scratch = SyncScratch::new(&table, cfg.mesh.replicas, token_cap);
        if cfg.shard_outer && cfg.spec.layerwise() && cfg.mesh.replicas > 1 {
            // ZeRO-1-style outer sharding across the N sync-group ranks
            // (a single replica keeps the full-matrix path — there is
            // nothing to shard across).
            scratch.enable_sharding(&table, cfg.mesh.replicas);
        }
        // Payload axis: size the error-feedback residual buffers (a
        // no-op for f32 — the buffers stay empty and the quantization
        // branch never runs, keeping the f32 path bitwise identical).
        scratch.set_payload(cfg.spec.payload);
        let lanes: Vec<worker::Lane> = (0..cfg.mesh.replicas)
            .map(|_| worker::Lane::with_token_capacity(token_cap))
            .collect();
        let plan = sync::CommPlan::build(&step_model, &cfg.spec, &table, cfg.shard_outer);
        let mut tracker = RunTracker::new();
        // The tracker records once per round for step-synced local-SGD
        // methods (plus once per warmup DDP step), so reserving per-step
        // capacity would overshoot by ~τ. Baseline records every step and
        // A-EDiT's steps-per-round varies (1..4τ), so both keep the
        // conservative per-step bound.
        let tracker_capacity = if cfg.spec.is_local_sgd() && !cfg.spec.trigger.time_based() {
            cfg.t_warm
                .saturating_add(
                    cfg.total_steps.saturating_sub(cfg.t_warm) / cfg.tau.max(1),
                )
                .saturating_add(2)
                .min(LOSS_TRACE_CAP) as usize
        } else {
            loss_capacity
        };
        tracker.reserve(tracker_capacity);
        let mut timeline = Timeline::default();
        if cfg.trace_timeline {
            // One event per replica per sync; ~2 syncs/round worst case
            // under heterogeneity.
            let est = (tracker_capacity as u64)
                .saturating_mul(2 * cfg.mesh.replicas as u64)
                .min(LOSS_TRACE_CAP) as usize;
            timeline.reserve(est);
        }
        Ok(Self {
            outer: OuterOpt::new(cfg.spec.outer, n),
            detector,
            pending: Default::default(),
            step_model,
            tracker,
            comm: CommStats::default(),
            sim_time: 0.0,
            global_step: 0,
            syncs: 0,
            pjrt_calls: 0,
            debug_norms: std::env::var("EDIT_DEBUG_NORMS").is_ok(),
            loss_capacity,
            plan,
            lanes,
            events: clock::EventQueue::with_capacity(cfg.mesh.replicas),
            group_buf: Vec::with_capacity(cfg.mesh.replicas),
            member_buf: Vec::with_capacity(cfg.mesh.replicas),
            anchor_version: 0,
            sync_windows: 0,
            last_sync_version: vec![0; cfg.mesh.replicas],
            max_staleness: 0,
            flushed_updates: 0,
            rounds: 0,
            fault_cursor: 0,
            alive: vec![true; cfg.mesh.replicas],
            fault_caps: vec![u64::MAX; cfg.mesh.replicas],
            pending_crash: Vec::with_capacity(cfg.mesh.replicas),
            crashes: 0,
            rejoins: 0,
            evictions: 0,
            degraded_syncs: 0,
            evict_charge: false,
            timeline,
            grad_buf: vec![0.0; n],
            grad_acc: vec![0.0; n],
            scratch,
            anchor: init,
            replicas,
            table,
            corpus,
            engine,
            cfg,
        })
    }

    pub fn num_params(&self) -> usize {
        self.anchor.len()
    }

    pub fn pjrt_calls(&self) -> u64 {
        self.pjrt_calls
    }

    /// Simulated duration of one local inner step — lets callers express
    /// τ_time and straggler lags in step-time units.
    pub fn inner_step_seconds(&self) -> f64 {
        self.plan.step_time_local
    }

    /// Fill the scratch token buffer with the batch for (replica, step).
    /// Used by the lock-step DDP path; local rounds use the per-lane
    /// buffers (`worker::Lane::fill_batch`) so lanes can run in parallel.
    fn fill_batch(&mut self, replica: usize, step: u64) {
        let [b, s1] = self.engine.manifest.token_shape;
        let m = self.cfg.mesh.shard;
        self.scratch.tokens.clear();
        for r in 0..b {
            let worker = self.cfg.mesh.rank(r % m, replica);
            self.corpus.sequence_into(
                Split::Train,
                worker,
                step,
                r / m,
                s1,
                &mut self.scratch.tokens,
            );
        }
    }

    fn in_warmup(&self) -> bool {
        !self.cfg.spec.is_local_sgd()
            || (self.cfg.spec.warmup && self.global_step < self.cfg.t_warm)
    }

    /// One synchronous mini-batch DDP step (Baseline & warmup phase).
    /// Replicas stay bitwise identical: gradients are averaged across
    /// the whole mesh and applied once, then copied.
    fn ddp_step(&mut self) -> Result<()> {
        let lr = self.cfg.inner_lr.at(self.global_step) as f32;
        let n = self.replicas.len();
        self.grad_acc.fill(0.0);
        let mut mean_loss = 0.0f64;
        for j in 0..n {
            self.fill_batch(j, self.replicas[j].inner_steps);
            let out = self.engine.grad_step(
                &self.replicas[j].params,
                &self.scratch.tokens,
                &mut self.grad_buf,
            )?;
            self.pjrt_calls += 1;
            crate::tensor::axpy(&mut self.grad_acc, 1.0 / n as f32, &self.grad_buf);
            mean_loss += out.loss as f64 / n as f64;
            let gs = self.global_step;
            self.replicas[j].losses.push((gs, out.loss));
        }
        // Gradient all-reduce: each worker all-reduces its grad shard
        // across its sync group — one charge per mesh row.
        for &(bytes, secs) in &self.plan.sync_allreduce {
            self.comm.record(bytes, secs);
        }

        // Apply once, copy to all replicas (they are identical under DDP).
        let adam_t = self.replicas[0].adam_t + 1;
        {
            let r0 = &mut self.replicas[0];
            r0.adam_t = adam_t;
        }
        let (first, rest) = self.replicas.split_at_mut(1);
        let r0 = &mut first[0];
        self.engine.apply_step(
            &mut r0.params,
            &mut r0.m,
            &mut r0.v,
            &self.grad_acc,
            lr,
            adam_t,
        )?;
        self.pjrt_calls += 1;
        for r in rest.iter_mut() {
            r.params.copy_from_slice(&r0.params);
            r.m.copy_from_slice(&r0.m);
            r.v.copy_from_slice(&r0.v);
            r.adam_t = adam_t;
        }
        // Clocks: everyone waits for the slowest (synchronous step).
        let step_time = self.plan.step_time_ddp;
        let mut max_clock: f64 = 0.0;
        for j in 0..self.replicas.len() {
            let lag = worker::straggler_lag(
                &self.cfg.straggler,
                self.cfg.seed,
                j,
                self.replicas[j].inner_steps,
                self.cfg.mesh.replicas,
            );
            let r = &mut self.replicas[j];
            r.clock += step_time + lag;
            r.inner_steps += 1;
            max_clock = max_clock.max(r.clock);
        }
        for r in &mut self.replicas {
            r.clock = max_clock;
        }
        self.sim_time = max_clock;
        self.global_step += 1;
        self.tracker.record_loss(self.global_step, mean_loss);
        // The anchor tracks the (shared) parameters during DDP/warmup.
        self.anchor.copy_from_slice(&self.replicas[0].params);
        Ok(())
    }

    /// Run every replica's inner loop for one round — sequentially or on
    /// parallel worker threads (`cfg.worker_threads`), bitwise
    /// identically either way (see `worker` module docs). Returns
    /// `(loss_sum, loss_count, max_steps)` folded in replica order.
    fn run_lanes(&mut self, deadline: Option<f64>, step_cap: u64) -> Result<(f64, u64, u64)> {
        let Trainer {
            engine,
            corpus,
            cfg,
            replicas,
            lanes,
            plan,
            global_step,
            syncs,
            pjrt_calls,
            fault_caps,
            ..
        } = self;
        debug_assert_eq!(replicas.len(), lanes.len());
        debug_assert_eq!(replicas.len(), fault_caps.len());
        let ctx = worker::RoundCtx {
            engine: &*engine,
            corpus: &*corpus,
            cfg: &*cfg,
            step_time: plan.step_time_local,
            base_step: *global_step,
            deadline,
            step_cap,
            caps: fault_caps,
            syncs: *syncs,
        };
        let threads = ctx.cfg.worker_threads.max(1).min(replicas.len().max(1));
        if threads <= 1 {
            for (j, (r, lane)) in replicas.iter_mut().zip(lanes.iter_mut()).enumerate() {
                lane.begin_round();
                lane.run_round(j, r, &ctx)?;
            }
        } else {
            let mut work: Vec<(usize, &mut Replica, &mut worker::Lane)> = replicas
                .iter_mut()
                .zip(lanes.iter_mut())
                .enumerate()
                .map(|(j, (r, l))| (j, r, l))
                .collect();
            let chunk = work.len().div_ceil(threads);
            std::thread::scope(|s| -> Result<()> {
                let ctx = &ctx;
                let mut handles = Vec::with_capacity(threads);
                for batch in work.chunks_mut(chunk) {
                    handles.push(s.spawn(move || -> Result<()> {
                        for (j, r, lane) in batch.iter_mut() {
                            lane.begin_round();
                            lane.run_round(*j, &mut **r, ctx)?;
                        }
                        Ok(())
                    }));
                }
                for h in handles {
                    h.join().expect("worker lane thread panicked")?;
                }
                Ok(())
            })?;
        }
        // Fold in replica order: reproduces the sequential f64 sums.
        let mut loss_sum = 0.0f64;
        let mut loss_count = 0u64;
        let mut max_steps = 0u64;
        for lane in lanes.iter() {
            loss_sum += lane.loss_sum;
            loss_count += lane.loss_count;
            max_steps = max_steps.max(lane.steps);
            *pjrt_calls += lane.calls;
        }
        Ok((loss_sum, loss_count, max_steps))
    }

    /// One local-SGD round. Step-synced strategies: τ inner steps per
    /// replica, then barrier synchronization. Time-based strategies
    /// (A-EDiT, PALSGD): every lane runs to the τ_time deadline, then
    /// the event scheduler orders the sync events by simulated clock
    /// (coalescing bitwise ties) and each group anchor-syncs without
    /// waiting for the rest of the cluster. Under the probabilistic
    /// trigger (PALSGD) each replica joins its window's sync only with
    /// probability p (stateless draw); skipped replicas keep training
    /// against their stale anchor and simply accrue staleness.
    fn local_round(&mut self) -> Result<()> {
        // Fault events scheduled for this round fire first: joins and
        // hangs take effect before the lanes run; crash victims get
        // their partial step budget and are committed dead after.
        self.apply_fault_events()?;
        if self.cfg.spec.trigger.time_based() {
            let deadline = self.sim_time + self.cfg.tau_time;
            let cap = self.cfg.tau.saturating_mul(4).max(1);
            let (loss_sum, loss_count, max_steps) = self.run_lanes(Some(deadline), cap)?;
            self.commit_crashes()?;
            self.global_step += max_steps;
            self.tracker
                .record_loss(self.global_step, loss_sum / loss_count.max(1) as f64);
            // The deadline frontier advances with the lanes regardless
            // of which replicas draw a sync: PALSGD can skip a whole
            // window, and the next one must still be τ_time wide (and
            // end-of-run sim_seconds must count the time the lanes
            // actually ran). Neutral for always-sync triggers — every
            // replica's sync group finishes at max(member clocks) +
            // exposed ≥ its clock, so the final sim_time is unchanged.
            for r in &self.replicas {
                if r.clock > self.sim_time {
                    self.sim_time = r.clock;
                }
            }
            let window = self.sync_windows;
            self.sync_windows += 1;
            self.events.clear();
            // Dead replicas enqueue no sync event: a crashed replica's
            // pending contribution is excluded from the anchor sync (a
            // per-group membership change, not a global abort).
            for (j, r) in self.replicas.iter().enumerate() {
                if self.alive[j]
                    && worker::sync_draw(&self.cfg.spec.trigger, self.cfg.seed, j, window)
                {
                    self.events.push(clock::Event { clock: r.clock, replica: j });
                }
            }
            loop {
                let mut members = std::mem::take(&mut self.group_buf);
                if self.events.pop_group(&mut members).is_none() {
                    self.group_buf = members;
                    break;
                }
                let res = sync::anchor_sync(self, &members);
                members.clear();
                self.group_buf = members;
                res?;
            }
            // One z-test round for the whole deadline window, however
            // many event groups it fragmented into (the warmup gate must
            // count rounds, not groups).
            self.detector.advance();
        } else {
            let remaining = self.cfg.total_steps.saturating_sub(self.global_step);
            let tau = self.cfg.tau.min(remaining.max(1));
            let (loss_sum, loss_count, max_steps) = self.run_lanes(None, tau)?;
            self.commit_crashes()?;
            self.global_step += max_steps;
            self.tracker
                .record_loss(self.global_step, loss_sum / loss_count.max(1) as f64);
            sync::barrier_sync(self)?;
        }
        self.rounds += 1;
        Ok(())
    }

    /// Fire every fault-plan event scheduled for the current round (and
    /// any that pointed at already-elapsed rounds, e.g. plans written
    /// against a longer schedule): joins and hangs apply immediately;
    /// crash victims get their per-lane step budget for this round and
    /// are committed dead after the lanes run ([`Self::commit_crashes`]).
    /// With an empty plan this refills the cap vector and returns — no
    /// allocation, no branches on the hot path beyond the cursor check.
    fn apply_fault_events(&mut self) -> Result<()> {
        for j in 0..self.fault_caps.len() {
            self.fault_caps[j] = if self.alive[j] { u64::MAX } else { 0 };
        }
        self.pending_crash.clear();
        let plan_len = self.cfg.fault_plan.events().len();
        while self.fault_cursor < plan_len {
            let ev = self.cfg.fault_plan.events()[self.fault_cursor];
            if ev.round > self.rounds {
                break;
            }
            self.fault_cursor += 1;
            self.apply_fault_event(ev)?;
        }
        Ok(())
    }

    fn apply_fault_event(&mut self, ev: crate::fault::FaultEvent) -> Result<()> {
        use crate::fault::FaultKind;
        let n = self.replicas.len();
        match ev.kind {
            FaultKind::Crash { after_steps } => {
                anyhow::ensure!(
                    ev.replica < n && self.alive[ev.replica],
                    "fault plan: crash@{}:{} targets a {} replica",
                    ev.round,
                    ev.replica,
                    if ev.replica < n { "dead" } else { "nonexistent" }
                );
                self.fault_caps[ev.replica] = after_steps;
                self.pending_crash.push(ev.replica);
            }
            FaultKind::Hang { secs } => {
                anyhow::ensure!(
                    ev.replica < n && self.alive[ev.replica],
                    "fault plan: hang@{}:{} targets a dead or nonexistent replica",
                    ev.round,
                    ev.replica
                );
                self.replicas[ev.replica].clock += secs;
            }
            FaultKind::Join if ev.replica < n => {
                anyhow::ensure!(
                    !self.alive[ev.replica],
                    "fault plan: join@{}:{} targets a replica that is already alive",
                    ev.round,
                    ev.replica
                );
                self.revive(ev.replica);
            }
            FaultKind::Join => {
                anyhow::ensure!(
                    ev.replica == n,
                    "fault plan: join@{}:{} would leave a gap (cluster has {} replicas)",
                    ev.round,
                    ev.replica,
                    n
                );
                self.append_replica();
            }
            FaultKind::NetDrop | FaultKind::NetDelay { .. } | FaultKind::Partition { .. } => {
                anyhow::bail!(
                    "fault plan: wire-level kinds (netdrop/netdelay/partition) target the \
                     socket transport; pass them to `edit-train worker --net-plan`, not the \
                     in-process trainer"
                );
            }
        }
        Ok(())
    }

    /// Flip this round's crash victims dead, after their partial steps
    /// ran. Step-synced rounds additionally arm the barrier's
    /// timeout-then-evict pricing.
    fn commit_crashes(&mut self) -> Result<()> {
        if self.pending_crash.is_empty() {
            return Ok(());
        }
        let pending = std::mem::take(&mut self.pending_crash);
        for &j in &pending {
            self.alive[j] = false;
            self.fault_caps[j] = 0;
            self.crashes += 1;
            if !self.cfg.spec.trigger.time_based() {
                self.evict_charge = true;
                self.evictions += 1;
            }
        }
        self.pending_crash = pending;
        self.pending_crash.clear();
        anyhow::ensure!(
            self.alive.iter().any(|&a| a),
            "fault plan crashed every replica (round {})",
            self.rounds
        );
        Ok(())
    }

    /// Revive a crashed replica in place: it adopts the current anchor,
    /// zeroed inner-optimizer moments, the present simulated clock and
    /// the cluster's AdamW step count — exactly the state a fresh
    /// elastic joiner gets from [`Self::rescale`]. Its data-stream
    /// cursor (`inner_steps`) continues where it left off, and the
    /// anchor versions it slept through are folded into the staleness
    /// high-water before its cursor resets.
    fn revive(&mut self, j: usize) {
        let missed = self.anchor_version.saturating_sub(self.last_sync_version[j]);
        if missed > self.max_staleness {
            self.max_staleness = missed;
        }
        self.last_sync_version[j] = self.anchor_version;
        let adam_t = self
            .alive
            .iter()
            .position(|&a| a)
            .map(|k| self.replicas[k].adam_t)
            .unwrap_or(self.replicas[j].adam_t);
        let clock = self.sim_time;
        let r = &mut self.replicas[j];
        r.params.copy_from_slice(&self.anchor);
        r.m.fill(0.0);
        r.v.fill(0.0);
        r.adam_t = adam_t;
        r.clock = clock;
        self.alive[j] = true;
        self.fault_caps[j] = u64::MAX;
        self.rejoins += 1;
    }

    /// Live-append a brand-new replica mid-run (a mid-round elastic
    /// join): unlike [`Self::rescale`], the existing replicas' state is
    /// untouched — only the joiner starts from the anchor. The mesh is
    /// column-major (`rank = col * shard + row`), so appending a column
    /// leaves every existing replica's worker ranks, and therefore its
    /// data streams, unchanged.
    fn append_replica(&mut self) {
        let n = self.replicas.len() + 1;
        let adam_t = self
            .alive
            .iter()
            .position(|&a| a)
            .map(|k| self.replicas[k].adam_t)
            .unwrap_or(0);
        let mut r = Replica::new(self.anchor.clone());
        r.losses.reserve(self.loss_capacity);
        r.adam_t = adam_t;
        r.clock = self.sim_time;
        self.replicas.push(r);
        let [b, s1] = self.engine.manifest.token_shape;
        self.lanes.push(worker::Lane::with_token_capacity(b * s1));
        self.alive.push(true);
        self.fault_caps.push(u64::MAX);
        self.last_sync_version.push(self.anchor_version);
        self.rejoins += 1;
        self.refresh_topology(n);
    }

    /// Rebuild everything derived from the replica count (mesh, step
    /// model, comm plan, detector width, scratch arena, sharding) —
    /// shared by [`Self::rescale`] and the live-join path.
    fn refresh_topology(&mut self, new_replicas: usize) {
        self.member_buf.reserve(new_replicas);
        self.group_buf.reserve(new_replicas);
        self.cfg.mesh = MeshSpec::new(self.cfg.mesh.shard, new_replicas);
        self.step_model.mesh = self.cfg.mesh;
        self.detector.resize_replicas(new_replicas);
        self.scratch.ensure_replicas(new_replicas);
        if self.cfg.shard_outer && self.cfg.spec.layerwise() && new_replicas > 1 {
            // Re-partition the outer shards for the new sync-group size.
            self.scratch.enable_sharding(&self.table, new_replicas);
        } else {
            // Down to one replica (or sharding off): the full-matrix
            // path resumes; restore its buffers if lanes were active.
            self.scratch.disable_sharding();
        }
        self.plan = sync::CommPlan::build(
            &self.step_model,
            &self.cfg.spec,
            &self.table,
            self.cfg.shard_outer,
        );
    }

    /// Mean validation loss over `eval_batches` held-out batches.
    pub fn evaluate(&mut self) -> Result<f64> {
        let [b, s1] = self.engine.manifest.token_shape;
        let mut total = 0.0f64;
        for i in 0..self.cfg.eval_batches {
            let batch =
                self.corpus
                    .batch_i32(Split::Validation(0), 0, i as u64, b, s1);
            total += self.engine.eval_step(&self.anchor, &batch)? as f64;
            self.pjrt_calls += 1;
        }
        Ok(total / self.cfg.eval_batches as f64)
    }

    /// PPL on every probe stream (the Table-1 substitute).
    pub fn probe_ppls(&mut self) -> Result<Vec<(&'static str, f64)>> {
        let [b, s1] = self.engine.manifest.token_shape;
        let mut out = Vec::new();
        for probe in crate::data::probe::Probe::ALL {
            let mut total = 0.0f64;
            let reps = self.cfg.eval_batches.max(2);
            for i in 0..reps {
                let batch = probe.batch_i32(&self.corpus, b, s1, i as u64);
                total += self.engine.eval_step(&self.anchor, &batch)? as f64;
                self.pjrt_calls += 1;
            }
            out.push((probe.name(), (total / reps as f64).exp()));
        }
        Ok(out)
    }

    /// Run to `total_steps`, returning the summary. On exit, any CO2
    /// staleness-queue updates still in flight are flushed into the
    /// anchor (they were combined and their communication charged — the
    /// historical behavior silently dropped them).
    pub fn run(&mut self) -> Result<RunSummary> {
        while self.global_step < self.cfg.total_steps {
            if self.in_warmup() {
                self.ddp_step()?;
            } else {
                self.local_round()?;
                self.maybe_checkpoint()?;
            }
        }
        sync::flush_pending(self)?;
        // Final eval if none recorded yet.
        if self.tracker.val_ppl.is_empty() {
            let val = self.evaluate()?;
            self.tracker.record_val(self.global_step, val);
        }
        Ok(self.summary())
    }

    /// Run exactly one unit of progress (one DDP step or one round) —
    /// the elastic driver uses this to interleave rescaling. Does NOT
    /// flush the CO2 staleness queue (see [`Trainer::run`]).
    pub fn run_round(&mut self) -> Result<()> {
        if self.in_warmup() {
            self.ddp_step()
        } else {
            self.local_round()
        }
    }

    pub fn summary(&self) -> RunSummary {
        let tokens_per_call = self.engine.manifest.tokens_per_step() as u64;
        let train_calls: u64 = self.replicas.iter().map(|r| r.inner_steps).sum();
        let tokens = train_calls * tokens_per_call;
        RunSummary {
            label: self.cfg.label.clone(),
            final_loss: self.tracker.final_loss().unwrap_or(f64::NAN),
            final_ppl: self.tracker.final_ppl().unwrap_or(f64::NAN),
            sim_seconds: self.sim_time,
            tokens,
            throughput: if self.sim_time > 0.0 {
                tokens as f64 / self.sim_time
            } else {
                0.0
            },
            syncs: self.syncs,
            anomalies: self.detector.anomalies_flagged,
            rollbacks: self.detector.rollbacks,
            max_staleness: self.max_staleness,
            flushed_updates: self.flushed_updates,
            crashes: self.crashes,
            rejoins: self.rejoins,
            evictions: self.evictions,
            degraded_syncs: self.degraded_syncs,
            comm: self.comm.clone(),
        }
    }

    /// Local rounds completed (the fault plan's round key and the
    /// `--checkpoint-every` cadence unit).
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Per-replica liveness under the fault harness (all true without
    /// one).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// CO2 staleness-queue updates currently in flight.
    pub fn pending_updates(&self) -> usize {
        self.pending.len()
    }

    /// Periodic checkpoint at a round boundary (`cfg.checkpoint_every`).
    fn maybe_checkpoint(&mut self) -> Result<()> {
        if self.cfg.checkpoint_every == 0 || self.rounds % self.cfg.checkpoint_every != 0 {
            return Ok(());
        }
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            anyhow::bail!("checkpoint_every is set but checkpoint_dir is not");
        };
        let path = dir.join(format!("ckpt-round-{:06}.bin", self.rounds));
        self.save_checkpoint(&path)
    }

    /// Elastic rescale to `new_replicas` columns (Fig. 6c): new replicas
    /// clone the synchronized parameters; leaving replicas are dropped.
    /// Outer momentum and anomaly statistics persist. The event queue is
    /// drained (rescaling is a rendezvous: callers rescale at round
    /// boundaries, where every sync event has already been processed,
    /// and all clocks re-align to the current simulated time).
    pub fn rescale(&mut self, new_replicas: usize) -> Result<()> {
        anyhow::ensure!(new_replicas > 0);
        // A real error (not just a debug assert): silently rescaling on
        // a dirty queue would drop pending sync contributions in release
        // builds. Mid-round membership changes go through the fault
        // plan's live evict/join path instead.
        anyhow::ensure!(
            self.events.is_empty(),
            "rescale with undrained sync events (mid-round rescale?)"
        );
        self.group_buf.clear();
        // Synchronize state into the anchor first if mid-round divergence
        // exists (callers rescale at round boundaries; anchor is current).
        let template = Replica::new(self.anchor.clone());
        let adam_t = self.replicas[0].adam_t;
        let clock = self.sim_time;
        let loss_capacity = self.loss_capacity;
        self.replicas.resize_with(new_replicas, || {
            let mut r = template.clone();
            r.losses.reserve(loss_capacity);
            r.adam_t = adam_t;
            r.clock = clock;
            r
        });
        for r in &mut self.replicas {
            r.params.copy_from_slice(&self.anchor);
            r.clock = clock;
        }
        let [b, s1] = self.engine.manifest.token_shape;
        let token_cap = b * s1;
        self.lanes
            .resize_with(new_replicas, || worker::Lane::with_token_capacity(token_cap));
        // Joining replicas start "fresh" at the current anchor version.
        self.last_sync_version.resize(new_replicas, self.anchor_version);
        // A rescale is a full-cluster rendezvous: everyone present is
        // alive and unbudgeted afterwards.
        self.alive.clear();
        self.alive.resize(new_replicas, true);
        self.fault_caps.clear();
        self.fault_caps.resize(new_replicas, u64::MAX);
        self.pending_crash.clear();
        self.evict_charge = false;
        self.refresh_topology(new_replicas);
        Ok(())
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// The sync scratch arena (memory accounting / tests).
    pub fn scratch(&self) -> &SyncScratch {
        &self.scratch
    }

    /// Per-rank high-water of the sharded sync state: the rank's shard
    /// lane (Δ rows, combine buffer, scalar partials) plus its anchor
    /// and outer-momentum shards. Max over ranks; 0 when `shard_outer`
    /// is off. Asserted ≈ [`Self::unsharded_sync_footprint`] ÷ N by
    /// `tests/sharded_sync.rs`.
    pub fn shard_sync_high_water(&self) -> usize {
        let parts = self.scratch.shard_parts();
        (0..parts)
            .map(|s| {
                let (_, len) = self.scratch.shard_range(s);
                let anchor = len * 4;
                let momentum = self.outer.state_elems(len) * 4;
                self.scratch.shard_rank_bytes(s) + anchor + momentum
            })
            .max()
            .unwrap_or(0)
    }

    /// The full-matrix sync footprint the sharded path divides across
    /// ranks: the Δ matrix (replicas × P), the anchor and the outer
    /// state, in bytes.
    pub fn unsharded_sync_footprint(&self) -> usize {
        let n = self.num_params();
        (self.cfg.mesh.replicas * n + n + self.outer.state_elems(n)) * 4
    }
}
