//! The local-SGD training engine (Alg. 1) — one engine, seven methods.
//!
//! Numerics model (DESIGN.md §4): each *column* of the M×N mesh (a model
//! shard group) keeps bitwise-identical parameters at every inner step
//! (per-step gradient averaging inside the column), so the engine
//! simulates one logical replica per column.  Each replica executes the
//! fused AOT train step (fwd+bwd+AdamW — Layers 2/1) through PJRT, and
//! the coordinator (Layer 3) owns everything across replicas: warmup
//! DDP, periodic synchronization, the pseudo-gradient penalty, outer
//! optimization, rollbacks, elastic rescaling, and the simulated-clock
//! accounting that turns collective volumes into throughput numbers via
//! the shared α-β cost model.
//!
//! Virtual time: every replica carries a clock (seconds).  Inner steps
//! advance it by `StepModel::inner_step` plus injected straggler lag;
//! synchronization is a barrier at `max(clocks) + sync_exposed`.  A-EDiT
//! replaces the fixed-τ trigger with a deadline of `τ_time` seconds, so
//! fast replicas genuinely run more inner steps per round (§3.3).
//!
//! Hot-path discipline: all per-round buffers live in the
//! [`SyncScratch`] arena and all per-round communication charges and
//! step timings are precomputed in a [`CommPlan`], so `synchronize()`,
//! `ddp_step()` and `inner_step()` perform **zero heap allocations** in
//! steady state (asserted by `tests/sync_steady_state.rs`).  The sync
//! round itself is a single fused pass per module — pseudo-gradient +
//! norm, weighted combine + norm, clip-β folded into the outer apply —
//! instead of the historical collect-then-scatter shape.

use anyhow::Result;

use crate::collectives::{CollOp, CommStats};
use crate::data::{Corpus, Split};
use crate::metrics::RunTracker;
use crate::runtime::Engine;
use crate::simulator::stepmodel::StepModel;
use crate::tensor::ModuleTable;
use crate::util::prng::Rng;

use super::mesh::MeshSpec;
use super::method::Method;
use super::outer::{OuterOpt, OuterOptKind};
use super::penalty::{AnomalyDetector, PenaltyConfig};
use super::schedule::LrSchedule;
use super::scratch::SyncScratch;

/// Upper bound on the per-replica loss-trace reservation (entries; 16 B
/// each ⇒ 16 MB per replica). Up to this many inner steps the trace
/// never reallocates — the boundary of the steady-state zero-allocation
/// invariant for very long runs.
pub const LOSS_TRACE_CAP: u64 = 1 << 20;

/// Straggler injection (paper §4.3, Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Straggler {
    None,
    /// A uniformly random replica lags by `lag` seconds each inner step.
    Random { lag: f64 },
    /// A fixed replica lags by `lag` seconds each inner step.
    Consistent { lag: f64, replica: usize },
}

/// Fault injection: a "sick worker" whose state diverges (perturbed by
/// Gaussian noise each inner step) for a window of sync rounds — the
/// scenario behind the paper's Fig. 7b/c per-worker loss spikes.
/// Exercises anomaly elimination / weighted suppression / clipping /
/// rollback end to end.
///
/// Note on the fault model: with AdamW as the inner optimizer,
/// low-quality *data* barely moves the pseudo-gradient norm at our
/// compressed scale (Adam normalizes per-coordinate step sizes), so the
/// harness injects the downstream symptom directly — a worker whose
/// parameters drift anomalously — which is what the z-test screens for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Poison {
    /// Poisoned replica, or `usize::MAX` for ALL replicas (rollback path).
    pub replica: usize,
    pub from_sync: u64,
    pub to_sync: u64,
    /// Std-dev of the per-step parameter perturbation.
    pub strength: f32,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    pub mesh: MeshSpec,
    /// Synchronization interval in inner steps (τ).
    pub tau: u64,
    /// Time-based interval for A-EDiT (τ_time, simulated seconds).
    pub tau_time: f64,
    /// Warmup (mini-batch DDP) inner steps, Alg. 1's t_warm.
    pub t_warm: u64,
    /// Experiment length in global inner steps.
    pub total_steps: u64,
    pub inner_lr: LrSchedule,
    pub outer: OuterOptKind,
    pub penalty: PenaltyConfig,
    pub seed: u64,
    /// Evaluate validation PPL every this many syncs (0 = never).
    pub eval_every_syncs: u64,
    pub eval_batches: usize,
    pub straggler: Straggler,
    pub poison: Vec<Poison>,
    /// Pure compute seconds per inner step per worker (virtual clock).
    pub base_step_time: f64,
    /// Print a progress line every N syncs (0 = silent).
    pub log_every: u64,
}

impl TrainConfig {
    /// Paper-shaped defaults scaled to the CPU-trainable regime.
    pub fn paper_default(method: Method, mesh: MeshSpec, total_steps: u64) -> Self {
        Self {
            method,
            mesh,
            tau: 16,
            tau_time: 16.0 * 0.5,
            t_warm: if method.uses_warmup() { 16 } else { 0 },
            total_steps,
            inner_lr: LrSchedule::paper_cosine(
                if method.is_local_sgd() { 1.5e-3 } else { 3e-3 },
                total_steps,
            ),
            outer: method.default_outer(),
            penalty: method.default_penalty(),
            seed: 42,
            eval_every_syncs: 4,
            eval_batches: 4,
            straggler: Straggler::None,
            poison: Vec::new(),
            base_step_time: 0.5,
            log_every: 0,
        }
    }
}

/// One logical replica (= one model shard group / mesh column).
#[derive(Debug, Clone)]
pub struct Replica {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based AdamW step counter (bias correction).
    pub adam_t: i32,
    /// Virtual clock, seconds.
    pub clock: f64,
    /// Inner steps completed (also the data-stream cursor).
    pub inner_steps: u64,
    /// (global_step, loss) trace — Fig. 7b/c per-worker curves.
    pub losses: Vec<(u64, f32)>,
}

impl Replica {
    fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        Self {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_t: 0,
            clock: 0.0,
            inner_steps: 0,
            losses: Vec::new(),
        }
    }
}

/// End-of-run summary (the numbers the experiment tables consume).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub method: Method,
    pub final_loss: f64,
    pub final_ppl: f64,
    pub sim_seconds: f64,
    pub tokens: u64,
    /// tokens / simulated second across the whole cluster.
    pub throughput: f64,
    pub syncs: u64,
    pub anomalies: u64,
    pub rollbacks: u64,
    pub comm: CommStats,
}

/// Precomputed per-round communication charges and step timings.
///
/// `MeshSpec::sync_group`/`shard_group` allocate rank vectors and the
/// α-β formulas are pure functions of (mesh, cost, param bytes), so the
/// trainer resolves them once at construction (and again after an
/// elastic rescale) instead of per step / per module. This is also the
/// fix for the historical accounting bug: *every* sync group row and
/// *every* shard group column is charged, not just group 0.
#[derive(Debug, Clone, Default)]
struct CommPlan {
    /// (bytes, seconds) of one shard all-reduce per mesh row (sync group).
    sync_allreduce: Vec<(usize, f64)>,
    /// (bytes, seconds) of one scalar-norm exchange per mesh column
    /// (shard group) — charged once per module during EDiT sync.
    scalar_sync: Vec<(usize, f64)>,
    /// Simulated duration of one local / one DDP inner step.
    step_time_local: f64,
    step_time_ddp: f64,
    /// Exposed sync barrier cost for the configured method.
    sync_exposed: f64,
}

impl CommPlan {
    fn build(step_model: &StepModel, method: Method, param_count: usize) -> Self {
        let mesh = step_model.mesh;
        let shard_bytes = param_count * 4 / mesh.shard;
        let mut plan = CommPlan {
            step_time_local: step_model.inner_step(false),
            step_time_ddp: step_model.inner_step(true),
            sync_exposed: step_model.sync_exposed(method),
            ..Default::default()
        };
        for row in 0..mesh.shard {
            let group = mesh.sync_group(row);
            plan.sync_allreduce.push((
                shard_bytes,
                step_model.cost.time(CollOp::AllReduce, shard_bytes, &group),
            ));
        }
        for col in 0..mesh.replicas {
            let group = mesh.shard_group(col);
            plan.scalar_sync
                .push((4, step_model.cost.time(CollOp::ScalarSync, 4, &group)));
        }
        plan
    }
}

pub struct Trainer {
    pub cfg: TrainConfig,
    engine: Engine,
    corpus: Corpus,
    table: ModuleTable,
    pub replicas: Vec<Replica>,
    /// θ_t — last synchronized parameters (identical across replicas).
    pub anchor: Vec<f32>,
    outer: OuterOpt,
    detector: AnomalyDetector,
    /// CO2 staleness queue of combined-but-unapplied updates.
    pending: std::collections::VecDeque<Vec<f32>>,
    step_model: StepModel,
    rng: Rng,
    pub tracker: RunTracker,
    pub comm: CommStats,
    pub sim_time: f64,
    pub global_step: u64,
    pub syncs: u64,
    pjrt_calls: u64,
    /// `EDIT_DEBUG_NORMS` read once at construction (the per-module
    /// env lookup used to sit inside the sync hot loop).
    debug_norms: bool,
    /// Per-replica loss-trace capacity reserved up front so steady-state
    /// recording never reallocates.
    loss_capacity: usize,
    plan: CommPlan,
    // reusable scratch
    grad_buf: Vec<f32>,
    grad_acc: Vec<f32>,
    scratch: SyncScratch,
}

impl Trainer {
    pub fn new(engine: Engine, corpus: Corpus, cfg: TrainConfig, cost: crate::collectives::CostModel) -> Result<Self> {
        anyhow::ensure!(
            corpus.language.vocab() == engine.manifest.model.vocab_size,
            "corpus vocab {} != model vocab {}",
            corpus.language.vocab(),
            engine.manifest.model.vocab_size
        );
        let init = engine.init_params()?;
        let n = init.len();
        let table = engine.manifest.table.clone();
        // Loss-trace reservation: total_steps plus one round of A-EDiT
        // slack (fast replicas run up to 4τ extra steps). The cap bounds
        // memory for open-ended runs (total_steps = u64::MAX) at 16 MB
        // per replica; it is also the stated bound of the zero-allocation
        // invariant — runs past LOSS_TRACE_CAP inner steps reallocate the
        // trace amortized (see `coordinator::scratch` docs).
        let loss_capacity = cfg
            .total_steps
            .saturating_add(cfg.tau.saturating_mul(4))
            .min(LOSS_TRACE_CAP) as usize;
        let replicas: Vec<Replica> = (0..cfg.mesh.replicas)
            .map(|_| {
                let mut r = Replica::new(init.clone());
                r.losses.reserve(loss_capacity);
                r
            })
            .collect();
        let detector =
            AnomalyDetector::new(cfg.mesh.replicas, table.num_modules(), cfg.penalty);
        let step_model = StepModel {
            mesh: cfg.mesh,
            cost,
            param_bytes: n * 4,
            compute: cfg.base_step_time,
            cpu_offload: false,
        };
        let rng = Rng::new(cfg.seed ^ 0x7123_55AA);
        let [b, s1] = engine.manifest.token_shape;
        let scratch = SyncScratch::new(&table, cfg.mesh.replicas, b * s1);
        let plan = CommPlan::build(&step_model, cfg.method, n);
        let mut tracker = RunTracker::new();
        // The tracker records once per round for step-synced local-SGD
        // methods (plus once per warmup DDP step), so reserving per-step
        // capacity would overshoot by ~τ. Baseline records every step and
        // A-EDiT's steps-per-round varies (1..4τ), so both keep the
        // conservative per-step bound.
        let tracker_capacity = if cfg.method.is_local_sgd() && !cfg.method.time_based_sync() {
            cfg.t_warm
                .saturating_add(
                    cfg.total_steps.saturating_sub(cfg.t_warm) / cfg.tau.max(1),
                )
                .saturating_add(2)
                .min(LOSS_TRACE_CAP) as usize
        } else {
            loss_capacity
        };
        tracker.reserve(tracker_capacity);
        Ok(Self {
            outer: OuterOpt::new(cfg.outer, n),
            detector,
            pending: Default::default(),
            step_model,
            rng,
            tracker,
            comm: CommStats::default(),
            sim_time: 0.0,
            global_step: 0,
            syncs: 0,
            pjrt_calls: 0,
            debug_norms: std::env::var("EDIT_DEBUG_NORMS").is_ok(),
            loss_capacity,
            plan,
            grad_buf: vec![0.0; n],
            grad_acc: vec![0.0; n],
            scratch,
            anchor: init,
            replicas,
            table,
            corpus,
            engine,
            cfg,
        })
    }

    pub fn num_params(&self) -> usize {
        self.anchor.len()
    }

    pub fn pjrt_calls(&self) -> u64 {
        self.pjrt_calls
    }

    /// Fill the scratch token buffer with the batch for (replica, step).
    /// Batch row r draws from physical worker (row = r mod M, col = j):
    /// the column's M data-parallel workers interleave into the
    /// effective column batch.
    fn fill_batch(&mut self, replica: usize, step: u64) {
        let [b, s1] = self.engine.manifest.token_shape;
        let m = self.cfg.mesh.shard;
        self.scratch.tokens.clear();
        for r in 0..b {
            let worker = self.cfg.mesh.rank(r % m, replica);
            self.corpus.sequence_into(
                Split::Train,
                worker,
                step,
                r / m,
                s1,
                &mut self.scratch.tokens,
            );
        }
    }

    fn straggler_lag(&mut self, replica: usize) -> f64 {
        match self.cfg.straggler {
            Straggler::None => 0.0,
            Straggler::Random { lag } => {
                let victim = self.rng.below(self.cfg.mesh.replicas as u64) as usize;
                if victim == replica { lag } else { 0.0 }
            }
            Straggler::Consistent { lag, replica: r } => {
                if r == replica { lag } else { 0.0 }
            }
        }
    }

    fn in_warmup(&self) -> bool {
        self.cfg.method == Method::Baseline
            || (self.cfg.method.uses_warmup() && self.global_step < self.cfg.t_warm)
    }

    /// One synchronous mini-batch DDP step (Baseline & warmup phase).
    /// Replicas stay bitwise identical: gradients are averaged across
    /// the whole mesh and applied once, then copied.
    fn ddp_step(&mut self) -> Result<()> {
        let lr = self.cfg.inner_lr.at(self.global_step) as f32;
        let n = self.replicas.len();
        self.grad_acc.fill(0.0);
        let mut mean_loss = 0.0f64;
        for j in 0..n {
            self.fill_batch(j, self.replicas[j].inner_steps);
            let out = self.engine.grad_step(
                &self.replicas[j].params,
                &self.scratch.tokens,
                &mut self.grad_buf,
            )?;
            self.pjrt_calls += 1;
            crate::tensor::axpy(&mut self.grad_acc, 1.0 / n as f32, &self.grad_buf);
            mean_loss += out.loss as f64 / n as f64;
            let gs = self.global_step;
            self.replicas[j].losses.push((gs, out.loss));
        }
        // Gradient all-reduce: each worker all-reduces its grad shard
        // across its sync group — one charge per mesh row.
        for &(bytes, secs) in &self.plan.sync_allreduce {
            self.comm.record(bytes, secs);
        }

        // Apply once, copy to all replicas (they are identical under DDP).
        let adam_t = self.replicas[0].adam_t + 1;
        {
            let r0 = &mut self.replicas[0];
            r0.adam_t = adam_t;
        }
        let (first, rest) = self.replicas.split_at_mut(1);
        let r0 = &mut first[0];
        self.engine.apply_step(
            &mut r0.params,
            &mut r0.m,
            &mut r0.v,
            &self.grad_acc,
            lr,
            adam_t,
        )?;
        self.pjrt_calls += 1;
        for r in rest.iter_mut() {
            r.params.copy_from_slice(&r0.params);
            r.m.copy_from_slice(&r0.m);
            r.v.copy_from_slice(&r0.v);
            r.adam_t = adam_t;
        }
        // Clocks: everyone waits for the slowest (synchronous step).
        let step_time = self.plan.step_time_ddp;
        let mut max_clock: f64 = 0.0;
        for j in 0..self.replicas.len() {
            let lag = self.straggler_lag(j);
            let r = &mut self.replicas[j];
            r.clock += step_time + lag;
            r.inner_steps += 1;
            max_clock = max_clock.max(r.clock);
        }
        for r in &mut self.replicas {
            r.clock = max_clock;
        }
        self.sim_time = max_clock;
        self.global_step += 1;
        self.tracker.record_loss(self.global_step, mean_loss);
        // The anchor tracks the (shared) parameters during DDP/warmup.
        self.anchor.copy_from_slice(&self.replicas[0].params);
        Ok(())
    }

    /// One local inner step on replica `j`; returns its loss.
    fn inner_step(&mut self, j: usize) -> Result<f32> {
        let min_steps = self.replicas.iter().map(|r| r.inner_steps).min().unwrap_or(0);
        let step_for_lr = self.global_step + (self.replicas[j].inner_steps - min_steps);
        let lr = self.cfg.inner_lr.at(step_for_lr.min(self.cfg.total_steps)) as f32;
        self.fill_batch(j, self.replicas[j].inner_steps);
        let lag = self.straggler_lag(j);
        let step_time = self.plan.step_time_local;
        let r = &mut self.replicas[j];
        r.adam_t += 1;
        let adam_t = r.adam_t;
        let out = self.engine.train_step(
            &mut r.params,
            &mut r.m,
            &mut r.v,
            &self.scratch.tokens,
            lr,
            adam_t,
        )?;
        self.pjrt_calls += 1;
        // Fault injection: corrupt the sick replica's state (see Poison).
        for p in &self.cfg.poison {
            let sick = p.replica == usize::MAX || p.replica == j;
            if sick && self.syncs >= p.from_sync && self.syncs < p.to_sync {
                let mut prng = Rng::new(crate::util::prng::mix(
                    self.cfg.seed ^ 0xBAD,
                    (j as u64) << 32 | r.inner_steps,
                ));
                for x in r.params.iter_mut() {
                    *x += p.strength * prng.normal_f32();
                }
            }
        }
        r.clock += step_time + lag;
        r.inner_steps += 1;
        let gs = self.global_step + 1;
        r.losses.push((gs, out.loss));
        Ok(out.loss)
    }

    /// One local-SGD round: τ inner steps per replica (or τ_time worth
    /// for A-EDiT), then synchronization.
    fn local_round(&mut self) -> Result<()> {
        let n = self.replicas.len();
        let mut loss_sum = 0.0f64;
        let mut loss_count = 0u64;
        let mut max_steps = 0u64;

        if self.cfg.method.time_based_sync() {
            let deadline = self.sim_time + self.cfg.tau_time;
            for j in 0..n {
                let mut steps = 0u64;
                while (self.replicas[j].clock < deadline || steps == 0)
                    && steps < self.cfg.tau * 4
                {
                    loss_sum += self.inner_step(j)? as f64;
                    loss_count += 1;
                    steps += 1;
                }
                max_steps = max_steps.max(steps);
            }
        } else {
            let remaining = self.cfg.total_steps.saturating_sub(self.global_step);
            let tau = self.cfg.tau.min(remaining.max(1));
            for j in 0..n {
                for _ in 0..tau {
                    loss_sum += self.inner_step(j)? as f64;
                    loss_count += 1;
                }
            }
            max_steps = tau;
        }

        self.global_step += max_steps;
        let mean_loss = loss_sum / loss_count.max(1) as f64;
        self.tracker.record_loss(self.global_step, mean_loss);
        self.synchronize()?;
        Ok(())
    }

    /// The outer synchronization (Alg. 1 lines 7-9 / Alg. 2): one fused
    /// pass per module over the scratch arena — no allocations, no
    /// collect-then-scatter staging.
    fn synchronize(&mut self) -> Result<()> {
        let n = self.replicas.len();
        self.scratch.ensure_replicas(n);

        // Communication accounting: each worker all-reduces its parameter
        // shard across its sync group — one charge per mesh row.
        for &(bytes, secs) in &self.plan.sync_allreduce {
            self.comm.record(bytes, secs);
        }

        let mut rollbacks = 0u64;
        if self.cfg.method.uses_penalty() {
            self.detector.set_config(self.cfg.penalty);
            // Layer-wise EDiT sync: per-module screen → combine → outer.
            // Module ranges partition the flat vector and each apply only
            // touches its own module, so computing Δ lazily per module
            // from the in-place-updated anchor is exact.
            for module in 0..self.table.num_modules() {
                {
                    let replicas = &self.replicas;
                    self.scratch.load_module(
                        module,
                        |j| replicas[j].params.as_slice(),
                        &self.anchor,
                    );
                }
                if self.debug_norms {
                    eprintln!(
                        "sync {} module {module}: norms {:?}",
                        self.syncs,
                        self.scratch.norms()
                    );
                }
                {
                    let (norms, screened) = self.scratch.screen_buffers();
                    self.detector.screen_into(module, norms, screened);
                }
                // Scalar norm exchange in every shard group (cheap).
                for &(bytes, secs) in &self.plan.scalar_sync {
                    self.comm.record(bytes, secs);
                }
                if !self.scratch.compute_weights(self.cfg.penalty.weighted_averaging) {
                    rollbacks += 1;
                    continue; // θ stays at anchor for this module (rollback)
                }
                // Fused weighted combine + module norm, then the outer
                // apply with clip-β folded in.
                let module_sq = self.scratch.combine_module(module);
                let mut beta = 1.0f64;
                if self.cfg.penalty.gradient_clip {
                    let norm = module_sq.sqrt();
                    beta = (self.cfg.penalty.phi / (norm + self.cfg.penalty.eps)).min(1.0);
                }
                self.scratch
                    .apply_module(module, &mut self.outer, &mut self.anchor, beta as f32);
            }
            self.detector.advance();
        } else {
            // Uniform averaging (PLS/DiLoCo/CO2): mean pseudo gradient.
            {
                let replicas = &self.replicas;
                self.scratch
                    .load_full(|j| replicas[j].params.as_slice(), &self.anchor);
            }
            let staleness = self.cfg.method.outer_staleness();
            if staleness == 0 {
                let mean = self.scratch.mean_deltas();
                self.outer.apply(&mut self.anchor, mean);
            } else {
                // CO2: apply the update combined `staleness` rounds ago.
                // Queue buffers are recycled through the scratch free list.
                let mut buf = self.scratch.take_spare();
                self.scratch.mean_deltas_into(&mut buf);
                self.pending.push_back(buf);
                if self.pending.len() > staleness {
                    let stale = self.pending.pop_front().unwrap();
                    self.outer.apply(&mut self.anchor, &stale);
                    self.scratch.put_spare(stale);
                }
            }
        }

        // All replicas adopt the synchronized parameters.
        for r in &mut self.replicas {
            r.params.copy_from_slice(&self.anchor);
        }

        // Clock barrier + exposed sync cost.
        let max_clock = self
            .replicas
            .iter()
            .map(|r| r.clock)
            .fold(0.0f64, f64::max);
        let after = max_clock + self.plan.sync_exposed;
        for r in &mut self.replicas {
            r.clock = after;
        }
        self.sim_time = after;
        self.syncs += 1;

        if self.cfg.eval_every_syncs > 0 && self.syncs % self.cfg.eval_every_syncs == 0 {
            let val = self.evaluate()?;
            self.tracker.record_val(self.global_step, val);
        }
        if self.cfg.log_every > 0 && self.syncs % self.cfg.log_every == 0 {
            eprintln!(
                "[{}] step {:>6} sync {:>4} loss {:.4} ppl {:.2} simtime {:.1}s",
                self.cfg.method.name(),
                self.global_step,
                self.syncs,
                self.tracker.losses.last().map(|x| x.1).unwrap_or(f64::NAN),
                self.tracker.val_ppl.last().map(|x| x.1).unwrap_or(f64::NAN),
                self.sim_time,
            );
        }
        if rollbacks > 0 {
            self.detector.rollbacks += rollbacks;
        }
        Ok(())
    }

    /// Mean validation loss over `eval_batches` held-out batches.
    pub fn evaluate(&mut self) -> Result<f64> {
        let [b, s1] = self.engine.manifest.token_shape;
        let mut total = 0.0f64;
        for i in 0..self.cfg.eval_batches {
            let batch =
                self.corpus
                    .batch_i32(Split::Validation(0), 0, i as u64, b, s1);
            total += self.engine.eval_step(&self.anchor, &batch)? as f64;
            self.pjrt_calls += 1;
        }
        Ok(total / self.cfg.eval_batches as f64)
    }

    /// PPL on every probe stream (the Table-1 substitute).
    pub fn probe_ppls(&mut self) -> Result<Vec<(&'static str, f64)>> {
        let [b, s1] = self.engine.manifest.token_shape;
        let mut out = Vec::new();
        for probe in crate::data::probe::Probe::ALL {
            let mut total = 0.0f64;
            let reps = self.cfg.eval_batches.max(2);
            for i in 0..reps {
                let batch = probe.batch_i32(&self.corpus, b, s1, i as u64);
                total += self.engine.eval_step(&self.anchor, &batch)? as f64;
                self.pjrt_calls += 1;
            }
            out.push((probe.name(), (total / reps as f64).exp()));
        }
        Ok(out)
    }

    /// Run to `total_steps`, returning the summary.
    pub fn run(&mut self) -> Result<RunSummary> {
        while self.global_step < self.cfg.total_steps {
            if self.in_warmup() {
                self.ddp_step()?;
            } else {
                self.local_round()?;
            }
        }
        // Final eval if none recorded yet.
        if self.tracker.val_ppl.is_empty() {
            let val = self.evaluate()?;
            self.tracker.record_val(self.global_step, val);
        }
        Ok(self.summary())
    }

    /// Run exactly one unit of progress (one DDP step or one round) —
    /// the elastic driver uses this to interleave rescaling.
    pub fn run_round(&mut self) -> Result<()> {
        if self.in_warmup() {
            self.ddp_step()
        } else {
            self.local_round()
        }
    }

    pub fn summary(&self) -> RunSummary {
        let tokens_per_call = self.engine.manifest.tokens_per_step() as u64;
        let train_calls: u64 = self.replicas.iter().map(|r| r.inner_steps).sum();
        let tokens = train_calls * tokens_per_call;
        RunSummary {
            method: self.cfg.method,
            final_loss: self.tracker.final_loss().unwrap_or(f64::NAN),
            final_ppl: self.tracker.final_ppl().unwrap_or(f64::NAN),
            sim_seconds: self.sim_time,
            tokens,
            throughput: if self.sim_time > 0.0 {
                tokens as f64 / self.sim_time
            } else {
                0.0
            },
            syncs: self.syncs,
            anomalies: self.detector.anomalies_flagged,
            rollbacks: self.detector.rollbacks,
            comm: self.comm.clone(),
        }
    }

    /// Elastic rescale to `new_replicas` columns (Fig. 6c): new replicas
    /// clone the synchronized parameters; leaving replicas are dropped.
    /// Outer momentum and anomaly statistics persist.
    pub fn rescale(&mut self, new_replicas: usize) -> Result<()> {
        anyhow::ensure!(new_replicas > 0);
        // Synchronize state into the anchor first if mid-round divergence
        // exists (callers rescale at round boundaries; anchor is current).
        let template = Replica::new(self.anchor.clone());
        let adam_t = self.replicas[0].adam_t;
        let clock = self.sim_time;
        let loss_capacity = self.loss_capacity;
        self.replicas.resize_with(new_replicas, || {
            let mut r = template.clone();
            r.losses.reserve(loss_capacity);
            r.adam_t = adam_t;
            r.clock = clock;
            r
        });
        for r in &mut self.replicas {
            r.params.copy_from_slice(&self.anchor);
            r.clock = clock;
        }
        self.cfg.mesh = MeshSpec::new(self.cfg.mesh.shard, new_replicas);
        self.step_model.mesh = self.cfg.mesh;
        self.detector.resize_replicas(new_replicas);
        self.scratch.ensure_replicas(new_replicas);
        self.plan = CommPlan::build(&self.step_model, self.cfg.method, self.num_params());
        Ok(())
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }
}
