//! Layer 3 — the paper's coordination contribution.
//!
//! * [`mesh`]     — the M×N device mesh (shard groups × sync groups);
//! * [`spec`]     — `MethodSpec`, the compositional strategy descriptor
//!                  (sync trigger / granularity / outer opt / staleness
//!                  / penalty / sharding / warmup axes) every consumer
//!                  dispatches on, plus the `custom:` method grammar;
//! * [`method`]   — the named-preset table (EDiT, A-EDiT, PALSGD and
//!                  the baselines) over `MethodSpec`;
//! * [`engine`]   — the local-SGD training engine (Alg. 1): a thin
//!                  facade over the event-driven per-replica execution
//!                  core (`engine/clock.rs` scheduler, `engine/worker.rs`
//!                  lanes, `engine/sync.rs` barrier + anchor sync paths)
//!                  with virtual clocks, straggler injection, parallel
//!                  worker threads and elastic rescaling;
//! * [`penalty`]  — the pseudo-gradient penalty (Alg. 2): EMA z-test
//!                  anomaly elimination, softmax(-norm) weighted
//!                  averaging, pseudo-gradient clipping, rollback;
//! * [`outer`]    — outer optimizers (SGD / Nesterov over pseudo grads);
//! * [`schedule`] — inner LR schedules;
//! * [`scratch`]  — the preallocated `SyncScratch` arena behind the
//!                  zero-allocation synchronization pipeline.

pub mod engine;
pub mod mesh;
pub mod method;
pub mod outer;
pub mod penalty;
pub mod schedule;
pub mod scratch;
pub mod spec;

pub use engine::{Poison, Replica, RunSummary, Straggler, TrainConfig, Trainer};
pub use mesh::MeshSpec;
pub use method::Method;
pub use outer::{OuterOpt, OuterOptKind};
pub use penalty::{AnomalyDetector, PenaltyConfig};
pub use schedule::LrSchedule;
pub use scratch::SyncScratch;
pub use spec::{MethodSpec, PayloadKind, SyncGranularity, SyncTrigger};
