//! `SyncScratch` — the preallocated arena behind the zero-allocation
//! synchronization pipeline.
//!
//! # Ownership rules
//!
//! One `SyncScratch` is owned by each [`super::engine::Trainer`] and
//! lives as long as the trainer. Every buffer inside it is sized once
//! (at construction, or at [`Self::ensure_replicas`] after an elastic
//! rescale) and then only `clear()`ed / overwritten, so after the first
//! full round at a given mesh size — "warm-up" — the trainer's
//! `synchronize()`, `ddp_step()` and `inner_step()` perform **zero heap
//! allocations**. `tests/sync_steady_state.rs` asserts this with a
//! counting global allocator.
//!
//! One stated bound: the per-replica loss traces are reserved up front
//! for `min(total_steps + 4τ, LOSS_TRACE_CAP = 2^20)` entries. Runs
//! whose replicas exceed 2^20 inner steps reallocate the trace
//! (amortized doubling) — a deliberate memory/garbage trade-off for
//! open-ended runs, outside the invariant.
//!
//! Contents:
//!  * the pseudo-gradient matrix Δ (row j = replica j, one flat
//!    row-major `Vec<f32>` so per-module combines read strided rows
//!    without materializing `Vec<&[f32]>` views);
//!  * the module-contiguous combine buffer (max module length) that the
//!    per-range weighted sums land in before the outer apply;
//!  * per-replica norm / screened-norm / weight vectors;
//!  * the cached per-module range lists (`ModuleTable::module_ranges`
//!    allocates; the sync loop must not);
//!  * the token batch buffer filled by `Corpus::sequence_into`;
//!  * the full-vector mean buffer for the uniform-averaging methods and
//!    a spare-buffer free list that recycles the CO2 staleness queue's
//!    entries.
//!
//! The combine methods use the fused kernels (`tensor::kernels`): the
//! pseudo-gradient subtraction and per-module norms are one sweep
//! ([`kernels::sub_sq_norm_into`]), the weighted combine and its norm
//! are one sweep ([`kernels::weighted_sum_sq_strided`]), and the clip-β
//! scale rides inside the outer-optimizer apply
//! ([`super::outer::OuterOpt::apply_range_scaled`]).

use crate::tensor::kernels;
use crate::tensor::table::{ModuleTable, Range};

use super::outer::OuterOpt;
use super::penalty;

#[derive(Debug)]
pub struct SyncScratch {
    /// Row-major pseudo-gradient matrix: row j at `[j*params, (j+1)*params)`.
    deltas: Vec<f32>,
    /// Flat-vector length (row stride of `deltas`).
    params: usize,
    /// Current replica count (number of rows).
    replicas: usize,
    /// Module-contiguous combine buffer (len = max module length).
    combined: Vec<f32>,
    /// Per-replica per-module pseudo-gradient norms (‖Δ_j^(m)‖).
    norms: Vec<f64>,
    /// Norms after anomaly screening (+inf = eliminated).
    screened: Vec<f64>,
    /// softmax(-norm) combine weights.
    weights: Vec<f32>,
    /// Cached `table.module_ranges(m)` for every module.
    module_ranges: Vec<Vec<Range>>,
    /// Token batch buffer for `Corpus::sequence_into`.
    pub tokens: Vec<i32>,
    /// Full-vector mean pseudo gradient (uniform-averaging methods).
    mean: Vec<f32>,
    /// Recycled full-vector buffers for the CO2 staleness queue.
    spare: Vec<Vec<f32>>,
}

impl SyncScratch {
    pub fn new(table: &ModuleTable, replicas: usize, token_capacity: usize) -> Self {
        let params = table.total;
        let module_ranges: Vec<Vec<Range>> =
            (0..table.num_modules()).map(|m| table.module_ranges(m)).collect();
        let max_module_len = module_ranges
            .iter()
            .map(|rs| rs.iter().map(|r| r.len).sum::<usize>())
            .max()
            .unwrap_or(0);
        Self {
            deltas: vec![0.0; replicas * params],
            params,
            replicas,
            combined: vec![0.0; max_module_len],
            norms: Vec::with_capacity(replicas),
            screened: Vec::with_capacity(replicas),
            weights: Vec::with_capacity(replicas),
            module_ranges,
            tokens: Vec::with_capacity(token_capacity),
            mean: vec![0.0; params],
            spare: Vec::new(),
        }
    }

    /// Resize the per-replica buffers after an elastic rescale. No-op
    /// (and allocation-free) when the replica count is unchanged.
    pub fn ensure_replicas(&mut self, replicas: usize) {
        if replicas == self.replicas {
            return;
        }
        self.replicas = replicas;
        self.deltas.resize(replicas * self.params, 0.0);
        self.norms.reserve(replicas);
        self.screened.reserve(replicas);
        self.weights.reserve(replicas);
    }

    pub fn num_modules(&self) -> usize {
        self.module_ranges.len()
    }

    /// Per-replica norms computed by the last [`Self::load_module`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// Split view for `AnomalyDetector::screen_into` (reads the norms,
    /// writes the screened vector).
    pub fn screen_buffers(&mut self) -> (&[f64], &mut Vec<f64>) {
        (&self.norms, &mut self.screened)
    }

    /// The screened norms written by the detector (or by
    /// [`Self::adopt_norms_unscreened`]).
    pub fn screened(&self) -> &[f64] {
        &self.screened
    }

    /// Copy the raw norms into the screened slot (benches / penalty-off
    /// paths that skip the anomaly detector).
    pub fn adopt_norms_unscreened(&mut self) {
        self.screened.clear();
        let (norms, screened) = (&self.norms, &mut self.screened);
        screened.extend_from_slice(norms);
    }

    /// Cached ranges of module `m` — the sync sweep's per-module anchor
    /// adoption copies through this without re-deriving the table.
    pub fn module_ranges_of(&self, m: usize) -> &[Range] {
        &self.module_ranges[m]
    }

    /// Fill one module of the Δ matrix: for every replica j,
    /// Δ_j = params_j − anchor over the module's ranges (fused with the
    /// per-module squared norm), leaving ‖Δ_j^(m)‖ in [`Self::norms`].
    ///
    /// `row_params(j)` returns replica j's parameter vector; the closure
    /// indirection lets the trainer hand in `&self.replicas[j].params`
    /// while this arena is mutably borrowed.
    pub fn load_module<'a, F>(&mut self, m: usize, row_params: F, anchor: &[f32])
    where
        F: Fn(usize) -> &'a [f32],
    {
        self.norms.clear();
        for j in 0..self.replicas {
            let sq = self.load_one_row(m, j, row_params(j), anchor);
            self.norms.push(sq.sqrt());
        }
    }

    /// Subset variant of [`Self::load_module`] for the per-replica
    /// anchor syncs (A-EDiT event groups): Δ-matrix row `i` holds member
    /// `members[i]`'s pseudo gradient (rows are *compacted* so the
    /// strided combine kernels and the weight vector line up with the
    /// member list), and `norms()[i]` is that member's module norm.
    /// With `members = [0, 1, .., replicas-1]` this is exactly
    /// [`Self::load_module`].
    pub fn load_module_subset<'a, F>(
        &mut self,
        m: usize,
        members: &[usize],
        row_params: F,
        anchor: &[f32],
    ) where
        F: Fn(usize) -> &'a [f32],
    {
        debug_assert!(members.len() <= self.replicas);
        self.norms.clear();
        for (i, &j) in members.iter().enumerate() {
            let sq = self.load_one_row(m, i, row_params(j), anchor);
            self.norms.push(sq.sqrt());
        }
    }

    /// Δ-matrix row fill for one (row slot, module): fused subtraction +
    /// squared norm over the module's ranges.
    fn load_one_row(&mut self, m: usize, slot: usize, row: &[f32], anchor: &[f32]) -> f64 {
        debug_assert_eq!(row.len(), self.params);
        let base = slot * self.params;
        let mut sq = 0.0f64;
        for r in &self.module_ranges[m] {
            sq += kernels::sub_sq_norm_into(
                &mut self.deltas[base + r.offset..base + r.offset + r.len],
                &row[r.offset..r.offset + r.len],
                &anchor[r.offset..r.offset + r.len],
            );
        }
        sq
    }

    /// Fill the whole Δ matrix (uniform-averaging path; no norms).
    pub fn load_full<'a, F>(&mut self, row_params: F, anchor: &[f32])
    where
        F: Fn(usize) -> &'a [f32],
    {
        for j in 0..self.replicas {
            let base = j * self.params;
            kernels::sub(&mut self.deltas[base..base + self.params], row_params(j), anchor);
        }
    }

    /// softmax(-screened) into the weight buffer; `false` ⇒ all replicas
    /// anomalous (module rollback).
    pub fn compute_weights(&mut self, weighted_averaging: bool) -> bool {
        let (screened, weights) = (&self.screened, &mut self.weights);
        penalty::softmax_neg_weights_into(weights, screened, weighted_averaging)
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// Weighted-combine module `m` into the module-contiguous buffer,
    /// returning the combined squared norm (fused, one sweep per range).
    pub fn combine_module(&mut self, m: usize) -> f64 {
        let mut cursor = 0usize;
        let mut sq = 0.0f64;
        for r in &self.module_ranges[m] {
            sq += kernels::weighted_sum_sq_strided(
                &mut self.combined[cursor..cursor + r.len],
                &self.deltas,
                self.params,
                r.offset,
                &self.weights,
            );
            cursor += r.len;
        }
        sq
    }

    /// Apply the combined module through the outer optimizer with the
    /// clip factor β fused in (no separate scale pass over the update).
    pub fn apply_module(&self, m: usize, outer: &mut OuterOpt, anchor: &mut [f32], beta: f32) {
        let mut cursor = 0usize;
        for r in &self.module_ranges[m] {
            outer.apply_range_scaled(
                anchor,
                &self.combined[cursor..cursor + r.len],
                r.offset,
                beta,
            );
            cursor += r.len;
        }
    }

    /// Uniform mean of the Δ rows into the internal mean buffer.
    pub fn mean_deltas(&mut self) -> &[f32] {
        let w = 1.0 / self.replicas as f32;
        self.mean.fill(0.0);
        for j in 0..self.replicas {
            let base = j * self.params;
            kernels::axpy(&mut self.mean, w, &self.deltas[base..base + self.params]);
        }
        &self.mean
    }

    /// Like [`Self::mean_deltas`] but into a caller-owned buffer (the
    /// CO2 staleness queue needs an owned copy).
    pub fn mean_deltas_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.params, 0.0);
        let w = 1.0 / self.replicas as f32;
        for j in 0..self.replicas {
            let base = j * self.params;
            kernels::axpy(out, w, &self.deltas[base..base + self.params]);
        }
    }

    /// Grab a recycled full-vector buffer (or allocate the first time).
    pub fn take_spare(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_default()
    }

    /// Return a buffer to the free list for reuse.
    pub fn put_spare(&mut self, buf: Vec<f32>) {
        self.spare.push(buf);
    }

    /// Row j of the Δ matrix (tests / benches).
    pub fn delta_row(&self, j: usize) -> &[f32] {
        &self.deltas[j * self.params..(j + 1) * self.params]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::outer::OuterOptKind;
    use crate::tensor::{self, table::TensorEntry};

    fn toy_table() -> ModuleTable {
        ModuleTable::new(
            vec![
                TensorEntry { name: "embed".into(), shape: vec![4, 2], offset: 0, size: 8, stacked: false },
                TensorEntry { name: "layers.b".into(), shape: vec![2, 2], offset: 8, size: 4, stacked: true },
                TensorEntry { name: "layers.w".into(), shape: vec![2, 3, 2], offset: 12, size: 12, stacked: true },
                TensorEntry { name: "head".into(), shape: vec![2, 2], offset: 24, size: 4, stacked: false },
            ],
            2,
        )
    }

    fn rows(n: usize, p: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|j| (0..p).map(|i| ((i * (j + 2)) % 13) as f32 / 13.0 - 0.4).collect())
            .collect()
    }

    #[test]
    fn load_module_matches_naive_norms() {
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0).collect();
        let params = rows(3, p);
        let mut s = SyncScratch::new(&table, 3, 0);
        for m in 0..table.num_modules() {
            s.load_module(m, |j| params[j].as_slice(), &anchor);
            for j in 0..3 {
                let mut d = vec![0.0f32; p];
                tensor::sub(&mut d, &params[j], &anchor);
                let want = table.module_sq_norm(&d, m).sqrt();
                let got = s.norms()[j];
                assert!((got - want).abs() <= 1e-9 * want.max(1.0), "m={m} j={j}");
                // Delta rows written over the module's ranges.
                for r in table.module_ranges(m) {
                    assert_eq!(
                        &s.delta_row(j)[r.offset..r.offset + r.len],
                        &d[r.offset..r.offset + r.len]
                    );
                }
            }
        }
    }

    #[test]
    fn load_module_subset_compacts_rows() {
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0).collect();
        let params = rows(4, p);
        let mut full = SyncScratch::new(&table, 4, 0);
        let mut sub = SyncScratch::new(&table, 4, 0);
        let members = [1usize, 3];
        for m in 0..table.num_modules() {
            full.load_module(m, |j| params[j].as_slice(), &anchor);
            sub.load_module_subset(m, &members, |j| params[j].as_slice(), &anchor);
            assert_eq!(sub.norms().len(), 2);
            for (i, &j) in members.iter().enumerate() {
                assert_eq!(sub.norms()[i], full.norms()[j], "m={m} member {j}");
                for r in table.module_ranges(m) {
                    assert_eq!(
                        &sub.delta_row(i)[r.offset..r.offset + r.len],
                        &full.delta_row(j)[r.offset..r.offset + r.len],
                        "m={m} member {j}"
                    );
                }
            }
        }
        // Identity member list == load_module.
        let all = [0usize, 1, 2, 3];
        for m in 0..table.num_modules() {
            full.load_module(m, |j| params[j].as_slice(), &anchor);
            sub.load_module_subset(m, &all, |j| params[j].as_slice(), &anchor);
            assert_eq!(sub.norms(), full.norms());
        }
    }

    #[test]
    fn combine_apply_matches_collect_then_scatter() {
        // The fused per-module pipeline must reproduce the historical
        // collect-then-scatter synchronize shape exactly (same per-element
        // operations): weighted sum per range, module-level clip, outer
        // apply.
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 5) as f32 / 5.0).collect();
        let params = rows(2, p);
        let phi = 0.8f64; // small phi so clipping engages
        let eps = 1e-8f64;

        // --- fused path -----------------------------------------------------
        let mut s = SyncScratch::new(&table, 2, 0);
        let mut outer_f = OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
        let mut anchor_f = anchor.clone();
        for m in 0..table.num_modules() {
            s.load_module(m, |j| params[j].as_slice(), &anchor_f);
            s.adopt_norms_unscreened();
            assert!(s.compute_weights(true));
            let sq = s.combine_module(m);
            let beta = (phi / (sq.sqrt() + eps)).min(1.0);
            s.apply_module(m, &mut outer_f, &mut anchor_f, beta as f32);
        }

        // --- historical reference path -------------------------------------
        let mut outer_r = OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
        let mut anchor_r = anchor.clone();
        for m in 0..table.num_modules() {
            let deltas: Vec<Vec<f32>> = (0..2)
                .map(|j| {
                    let mut d = vec![0.0f32; p];
                    tensor::sub(&mut d, &params[j], &anchor_r);
                    d
                })
                .collect();
            let norms: Vec<f64> =
                (0..2).map(|j| table.module_sq_norm(&deltas[j], m).sqrt()).collect();
            let weights = penalty::softmax_neg_weights(&norms, true);
            let ranges = table.module_ranges(m);
            let mut module_sq = 0.0f64;
            let mut combined: Vec<(usize, Vec<f32>)> = Vec::new();
            for r in &ranges {
                let mut out = vec![0.0f32; r.len];
                let views: Vec<&[f32]> = deltas
                    .iter()
                    .map(|d| &d[r.offset..r.offset + r.len])
                    .collect();
                tensor::weighted_sum_into(&mut out, &views, &weights);
                module_sq += tensor::sq_norm(&out);
                combined.push((r.offset, out));
            }
            let beta = (phi / (module_sq.sqrt() + eps)).min(1.0);
            for (off, mut delta) in combined {
                if beta < 1.0 {
                    tensor::scale(&mut delta, beta as f32);
                }
                outer_r.apply_range(&mut anchor_r, &delta, off);
            }
        }

        crate::testing::assert_close(&anchor_f, &anchor_r, 1e-6, 1e-5);
        crate::testing::assert_close(&outer_f.momentum, &outer_r.momentum, 1e-6, 1e-5);
    }

    #[test]
    fn mean_deltas_matches_mean_into() {
        let table = toy_table();
        let p = table.total;
        let anchor = vec![0.25f32; p];
        let params = rows(4, p);
        let mut s = SyncScratch::new(&table, 4, 0);
        s.load_full(|j| params[j].as_slice(), &anchor);
        let mut owned = Vec::new();
        s.mean_deltas_into(&mut owned);
        let got = s.mean_deltas().to_vec();

        let deltas: Vec<Vec<f32>> = (0..4)
            .map(|j| {
                let mut d = vec![0.0f32; p];
                tensor::sub(&mut d, &params[j], &anchor);
                d
            })
            .collect();
        let views: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let mut want = vec![0.0f32; p];
        tensor::mean_into(&mut want, &views);
        assert_eq!(got, want);
        assert_eq!(owned, want);
    }

    #[test]
    fn spare_buffers_recycle() {
        let table = toy_table();
        let mut s = SyncScratch::new(&table, 2, 0);
        let mut b = s.take_spare();
        b.resize(table.total, 0.0);
        let ptr = b.as_ptr();
        s.put_spare(b);
        let b2 = s.take_spare();
        assert_eq!(b2.as_ptr(), ptr, "free list must hand back the same buffer");
    }
}
