//! `SyncScratch` — the preallocated arena behind the zero-allocation
//! synchronization pipeline.
//!
//! # Ownership rules
//!
//! One `SyncScratch` is owned by each [`super::engine::Trainer`] and
//! lives as long as the trainer. Every buffer inside it is sized once
//! (at construction, or at [`Self::ensure_replicas`] after an elastic
//! rescale) and then only `clear()`ed / overwritten, so after the first
//! full round at a given mesh size — "warm-up" — the trainer's
//! `synchronize()`, `ddp_step()` and `inner_step()` perform **zero heap
//! allocations**. `tests/sync_steady_state.rs` asserts this with a
//! counting global allocator.
//!
//! One stated bound: the per-replica loss traces are reserved up front
//! for `min(total_steps + 4τ, LOSS_TRACE_CAP = 2^20)` entries. Runs
//! whose replicas exceed 2^20 inner steps reallocate the trace
//! (amortized doubling) — a deliberate memory/garbage trade-off for
//! open-ended runs, outside the invariant.
//!
//! Contents:
//!  * the pseudo-gradient matrix Δ (row j = replica j, one flat
//!    row-major `Vec<f32>` so per-module combines read strided rows
//!    without materializing `Vec<&[f32]>` views);
//!  * the module-contiguous combine buffer (max module length) that the
//!    per-range weighted sums land in before the outer apply;
//!  * per-replica norm / screened-norm / weight vectors;
//!  * the cached per-module range lists (`ModuleTable::module_ranges`
//!    allocates; the sync loop must not);
//!  * the token batch buffer filled by `Corpus::sequence_into`;
//!  * the full-vector mean buffer for the uniform-averaging methods and
//!    a spare-buffer free list that recycles the CO2 staleness queue's
//!    entries.
//!
//! The combine methods use the fused kernels (`tensor::kernels`): the
//! pseudo-gradient subtraction and per-module norms are one sweep
//! ([`kernels::sub_sq_norm_into`]), the weighted combine and its norm
//! are one sweep ([`kernels::weighted_sum_sq_strided`]), and the clip-β
//! scale rides inside the outer-optimizer apply
//! ([`super::outer::OuterOpt::apply_range_scaled`]).
//!
//! # Sharded mode (`TrainConfig::shard_outer`)
//!
//! [`Self::enable_sharding`] switches the arena to the ZeRO-1-style
//! layout: the flat space is partitioned into `parts` contiguous,
//! range-aligned shards (`tensor::TableShards`) and the full Δ matrix
//! is replaced by per-rank **shard lanes** — each lane holds only its
//! shard's Δ rows, combine buffer and scalar partials, so the per-rank
//! sync high-water drops to ≈ 1/parts of the unsharded arena (asserted
//! by `tests/sharded_sync.rs`). The sync then runs in phases:
//!
//!  1. [`Self::shard_load`] — "reduce-scatter": every lane materializes
//!     the members' pseudo-gradients over its owned ranges and records
//!     per-range ‖Δ‖² partials (lane-parallel when `threads > 1`);
//!  2. [`Self::shard_fold_norms`] — per-module norms folded from the
//!     partials **in flat range order**, the exact f64 association of
//!     the unsharded sweep (the deterministic-combine contract);
//!  3. [`Self::shard_combine`] — shard-local softmax-weighted combine
//!     (the `collectives::group::reduce_scatter_weighted` fold), with
//!     per-range combined-norm partials (lane-parallel);
//!  4. [`Self::shard_module_sq`] / [`Self::shard_set_beta`] — clip-β
//!     from the range-order fold;
//!  5. [`Self::shard_apply`] — shard-local outer update over disjoint
//!     anchor/momentum slices ("all-gather" adoption is a plain anchor
//!     copy, priced in the `CommPlan`).
//!
//! Every lane buffer is sized at [`Self::enable_sharding`] /
//! [`Self::ensure_replicas`]; the phases allocate nothing, so the
//! zero-allocation steady-state invariant holds with sharding on.

use crate::tensor::table::{ModuleTable, Range};
use crate::tensor::{kernels, PayloadKind, TableShards};

use super::outer::OuterOpt;
use super::penalty;

/// One owned range in a shard lane, in lane-local coordinates.
#[derive(Debug, Clone, Copy)]
struct LanePart {
    /// Module the range belongs to.
    module: usize,
    /// Global flat offset.
    offset: usize,
    /// Offset within the shard (`offset - lane.offset`).
    local: usize,
    len: usize,
}

/// Per-rank shard lane: everything rank `s` owns in the sharded sync.
/// Lanes are data-disjoint, so the load/combine phases can fan out
/// across worker threads with bitwise-identical results.
#[derive(Debug)]
struct ShardLane {
    /// Flat-space offset of the owned shard.
    offset: usize,
    /// Shard length (row stride of `deltas`).
    len: usize,
    /// Owned module ranges, in flat order.
    parts: Vec<LanePart>,
    /// Member-compacted Δ shard (row i = i-th sync member).
    deltas: Vec<f32>,
    /// Weighted-combine output over the shard.
    combined: Vec<f32>,
    /// Per (part, member-slot) squared pseudo-gradient partials.
    load_sq: Vec<f64>,
    /// Per-part combined squared-norm partials.
    combine_sq: Vec<f64>,
    /// Error-feedback residuals over the owned shard, indexed by
    /// **replica id** (`j * len + local`), NOT the compacted member
    /// slot — a replica that skips a sync (fault, A-EDiT subset) keeps
    /// its residual untouched. Empty when `payload = f32`.
    residuals: Vec<f32>,
}

/// Sharded-sync state: the lanes, the range-order fold metadata and the
/// per-module control-plane results shared between phases.
#[derive(Debug)]
struct ShardState {
    lanes: Vec<ShardLane>,
    /// Per module: (lane, part slot) of every range, in flat range
    /// order — the deterministic fold order of the scalar combines.
    module_slots: Vec<Vec<(u32, u32)>>,
    /// Per-module softmax weights (row stride = replica capacity).
    weights_mat: Vec<f32>,
    rollback: Vec<bool>,
    betas: Vec<f32>,
    /// Member count of the in-flight sync (set by `shard_load`).
    members: usize,
}

/// Run `f` over every lane — sequentially (allocation-free), or fanned
/// out across up to `threads` scoped OS threads in contiguous chunks
/// (the same chunking as the replica lanes in `Trainer::run_lanes`).
/// Lanes are data-disjoint, so results are bitwise identical either
/// way.
fn for_each_lane<F>(lanes: &mut [ShardLane], threads: usize, f: F)
where
    F: Fn(&mut ShardLane) + Sync,
{
    let threads = threads.max(1).min(lanes.len().max(1));
    if threads <= 1 {
        for lane in lanes.iter_mut() {
            f(lane);
        }
    } else {
        let chunk = lanes.len().div_ceil(threads);
        std::thread::scope(|s| {
            for batch in lanes.chunks_mut(chunk) {
                let f = &f;
                s.spawn(move || {
                    for lane in batch.iter_mut() {
                        f(lane);
                    }
                });
            }
        });
    }
}

/// One module's staged sync state for the overlapped (software-
/// pipelined) full-matrix sweep: a compact member-major copy of the
/// module's Δ rows plus the committed weights, detached from the shared
/// arena so the arena can load module `m+1` while this module's
/// combine/apply completes. Two lanes double-buffer the pipeline;
/// buffers are reused across rounds (steady-state zero-allocation once
/// each lane has seen its largest module).
#[derive(Debug, Default)]
pub struct ModuleLane {
    /// Module staged in this lane.
    pub(crate) module: usize,
    /// Module length (sum of range lens; row stride of `deltas`).
    mlen: usize,
    /// Member-compacted Δ rows over the module (row i = member i).
    deltas: Vec<f32>,
    /// Committed softmax combine weights (len = member count).
    weights: Vec<f32>,
    /// Weighted-combine output, module-contiguous.
    combined: Vec<f32>,
    /// (flat offset, len) of the module's ranges, in flat order.
    ranges: Vec<(usize, usize)>,
    /// Combined squared norm (set by [`Self::combine`]).
    pub(crate) sq: f64,
    /// Module was rolled back (all members anomalous): skip combine and
    /// apply, members just re-adopt the unchanged anchor.
    pub(crate) rolled_back: bool,
}

impl ModuleLane {
    /// Weighted combine over the staged rows — per range, the same
    /// kernel call sequence (and therefore the same f64 association) as
    /// [`SyncScratch::combine_module`], just with module-local offsets
    /// into the compact copy.
    pub(crate) fn combine(&mut self) {
        let mut cursor = 0usize;
        let mut sq = 0.0f64;
        for &(_, len) in &self.ranges {
            sq += kernels::weighted_sum_sq_strided(
                &mut self.combined[cursor..cursor + len],
                &self.deltas,
                self.mlen,
                cursor,
                &self.weights,
            );
            cursor += len;
        }
        self.sq = sq;
    }

    /// Outer-optimizer apply of the staged combine — the
    /// [`SyncScratch::apply_module`] sweep reading from the lane.
    pub(crate) fn apply(&self, outer: &mut OuterOpt, anchor: &mut [f32], beta: f32) {
        let mut cursor = 0usize;
        for &(offset, len) in &self.ranges {
            outer.apply_range_scaled(anchor, &self.combined[cursor..cursor + len], offset, beta);
            cursor += len;
        }
    }
}

#[derive(Debug)]
pub struct SyncScratch {
    /// Row-major pseudo-gradient matrix: row j at `[j*params, (j+1)*params)`.
    deltas: Vec<f32>,
    /// Flat-vector length (row stride of `deltas`).
    params: usize,
    /// Current replica count (number of rows).
    replicas: usize,
    /// Module-contiguous combine buffer (len = max module length).
    combined: Vec<f32>,
    /// Per-replica per-module pseudo-gradient norms (‖Δ_j^(m)‖).
    norms: Vec<f64>,
    /// Norms after anomaly screening (+inf = eliminated).
    screened: Vec<f64>,
    /// softmax(-norm) combine weights.
    weights: Vec<f32>,
    /// Cached `table.module_ranges(m)` for every module.
    module_ranges: Vec<Vec<Range>>,
    /// Token batch buffer for `Corpus::sequence_into`.
    pub tokens: Vec<i32>,
    /// Full-vector mean pseudo gradient (uniform-averaging methods).
    mean: Vec<f32>,
    /// Recycled full-vector buffers for the CO2 staleness queue.
    spare: Vec<Vec<f32>>,
    /// Sync wire format ([`PayloadKind`]); `F32` is the historical
    /// uncompressed path with no residual state.
    payload: PayloadKind,
    /// Full-matrix error-feedback residuals (row j = replica j), the
    /// unsharded twin of the per-lane `ShardLane::residuals`. Empty
    /// when `payload = f32` or sharding is active.
    residuals: Vec<f32>,
    /// ZeRO-1-style shard lanes (`TrainConfig::shard_outer`); `None`
    /// runs the historical full-matrix path.
    shards: Option<ShardState>,
    /// Double-buffered [`ModuleLane`]s for the overlapped full-matrix
    /// sweep, parked here between syncs so their buffers are reused
    /// (empty until the first overlapped sync takes them).
    overlap_lanes: Vec<ModuleLane>,
}

impl SyncScratch {
    pub fn new(table: &ModuleTable, replicas: usize, token_capacity: usize) -> Self {
        let params = table.total;
        let module_ranges: Vec<Vec<Range>> =
            (0..table.num_modules()).map(|m| table.module_ranges(m)).collect();
        let max_module_len = module_ranges
            .iter()
            .map(|rs| rs.iter().map(|r| r.len).sum::<usize>())
            .max()
            .unwrap_or(0);
        Self {
            deltas: vec![0.0; replicas * params],
            params,
            replicas,
            combined: vec![0.0; max_module_len],
            norms: Vec::with_capacity(replicas),
            screened: Vec::with_capacity(replicas),
            weights: Vec::with_capacity(replicas),
            module_ranges,
            tokens: Vec::with_capacity(token_capacity),
            mean: vec![0.0; params],
            spare: Vec::new(),
            payload: PayloadKind::F32,
            residuals: Vec::new(),
            shards: None,
            overlap_lanes: Vec::new(),
        }
    }

    /// Select the sync wire format and (re)size the error-feedback
    /// residual buffers for the current layout. Setup-path only: the
    /// steady-state sweep allocates nothing, so this must be called at
    /// trainer construction and after any layout change
    /// ([`Self::enable_sharding`] / [`Self::disable_sharding`] /
    /// [`Self::ensure_replicas`] call it themselves).
    pub fn set_payload(&mut self, payload: PayloadKind) {
        self.payload = payload;
        self.resize_residuals();
    }

    /// Active sync wire format.
    pub fn payload(&self) -> PayloadKind {
        self.payload
    }

    /// Size the residual buffers for the active layout; `payload=f32`
    /// carries none (so the arena is byte-for-byte the pre-payload-axis
    /// arena). On a size change the buffer restarts at zero — residual
    /// state deliberately resets across elastic layout changes, and the
    /// checkpoint restore that follows a rescale re-imports it.
    fn resize_residuals(&mut self) {
        let (replicas, params) = (self.replicas, self.params);
        let quantized = self.payload.quantized();
        if let Some(st) = self.shards.as_mut() {
            for lane in &mut st.lanes {
                let want = if quantized { replicas * lane.len } else { 0 };
                if lane.residuals.len() != want {
                    lane.residuals.clear();
                    lane.residuals.resize(want, 0.0);
                }
            }
            self.residuals = Vec::new();
        } else {
            let want = if quantized { replicas * params } else { 0 };
            if self.residuals.len() != want {
                self.residuals.clear();
                self.residuals.resize(want, 0.0);
            }
        }
    }

    /// Switch the arena to the sharded (ZeRO-1-style) layout: partition
    /// the flat space into `parts` range-aligned shards and replace the
    /// full Δ matrix by per-rank shard lanes. Idempotent per (table,
    /// parts); called at trainer construction and after an elastic
    /// rescale (where `parts` follows the new replica count).
    pub fn enable_sharding(&mut self, table: &ModuleTable, parts: usize) {
        let spec = TableShards::from_table(table, parts);
        let replicas = self.replicas;
        let mut lanes: Vec<ShardLane> = (0..parts)
            .map(|s| {
                let (offset, len) = spec.range(s);
                ShardLane {
                    offset,
                    len,
                    parts: Vec::new(),
                    deltas: vec![0.0; replicas * len],
                    combined: vec![0.0; len],
                    load_sq: Vec::new(),
                    combine_sq: Vec::new(),
                    residuals: Vec::new(),
                }
            })
            .collect();
        let modules = self.module_ranges.len();
        let mut module_slots: Vec<Vec<(u32, u32)>> = vec![Vec::new(); modules];
        for (m, ranges) in self.module_ranges.iter().enumerate() {
            for r in ranges {
                if r.len == 0 {
                    continue;
                }
                let s = spec.owner_of(r.offset);
                let lane = &mut lanes[s];
                module_slots[m].push((s as u32, lane.parts.len() as u32));
                lane.parts.push(LanePart {
                    module: m,
                    offset: r.offset,
                    local: r.offset - lane.offset,
                    len: r.len,
                });
            }
        }
        for lane in &mut lanes {
            lane.load_sq = vec![0.0; lane.parts.len() * replicas];
            lane.combine_sq = vec![0.0; lane.parts.len()];
        }
        // The full-matrix buffers of the unsharded path (Δ matrix, mean,
        // module-contiguous combine buffer) are unused in sharded mode;
        // free them so the per-rank accounting is honest.
        self.deltas = Vec::new();
        self.mean = Vec::new();
        self.combined = Vec::new();
        self.shards = Some(ShardState {
            lanes,
            module_slots,
            weights_mat: vec![0.0; modules * replicas],
            rollback: vec![false; modules],
            betas: vec![1.0; modules],
            members: 0,
        });
        self.resize_residuals();
    }

    /// Restore the full-matrix layout (inverse of
    /// [`Self::enable_sharding`]) — used when an elastic rescale drops
    /// the sync group to a single replica, where sharding buys nothing.
    pub fn disable_sharding(&mut self) {
        if self.shards.take().is_some() {
            self.deltas = vec![0.0; self.replicas * self.params];
            self.mean = vec![0.0; self.params];
            let max_module_len = self
                .module_ranges
                .iter()
                .map(|rs| rs.iter().map(|r| r.len).sum::<usize>())
                .max()
                .unwrap_or(0);
            self.combined = vec![0.0; max_module_len];
            self.resize_residuals();
        }
    }

    /// Whether the sharded layout is active.
    pub fn sharded(&self) -> bool {
        self.shards.is_some()
    }

    /// Resize the per-replica buffers after an elastic rescale. No-op
    /// (and allocation-free) when the replica count is unchanged.
    ///
    /// Sharded mode: the lane buffers are NOT resized here — their
    /// `slot * replicas + i` partial indexing is stride-sensitive, so an
    /// in-place resize would scramble them. The one caller that changes
    /// the replica count (`Trainer::rescale`) must follow up with
    /// [`Self::enable_sharding`], which rebuilds every lane for the new
    /// count (and the freed full Δ matrix must not be re-grown here).
    pub fn ensure_replicas(&mut self, replicas: usize) {
        if replicas == self.replicas {
            return;
        }
        self.replicas = replicas;
        if self.shards.is_some() {
            debug_assert!(self.deltas.is_empty(), "sharded arena holds no full Δ matrix");
        } else {
            self.deltas.resize(replicas * self.params, 0.0);
            self.resize_residuals();
        }
        self.norms.reserve(replicas);
        self.screened.reserve(replicas);
        self.weights.reserve(replicas);
    }

    pub fn num_modules(&self) -> usize {
        self.module_ranges.len()
    }

    /// Per-replica norms computed by the last [`Self::load_module`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// Split view for `AnomalyDetector::screen_into` (reads the norms,
    /// writes the screened vector).
    pub fn screen_buffers(&mut self) -> (&[f64], &mut Vec<f64>) {
        (&self.norms, &mut self.screened)
    }

    /// The screened norms written by the detector (or by
    /// [`Self::adopt_norms_unscreened`]).
    pub fn screened(&self) -> &[f64] {
        &self.screened
    }

    /// Copy the raw norms into the screened slot (benches / penalty-off
    /// paths that skip the anomaly detector).
    pub fn adopt_norms_unscreened(&mut self) {
        self.screened.clear();
        let (norms, screened) = (&self.norms, &mut self.screened);
        screened.extend_from_slice(norms);
    }

    /// Cached ranges of module `m` — the sync sweep's per-module anchor
    /// adoption copies through this without re-deriving the table.
    pub fn module_ranges_of(&self, m: usize) -> &[Range] {
        &self.module_ranges[m]
    }

    /// Fill one module of the Δ matrix: for every replica j,
    /// Δ_j = params_j − anchor over the module's ranges (fused with the
    /// per-module squared norm), leaving ‖Δ_j^(m)‖ in [`Self::norms`].
    ///
    /// `row_params(j)` returns replica j's parameter vector; the closure
    /// indirection lets the trainer hand in `&self.replicas[j].params`
    /// while this arena is mutably borrowed.
    pub fn load_module<'a, F>(&mut self, m: usize, row_params: F, anchor: &[f32])
    where
        F: Fn(usize) -> &'a [f32],
    {
        self.norms.clear();
        for j in 0..self.replicas {
            let sq = self.load_one_row(m, j, j, row_params(j), anchor);
            self.norms.push(sq.sqrt());
        }
    }

    /// Subset variant of [`Self::load_module`] for the per-replica
    /// anchor syncs (A-EDiT event groups): Δ-matrix row `i` holds member
    /// `members[i]`'s pseudo gradient (rows are *compacted* so the
    /// strided combine kernels and the weight vector line up with the
    /// member list), and `norms()[i]` is that member's module norm.
    /// With `members = [0, 1, .., replicas-1]` this is exactly
    /// [`Self::load_module`].
    pub fn load_module_subset<'a, F>(
        &mut self,
        m: usize,
        members: &[usize],
        row_params: F,
        anchor: &[f32],
    ) where
        F: Fn(usize) -> &'a [f32],
    {
        debug_assert!(members.len() <= self.replicas);
        self.norms.clear();
        for (i, &j) in members.iter().enumerate() {
            let sq = self.load_one_row(m, i, j, row_params(j), anchor);
            self.norms.push(sq.sqrt());
        }
    }

    /// Δ-matrix row fill for one (row slot, module): fused subtraction +
    /// squared norm over the module's ranges. Quantized payloads fold
    /// the error-feedback residual add → quantize → dequantize into the
    /// same sweep, so the Δ row (and its norm — downstream consumes
    /// wire values) holds what actually crosses the wire. `slot` is the
    /// compacted Δ-matrix row; `replica` indexes the persistent
    /// residual row (they differ under member subsets).
    fn load_one_row(
        &mut self,
        m: usize,
        slot: usize,
        replica: usize,
        row: &[f32],
        anchor: &[f32],
    ) -> f64 {
        debug_assert_eq!(row.len(), self.params);
        let base = slot * self.params;
        let mut sq = 0.0f64;
        if self.payload.quantized() {
            let rbase = replica * self.params;
            let Self { deltas, residuals, module_ranges, payload, .. } = self;
            for r in &module_ranges[m] {
                sq += kernels::sub_qdq_ef_sq_norm_into(
                    *payload,
                    &mut deltas[base + r.offset..base + r.offset + r.len],
                    &row[r.offset..r.offset + r.len],
                    &anchor[r.offset..r.offset + r.len],
                    &mut residuals[rbase + r.offset..rbase + r.offset + r.len],
                );
            }
        } else {
            for r in &self.module_ranges[m] {
                sq += kernels::sub_sq_norm_into(
                    &mut self.deltas[base + r.offset..base + r.offset + r.len],
                    &row[r.offset..r.offset + r.len],
                    &anchor[r.offset..r.offset + r.len],
                );
            }
        }
        sq
    }

    /// Fill the whole Δ matrix (uniform-averaging path; no norms).
    /// Quantized payloads run the error-feedback quantize/dequantize
    /// over each full row (chunks restart per row) so the flat-sync
    /// methods (DiLoCo, CO2, ...) compress their exchange too.
    pub fn load_full<'a, F>(&mut self, row_params: F, anchor: &[f32])
    where
        F: Fn(usize) -> &'a [f32],
    {
        let Self { deltas, residuals, params, replicas, payload, .. } = self;
        let (params, replicas) = (*params, *replicas);
        for j in 0..replicas {
            let base = j * params;
            kernels::sub(&mut deltas[base..base + params], row_params(j), anchor);
            if payload.quantized() {
                kernels::quant_dequant_ef(
                    *payload,
                    &mut deltas[base..base + params],
                    &mut residuals[base..base + params],
                );
            }
        }
    }

    /// softmax(-screened) into the weight buffer; `false` ⇒ all replicas
    /// anomalous (module rollback).
    pub fn compute_weights(&mut self, weighted_averaging: bool) -> bool {
        let (screened, weights) = (&self.screened, &mut self.weights);
        penalty::softmax_neg_weights_into(weights, screened, weighted_averaging)
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// Weighted-combine module `m` into the module-contiguous buffer,
    /// returning the combined squared norm (fused, one sweep per range).
    pub fn combine_module(&mut self, m: usize) -> f64 {
        let mut cursor = 0usize;
        let mut sq = 0.0f64;
        for r in &self.module_ranges[m] {
            sq += kernels::weighted_sum_sq_strided(
                &mut self.combined[cursor..cursor + r.len],
                &self.deltas,
                self.params,
                r.offset,
                &self.weights,
            );
            cursor += r.len;
        }
        sq
    }

    /// Apply the combined module through the outer optimizer with the
    /// clip factor β fused in (no separate scale pass over the update).
    pub fn apply_module(&self, m: usize, outer: &mut OuterOpt, anchor: &mut [f32], beta: f32) {
        let mut cursor = 0usize;
        for r in &self.module_ranges[m] {
            outer.apply_range_scaled(
                anchor,
                &self.combined[cursor..cursor + r.len],
                r.offset,
                beta,
            );
            cursor += r.len;
        }
    }

    /// Detach the double-buffered overlap lanes (first call creates the
    /// two empty lanes; afterwards their buffers persist across syncs).
    /// Taking them out of `self` lets the caller mutate a lane while the
    /// arena loads the next module — return them with
    /// [`Self::put_overlap_lanes`] when the sweep finishes.
    pub fn take_overlap_lanes(&mut self) -> Vec<ModuleLane> {
        let mut lanes = std::mem::take(&mut self.overlap_lanes);
        while lanes.len() < 2 {
            lanes.push(ModuleLane::default());
        }
        lanes
    }

    /// Park the overlap lanes back in the arena for reuse.
    pub fn put_overlap_lanes(&mut self, lanes: Vec<ModuleLane>) {
        self.overlap_lanes = lanes;
    }

    /// Stage module `m`'s sync state into `lane`: a compact member-major
    /// copy of the Δ rows over the module's ranges plus the committed
    /// weights. Call after [`Self::load_module_subset`] and
    /// [`Self::compute_weights`] for `m` — the arena is free to load the
    /// next module afterwards. A rolled-back module stages only the
    /// flag and ranges (the combine/apply are skipped; members re-adopt
    /// the unchanged anchor).
    pub fn stage_module_lane(
        &self,
        lane: &mut ModuleLane,
        m: usize,
        members: usize,
        rolled_back: bool,
    ) {
        let ranges = &self.module_ranges[m];
        let mlen: usize = ranges.iter().map(|r| r.len).sum();
        lane.module = m;
        lane.mlen = mlen;
        lane.sq = 0.0;
        lane.rolled_back = rolled_back;
        lane.ranges.clear();
        lane.ranges.extend(ranges.iter().map(|r| (r.offset, r.len)));
        if rolled_back {
            return;
        }
        lane.weights.clear();
        lane.weights.extend_from_slice(&self.weights[..members]);
        lane.combined.resize(mlen, 0.0);
        lane.deltas.resize(members * mlen, 0.0);
        for i in 0..members {
            let src = i * self.params;
            let dst = i * mlen;
            let mut cursor = 0usize;
            for r in ranges {
                lane.deltas[dst + cursor..dst + cursor + r.len]
                    .copy_from_slice(&self.deltas[src + r.offset..src + r.offset + r.len]);
                cursor += r.len;
            }
        }
    }

    /// Uniform mean of the Δ rows into the internal mean buffer.
    pub fn mean_deltas(&mut self) -> &[f32] {
        let w = 1.0 / self.replicas as f32;
        self.mean.fill(0.0);
        for j in 0..self.replicas {
            let base = j * self.params;
            kernels::axpy(&mut self.mean, w, &self.deltas[base..base + self.params]);
        }
        &self.mean
    }

    /// Like [`Self::mean_deltas`] but into a caller-owned buffer (the
    /// CO2 staleness queue needs an owned copy).
    pub fn mean_deltas_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.params, 0.0);
        let w = 1.0 / self.replicas as f32;
        for j in 0..self.replicas {
            let base = j * self.params;
            kernels::axpy(out, w, &self.deltas[base..base + self.params]);
        }
    }

    /// Grab a recycled full-vector buffer (or allocate the first time).
    pub fn take_spare(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_default()
    }

    /// Return a buffer to the free list for reuse.
    pub fn put_spare(&mut self, buf: Vec<f32>) {
        self.spare.push(buf);
    }

    /// Row j of the Δ matrix (tests / benches).
    pub fn delta_row(&self, j: usize) -> &[f32] {
        &self.deltas[j * self.params..(j + 1) * self.params]
    }

    // --- sharded path (see the module docs' phase walkthrough) ----------

    /// Phase 1 — the "reduce-scatter": every lane materializes the
    /// members' pseudo-gradients over its owned ranges (member-compacted
    /// rows, as in [`Self::load_module_subset`]) and records per-range
    /// ‖Δ‖² partials for the deterministic norm fold. Lane-parallel when
    /// `threads > 1`, bitwise identical either way.
    pub fn shard_load<'a, F>(
        &mut self,
        members: &[usize],
        row_params: F,
        anchor: &[f32],
        threads: usize,
    ) where
        F: Fn(usize) -> &'a [f32] + Sync,
    {
        let replicas = self.replicas;
        let payload = self.payload;
        debug_assert!(members.len() <= replicas);
        let st = self.shards.as_mut().expect("sharding not enabled");
        st.members = members.len();
        for_each_lane(&mut st.lanes, threads, |lane| {
            // Lane buffers must have been rebuilt for the current
            // replica count (`enable_sharding`) — a stale stride would
            // silently scramble the partial indexing below.
            debug_assert_eq!(lane.load_sq.len(), lane.parts.len() * replicas);
            debug_assert_eq!(lane.deltas.len(), replicas * lane.len);
            debug_assert!(
                !payload.quantized() || lane.residuals.len() == replicas * lane.len
            );
            let len = lane.len;
            let ShardLane { parts, deltas, load_sq, residuals, .. } = lane;
            for (i, &j) in members.iter().enumerate() {
                let row = row_params(j);
                let base = i * len;
                for (slot, p) in parts.iter().enumerate() {
                    // `LanePart`s are whole module ranges (the
                    // range-aligned partition never splits one), so the
                    // quantization chunks restart exactly where the
                    // unsharded per-range sweep restarts them — sharded
                    // on/off stays bitwise identical. Residuals are
                    // indexed by replica id `j`, not member slot `i`.
                    let sq = if payload.quantized() {
                        kernels::sub_qdq_ef_sq_norm_into(
                            payload,
                            &mut deltas[base + p.local..base + p.local + p.len],
                            &row[p.offset..p.offset + p.len],
                            &anchor[p.offset..p.offset + p.len],
                            &mut residuals[j * len + p.local..j * len + p.local + p.len],
                        )
                    } else {
                        kernels::sub_sq_norm_into(
                            &mut deltas[base + p.local..base + p.local + p.len],
                            &row[p.offset..p.offset + p.len],
                            &anchor[p.offset..p.offset + p.len],
                        )
                    };
                    load_sq[slot * replicas + i] = sq;
                }
            }
        });
    }

    /// Phase 2a: fold module `m`'s squared partials — in flat range
    /// order, the exact f64 association of the unsharded
    /// [`Self::load_module_subset`] — into [`Self::norms`].
    pub fn shard_fold_norms(&mut self, m: usize) {
        let Self { shards, norms, replicas, .. } = self;
        let st = shards.as_ref().expect("sharding not enabled");
        norms.clear();
        for i in 0..st.members {
            let mut sq = 0.0f64;
            for &(lane, slot) in &st.module_slots[m] {
                sq += st.lanes[lane as usize].load_sq[slot as usize * *replicas + i];
            }
            norms.push(sq.sqrt());
        }
    }

    /// Phase 2b: publish module `m`'s combine weights (computed by
    /// [`Self::compute_weights`]) to the weight matrix the shard-local
    /// combine reads; `ok == false` marks the module rolled back
    /// (combine and apply skip it).
    pub fn shard_commit_weights(&mut self, m: usize, ok: bool) {
        let Self { shards, weights, replicas, .. } = self;
        let st = shards.as_mut().expect("sharding not enabled");
        st.rollback[m] = !ok;
        if ok {
            st.weights_mat[m * *replicas..m * *replicas + weights.len()]
                .copy_from_slice(weights);
        }
    }

    /// Phase 3 — shard-local weighted combine: every lane folds the
    /// members' Δ rows over its owned ranges with the committed
    /// per-module weights (ascending member order, zero weights skipped
    /// — the `collectives::group::reduce_scatter_weighted` fold) and
    /// records per-range combined-norm partials for the β fold.
    /// Lane-parallel when `threads > 1`.
    pub fn shard_combine(&mut self, threads: usize) {
        let replicas = self.replicas;
        let st = self.shards.as_mut().expect("sharding not enabled");
        let members = st.members;
        let ShardState { lanes, weights_mat, rollback, .. } = st;
        let weights_mat: &[f32] = weights_mat;
        let rollback: &[bool] = rollback;
        for_each_lane(lanes, threads, |lane| {
            for (slot, p) in lane.parts.iter().enumerate() {
                if rollback[p.module] {
                    continue;
                }
                let w = &weights_mat[p.module * replicas..p.module * replicas + members];
                lane.combine_sq[slot] = kernels::weighted_sum_sq_strided(
                    &mut lane.combined[p.local..p.local + p.len],
                    &lane.deltas,
                    lane.len,
                    p.local,
                    w,
                );
            }
        });
    }

    /// Single-module variant of [`Self::shard_combine`] for the
    /// overlapped sweep: combine exactly module `m`'s parts (same kernel
    /// call per part, so bitwise identical — parts are data-disjoint and
    /// the per-part fold is self-contained). No-op for a rolled-back
    /// module, matching the full-phase skip.
    pub fn shard_combine_module(&mut self, m: usize) {
        let replicas = self.replicas;
        let st = self.shards.as_mut().expect("sharding not enabled");
        if st.rollback[m] {
            return;
        }
        let members = st.members;
        let ShardState { lanes, weights_mat, module_slots, .. } = st;
        let w = &weights_mat[m * replicas..m * replicas + members];
        for &(lane, slot) in &module_slots[m] {
            let lane = &mut lanes[lane as usize];
            let slot = slot as usize;
            let (local, len) = {
                let p = &lane.parts[slot];
                debug_assert_eq!(p.module, m);
                (p.local, p.len)
            };
            lane.combine_sq[slot] = kernels::weighted_sum_sq_strided(
                &mut lane.combined[local..local + len],
                &lane.deltas,
                lane.len,
                local,
                w,
            );
        }
    }

    /// Phase 4a: module `m`'s combined squared norm, folded from the
    /// lane partials in flat range order (the unsharded
    /// [`Self::combine_module`] association).
    pub fn shard_module_sq(&self, m: usize) -> f64 {
        let st = self.shards.as_ref().expect("sharding not enabled");
        let mut sq = 0.0f64;
        for &(lane, slot) in &st.module_slots[m] {
            sq += st.lanes[lane as usize].combine_sq[slot as usize];
        }
        sq
    }

    /// Whether module `m` was rolled back this sync (phase 2b).
    pub fn shard_rollback(&self, m: usize) -> bool {
        self.shards.as_ref().expect("sharding not enabled").rollback[m]
    }

    /// Phase 4b: record module `m`'s clip factor β for the apply.
    pub fn shard_set_beta(&mut self, m: usize, beta: f32) {
        self.shards.as_mut().expect("sharding not enabled").betas[m] = beta;
    }

    /// Phase 5 — shard-local outer update: each lane applies its
    /// combined ranges through the outer optimizer with the per-module β
    /// fused in. Ranges are disjoint slices of the anchor and momentum,
    /// so the lane-major apply order is immaterial: the result is
    /// bitwise the unsharded module-major sweep. Fanned out across up
    /// to `threads` scoped threads over contiguous lane batches (the
    /// `for_each_lane` chunking): lanes tile the flat space in
    /// ascending order, so the anchor and momentum split into disjoint
    /// per-batch slices with `split_at_mut` — no allocation, and the
    /// per-element update (`OuterOptKind::apply_scaled`) is the same
    /// kernel the sequential path runs.
    pub fn shard_apply(&self, outer: &mut OuterOpt, anchor: &mut [f32], threads: usize) {
        let st = self.shards.as_ref().expect("sharding not enabled");
        let threads = threads.max(1).min(st.lanes.len().max(1));
        if threads <= 1 {
            for lane in &st.lanes {
                for p in &lane.parts {
                    if st.rollback[p.module] {
                        continue;
                    }
                    outer.apply_range_scaled(
                        anchor,
                        &lane.combined[p.local..p.local + p.len],
                        p.offset,
                        st.betas[p.module],
                    );
                }
            }
            return;
        }
        let kind = outer.kind;
        let has_momentum = kind.needs_momentum();
        let chunk = st.lanes.len().div_ceil(threads);
        std::thread::scope(|scope| {
            let mut a_rest: &mut [f32] = anchor;
            let mut m_rest: &mut [f32] = &mut outer.momentum;
            let mut cursor = 0usize;
            for batch in st.lanes.chunks(chunk) {
                // Lanes tile [0, params) contiguously in ascending
                // order; a batch therefore owns [cursor, cursor+len).
                debug_assert_eq!(batch[0].offset, cursor);
                let len: usize = batch.iter().map(|l| l.len).sum();
                let (a_cut, a_next) = a_rest.split_at_mut(len);
                a_rest = a_next;
                let (m_cut, m_next) = if has_momentum {
                    m_rest.split_at_mut(len)
                } else {
                    (&mut [][..], m_rest)
                };
                m_rest = m_next;
                let base = cursor;
                cursor += len;
                scope.spawn(move || {
                    for lane in batch {
                        for p in &lane.parts {
                            if st.rollback[p.module] {
                                continue;
                            }
                            let lo = p.offset - base;
                            let momentum = if has_momentum {
                                &mut m_cut[lo..lo + p.len]
                            } else {
                                &mut [][..]
                            };
                            kind.apply_scaled(
                                &mut a_cut[lo..lo + p.len],
                                momentum,
                                &lane.combined[p.local..p.local + p.len],
                                st.betas[p.module],
                            );
                        }
                    }
                });
            }
        });
    }

    /// Error-feedback residual state present? (`payload=f32` carries
    /// none — the checkpoint section is written empty.)
    pub fn residuals_enabled(&self) -> bool {
        self.payload.quantized()
    }

    /// Gather the residual matrix into `out` in the canonical
    /// replica-major flat order (`replicas × params`) — identical bytes
    /// whether sharding is on or off, so a checkpoint written by either
    /// layout restores into the other. Save-path only (may allocate);
    /// leaves `out` empty when the payload carries no residuals.
    pub fn export_residuals_into(&self, out: &mut Vec<f32>) {
        out.clear();
        if !self.payload.quantized() {
            return;
        }
        let (replicas, params) = (self.replicas, self.params);
        out.resize(replicas * params, 0.0);
        match &self.shards {
            None => out.copy_from_slice(&self.residuals),
            Some(st) => {
                for j in 0..replicas {
                    for lane in &st.lanes {
                        out[j * params + lane.offset..j * params + lane.offset + lane.len]
                            .copy_from_slice(
                                &lane.residuals[j * lane.len..(j + 1) * lane.len],
                            );
                    }
                }
            }
        }
    }

    /// Inverse of [`Self::export_residuals_into`]: scatter a canonical
    /// flat residual matrix into the active layout. `flat` must be
    /// `replicas × params` long (checkpoint restore validates the
    /// section count before calling). No-op for `payload=f32`.
    pub fn import_residuals(&mut self, flat: &[f32]) {
        if !self.payload.quantized() {
            return;
        }
        let (replicas, params) = (self.replicas, self.params);
        assert_eq!(flat.len(), replicas * params, "residual import size");
        match &mut self.shards {
            None => self.residuals.copy_from_slice(flat),
            Some(st) => {
                for j in 0..replicas {
                    for lane in &mut st.lanes {
                        lane.residuals[j * lane.len..(j + 1) * lane.len].copy_from_slice(
                            &flat[j * params + lane.offset
                                ..j * params + lane.offset + lane.len],
                        );
                    }
                }
            }
        }
    }

    /// Number of shard ranks (0 when sharding is disabled).
    pub fn shard_parts(&self) -> usize {
        self.shards.as_ref().map_or(0, |st| st.lanes.len())
    }

    /// (offset, len) of shard rank `s`'s owned region.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        let st = self.shards.as_ref().expect("sharding not enabled");
        (st.lanes[s].offset, st.lanes[s].len)
    }

    /// Scratch bytes resident on shard rank `s`: its Δ shard rows,
    /// combine buffer and scalar partials. The rank's anchor and
    /// outer-momentum shards (`len · 4` bytes each) come on top —
    /// together the per-rank sync high-water is ≈ the unsharded
    /// footprint ÷ parts (asserted by `tests/sharded_sync.rs`).
    pub fn shard_rank_bytes(&self, s: usize) -> usize {
        let st = self.shards.as_ref().expect("sharding not enabled");
        let lane = &st.lanes[s];
        (lane.deltas.len() + lane.combined.len() + lane.residuals.len()) * 4
            + (lane.load_sq.len() + lane.combine_sq.len()) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::outer::OuterOptKind;
    use crate::tensor::{self, table::toy_table};

    fn rows(n: usize, p: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|j| (0..p).map(|i| ((i * (j + 2)) % 13) as f32 / 13.0 - 0.4).collect())
            .collect()
    }

    #[test]
    fn load_module_matches_naive_norms() {
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0).collect();
        let params = rows(3, p);
        let mut s = SyncScratch::new(&table, 3, 0);
        for m in 0..table.num_modules() {
            s.load_module(m, |j| params[j].as_slice(), &anchor);
            for j in 0..3 {
                let mut d = vec![0.0f32; p];
                tensor::sub(&mut d, &params[j], &anchor);
                let want = table.module_sq_norm(&d, m).sqrt();
                let got = s.norms()[j];
                assert!((got - want).abs() <= 1e-9 * want.max(1.0), "m={m} j={j}");
                // Delta rows written over the module's ranges.
                for r in table.module_ranges(m) {
                    assert_eq!(
                        &s.delta_row(j)[r.offset..r.offset + r.len],
                        &d[r.offset..r.offset + r.len]
                    );
                }
            }
        }
    }

    #[test]
    fn load_module_subset_compacts_rows() {
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0).collect();
        let params = rows(4, p);
        let mut full = SyncScratch::new(&table, 4, 0);
        let mut sub = SyncScratch::new(&table, 4, 0);
        let members = [1usize, 3];
        for m in 0..table.num_modules() {
            full.load_module(m, |j| params[j].as_slice(), &anchor);
            sub.load_module_subset(m, &members, |j| params[j].as_slice(), &anchor);
            assert_eq!(sub.norms().len(), 2);
            for (i, &j) in members.iter().enumerate() {
                assert_eq!(sub.norms()[i], full.norms()[j], "m={m} member {j}");
                for r in table.module_ranges(m) {
                    assert_eq!(
                        &sub.delta_row(i)[r.offset..r.offset + r.len],
                        &full.delta_row(j)[r.offset..r.offset + r.len],
                        "m={m} member {j}"
                    );
                }
            }
        }
        // Identity member list == load_module.
        let all = [0usize, 1, 2, 3];
        for m in 0..table.num_modules() {
            full.load_module(m, |j| params[j].as_slice(), &anchor);
            sub.load_module_subset(m, &all, |j| params[j].as_slice(), &anchor);
            assert_eq!(sub.norms(), full.norms());
        }
    }

    #[test]
    fn combine_apply_matches_collect_then_scatter() {
        // The fused per-module pipeline must reproduce the historical
        // collect-then-scatter synchronize shape exactly (same per-element
        // operations): weighted sum per range, module-level clip, outer
        // apply.
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 5) as f32 / 5.0).collect();
        let params = rows(2, p);
        let phi = 0.8f64; // small phi so clipping engages
        let eps = 1e-8f64;

        // --- fused path -----------------------------------------------------
        let mut s = SyncScratch::new(&table, 2, 0);
        let mut outer_f = OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
        let mut anchor_f = anchor.clone();
        for m in 0..table.num_modules() {
            s.load_module(m, |j| params[j].as_slice(), &anchor_f);
            s.adopt_norms_unscreened();
            assert!(s.compute_weights(true));
            let sq = s.combine_module(m);
            let beta = (phi / (sq.sqrt() + eps)).min(1.0);
            s.apply_module(m, &mut outer_f, &mut anchor_f, beta as f32);
        }

        // --- historical reference path -------------------------------------
        let mut outer_r = OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
        let mut anchor_r = anchor.clone();
        for m in 0..table.num_modules() {
            let deltas: Vec<Vec<f32>> = (0..2)
                .map(|j| {
                    let mut d = vec![0.0f32; p];
                    tensor::sub(&mut d, &params[j], &anchor_r);
                    d
                })
                .collect();
            let norms: Vec<f64> =
                (0..2).map(|j| table.module_sq_norm(&deltas[j], m).sqrt()).collect();
            let weights = penalty::softmax_neg_weights(&norms, true);
            let ranges = table.module_ranges(m);
            let mut module_sq = 0.0f64;
            let mut combined: Vec<(usize, Vec<f32>)> = Vec::new();
            for r in &ranges {
                let mut out = vec![0.0f32; r.len];
                let views: Vec<&[f32]> = deltas
                    .iter()
                    .map(|d| &d[r.offset..r.offset + r.len])
                    .collect();
                tensor::weighted_sum_into(&mut out, &views, &weights);
                module_sq += tensor::sq_norm(&out);
                combined.push((r.offset, out));
            }
            let beta = (phi / (module_sq.sqrt() + eps)).min(1.0);
            for (off, mut delta) in combined {
                if beta < 1.0 {
                    tensor::scale(&mut delta, beta as f32);
                }
                outer_r.apply_range(&mut anchor_r, &delta, off);
            }
        }

        crate::testing::assert_close(&anchor_f, &anchor_r, 1e-6, 1e-5);
        crate::testing::assert_close(&outer_f.momentum, &outer_r.momentum, 1e-6, 1e-5);
    }

    #[test]
    fn mean_deltas_matches_mean_into() {
        let table = toy_table();
        let p = table.total;
        let anchor = vec![0.25f32; p];
        let params = rows(4, p);
        let mut s = SyncScratch::new(&table, 4, 0);
        s.load_full(|j| params[j].as_slice(), &anchor);
        let mut owned = Vec::new();
        s.mean_deltas_into(&mut owned);
        let got = s.mean_deltas().to_vec();

        let deltas: Vec<Vec<f32>> = (0..4)
            .map(|j| {
                let mut d = vec![0.0f32; p];
                tensor::sub(&mut d, &params[j], &anchor);
                d
            })
            .collect();
        let views: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let mut want = vec![0.0f32; p];
        tensor::mean_into(&mut want, &views);
        assert_eq!(got, want);
        assert_eq!(owned, want);
    }

    #[test]
    fn sharded_phases_match_reference_sweep_bitwise() {
        let table = toy_table();
        let p = table.total;
        let anchor0: Vec<f32> = (0..p).map(|i| (i % 5) as f32 / 5.0).collect();
        let params = rows(3, p);
        let members = [0usize, 1, 2];
        let phi = 0.6f64;
        let eps = 1e-8f64;

        // Reference module-major sweep.
        let mut r = SyncScratch::new(&table, 3, 0);
        let mut outer_r =
            OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
        let mut anchor_r = anchor0.clone();
        let mut norms_r: Vec<Vec<f64>> = Vec::new();
        for m in 0..table.num_modules() {
            r.load_module_subset(m, &members, |j| params[j].as_slice(), &anchor_r);
            norms_r.push(r.norms().to_vec());
            r.adopt_norms_unscreened();
            assert!(r.compute_weights(true));
            let sq = r.combine_module(m);
            let beta = (phi / (sq.sqrt() + eps)).min(1.0);
            r.apply_module(m, &mut outer_r, &mut anchor_r, beta as f32);
        }

        // Sharded five-phase pipeline, across shard counts (1 =
        // degenerate single lane; 5 > modules exercises short lanes) and
        // both the sequential and the 2-thread lane fan-out.
        for parts in [1usize, 2, 3, 5] {
            let threads = if parts == 2 { 2 } else { 1 };
            let mut s = SyncScratch::new(&table, 3, 0);
            s.enable_sharding(&table, parts);
            let mut outer_s =
                OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
            let mut anchor_s = anchor0.clone();
            s.shard_load(&members, |j| params[j].as_slice(), &anchor_s, threads);
            for m in 0..table.num_modules() {
                s.shard_fold_norms(m);
                assert_eq!(s.norms(), &norms_r[m][..], "parts={parts} m={m}");
                s.adopt_norms_unscreened();
                assert!(s.compute_weights(true));
                s.shard_commit_weights(m, true);
            }
            s.shard_combine(threads);
            for m in 0..table.num_modules() {
                let sq = s.shard_module_sq(m);
                let beta = (phi / (sq.sqrt() + eps)).min(1.0);
                s.shard_set_beta(m, beta as f32);
            }
            s.shard_apply(&mut outer_s, &mut anchor_s, threads);
            assert_eq!(anchor_s, anchor_r, "parts={parts}");
            assert_eq!(outer_s.momentum, outer_r.momentum, "parts={parts}");
        }
    }

    #[test]
    fn sharded_subset_and_rollback_semantics() {
        // A-EDiT-style member subset + a rolled-back module: the lanes
        // must compact rows to the member list and leave rolled-back
        // modules' anchor slices untouched.
        let table = toy_table();
        let p = table.total;
        let anchor0: Vec<f32> = (0..p).map(|i| (i % 3) as f32 / 3.0 - 0.2).collect();
        let params = rows(4, p);
        let members = [1usize, 3];

        let mut s = SyncScratch::new(&table, 4, 0);
        s.enable_sharding(&table, 4);
        let mut outer = OuterOpt::new(OuterOptKind::Sgd { lr: 1.0 }, p);
        let mut anchor = anchor0.clone();
        s.shard_load(&members, |j| params[j].as_slice(), &anchor, 1);

        let mut full = SyncScratch::new(&table, 4, 0);
        for m in 0..table.num_modules() {
            s.shard_fold_norms(m);
            full.load_module_subset(m, &members, |j| params[j].as_slice(), &anchor0);
            assert_eq!(s.norms(), full.norms(), "m={m}");
            s.adopt_norms_unscreened();
            assert!(s.compute_weights(true));
            // Roll module 0 back; commit the rest.
            s.shard_commit_weights(m, m != 0);
        }
        assert!(s.shard_rollback(0));
        assert!(!s.shard_rollback(1));
        s.shard_combine(1);
        for m in 1..table.num_modules() {
            let _ = s.shard_module_sq(m);
            s.shard_set_beta(m, 1.0);
        }
        s.shard_apply(&mut outer, &mut anchor, 1);
        // Rolled-back module 0: anchor slices untouched.
        for r in table.module_ranges(0) {
            assert_eq!(
                &anchor[r.offset..r.offset + r.len],
                &anchor0[r.offset..r.offset + r.len]
            );
        }
        // Non-rolled-back modules moved (SGD lr=1 ⇒ anchor + combined Δ).
        let moved = table
            .module_ranges(1)
            .iter()
            .any(|r| anchor[r.offset..r.offset + r.len] != anchor0[r.offset..r.offset + r.len]);
        assert!(moved, "module 1 must have been applied");
    }

    #[test]
    fn parallel_shard_apply_bitwise_matches_sequential() {
        let table = toy_table();
        let p = table.total;
        let anchor0: Vec<f32> = (0..p).map(|i| (i % 11) as f32 / 11.0 - 0.3).collect();
        let params = rows(3, p);
        let members = [0usize, 1, 2];
        for kind in [
            OuterOptKind::Sgd { lr: 0.7 },
            OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 },
        ] {
            let run = |threads: usize| {
                let mut s = SyncScratch::new(&table, 3, 0);
                s.enable_sharding(&table, 3);
                let mut outer = OuterOpt::new(kind, p);
                // Seed a nonzero momentum so the threaded split is
                // exercised against real state, not all-zeros.
                for (i, m) in outer.momentum.iter_mut().enumerate() {
                    *m = (i % 5) as f32 * 0.1 - 0.2;
                }
                let mut anchor = anchor0.clone();
                s.shard_load(&members, |j| params[j].as_slice(), &anchor, 1);
                for m in 0..table.num_modules() {
                    s.shard_fold_norms(m);
                    s.adopt_norms_unscreened();
                    assert!(s.compute_weights(true));
                    s.shard_commit_weights(m, true);
                }
                s.shard_combine(1);
                for m in 0..table.num_modules() {
                    let _ = s.shard_module_sq(m);
                    s.shard_set_beta(m, 0.9);
                }
                s.shard_apply(&mut outer, &mut anchor, threads);
                (anchor, outer.momentum)
            };
            let (a1, m1) = run(1);
            for threads in [2, 3, 7] {
                let (at, mt) = run(threads);
                assert_eq!(a1, at, "{kind:?} threads={threads}");
                assert_eq!(m1, mt, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn quantized_full_and_sharded_paths_match_bitwise() {
        // payload=int8: the sharded five-phase pipeline must reproduce
        // the unsharded module-major sweep bitwise — norms, anchor,
        // momentum AND the error-feedback residual state.
        let table = toy_table();
        let p = table.total;
        let anchor0: Vec<f32> = (0..p).map(|i| (i % 5) as f32 / 5.0).collect();
        let params = rows(3, p);
        let members = [0usize, 1, 2];
        let phi = 0.6f64;
        let eps = 1e-8f64;

        for payload in [PayloadKind::Int8, PayloadKind::Bit1] {
            let mut r = SyncScratch::new(&table, 3, 0);
            r.set_payload(payload);
            let mut outer_r =
                OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
            let mut anchor_r = anchor0.clone();
            let mut norms_r: Vec<Vec<f64>> = Vec::new();
            for m in 0..table.num_modules() {
                r.load_module_subset(m, &members, |j| params[j].as_slice(), &anchor_r);
                norms_r.push(r.norms().to_vec());
                r.adopt_norms_unscreened();
                assert!(r.compute_weights(true));
                let sq = r.combine_module(m);
                let beta = (phi / (sq.sqrt() + eps)).min(1.0);
                r.apply_module(m, &mut outer_r, &mut anchor_r, beta as f32);
            }
            let mut res_r = Vec::new();
            r.export_residuals_into(&mut res_r);
            assert_eq!(res_r.len(), 3 * p);
            assert!(
                res_r.iter().any(|&x| x != 0.0),
                "{payload:?}: quantization must leave a nonzero residual"
            );

            for parts in [2usize, 3] {
                let threads = parts; // exercise the lane fan-out too
                let mut s = SyncScratch::new(&table, 3, 0);
                s.enable_sharding(&table, parts);
                s.set_payload(payload);
                let mut outer_s =
                    OuterOpt::new(OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }, p);
                let mut anchor_s = anchor0.clone();
                s.shard_load(&members, |j| params[j].as_slice(), &anchor_s, threads);
                for m in 0..table.num_modules() {
                    s.shard_fold_norms(m);
                    assert_eq!(s.norms(), &norms_r[m][..], "{payload:?} parts={parts} m={m}");
                    s.adopt_norms_unscreened();
                    assert!(s.compute_weights(true));
                    s.shard_commit_weights(m, true);
                }
                s.shard_combine(threads);
                for m in 0..table.num_modules() {
                    let sq = s.shard_module_sq(m);
                    let beta = (phi / (sq.sqrt() + eps)).min(1.0);
                    s.shard_set_beta(m, beta as f32);
                }
                s.shard_apply(&mut outer_s, &mut anchor_s, threads);
                assert_eq!(anchor_s, anchor_r, "{payload:?} parts={parts}");
                assert_eq!(outer_s.momentum, outer_r.momentum, "{payload:?} parts={parts}");
                let mut res_s = Vec::new();
                s.export_residuals_into(&mut res_s);
                assert_eq!(res_s, res_r, "{payload:?} parts={parts} residuals");
            }
        }
    }

    #[test]
    fn residual_export_import_roundtrips_across_layouts() {
        let table = toy_table();
        let p = table.total;
        let anchor: Vec<f32> = (0..p).map(|i| (i % 7) as f32 / 7.0 - 0.4).collect();
        let params = rows(2, p);

        // Populate residuals on a sharded arena.
        let mut s = SyncScratch::new(&table, 2, 0);
        s.enable_sharding(&table, 2);
        s.set_payload(PayloadKind::Int8);
        s.shard_load(&[0, 1], |j| params[j].as_slice(), &anchor, 1);
        let mut flat = Vec::new();
        s.export_residuals_into(&mut flat);
        assert_eq!(flat.len(), 2 * p);

        // Import into an unsharded arena and re-export: identical.
        let mut u = SyncScratch::new(&table, 2, 0);
        u.set_payload(PayloadKind::Int8);
        u.import_residuals(&flat);
        let mut flat2 = Vec::new();
        u.export_residuals_into(&mut flat2);
        assert_eq!(flat, flat2);

        // And back into a differently-sharded arena.
        let mut s3 = SyncScratch::new(&table, 2, 0);
        s3.enable_sharding(&table, 3);
        s3.set_payload(PayloadKind::Int8);
        s3.import_residuals(&flat);
        let mut flat3 = Vec::new();
        s3.export_residuals_into(&mut flat3);
        assert_eq!(flat, flat3);

        // f32 payload: no residual state at all.
        let mut f = SyncScratch::new(&table, 2, 0);
        f.set_payload(PayloadKind::F32);
        assert!(!f.residuals_enabled());
        let mut none = vec![1.0f32; 3];
        f.export_residuals_into(&mut none);
        assert!(none.is_empty());
    }

    #[test]
    fn spare_buffers_recycle() {
        let table = toy_table();
        let mut s = SyncScratch::new(&table, 2, 0);
        let mut b = s.take_spare();
        b.resize(table.total, 0.0);
        let ptr = b.as_ptr();
        s.put_spare(b);
        let b2 = s.take_spare();
        assert_eq!(b2.as_ptr(), ptr, "free list must hand back the same buffer");
    }
}
