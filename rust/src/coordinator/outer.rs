//! Outer optimizers (paper: "OuterOpt").
//!
//! The pseudo gradient Δ = θ_{t,τ} - θ_t points in the *descent*
//! direction already (it is the progress the inner optimizer made), so
//! internally we feed g = -Δ to standard SGD/Nesterov update rules:
//!
//!   SGD:       θ ← θ - ν g                       (= θ + ν Δ)
//!   Nesterov:  m ← μ m + g ; θ ← θ - ν (g + μ m)
//!
//! Post Local SGD's plain parameter averaging is exactly SGD with ν = 1.
//! DiLoCo/EDiT use Nesterov (paper §4.1). The momentum buffer is the
//! "outer momentum" whose sharding/offload behaviour differentiates
//! CO2 vs CO2* vs EDiT in the memory model.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OuterOptKind {
    Sgd { lr: f64 },
    Nesterov { lr: f64, momentum: f64 },
}

impl OuterOptKind {
    /// Paper defaults for the FineWeb-Edu runs (§A.2).
    pub fn paper_nesterov() -> Self {
        OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }
    }

    /// Plain averaging (Post Local SGD).
    pub fn averaging() -> Self {
        OuterOptKind::Sgd { lr: 1.0 }
    }

    pub fn needs_momentum(&self) -> bool {
        matches!(self, OuterOptKind::Nesterov { .. })
    }

    /// Stateless slice-level update: apply β·delta to `params` with
    /// `momentum` as the matching slice of outer-momentum state (pass
    /// `&mut []` for SGD, which carries none). This is the kernel both
    /// [`OuterOpt::apply_range_scaled`] and the parallel shard apply
    /// fan-out call, so the threaded path is bitwise identical to the
    /// sequential one by construction.
    pub fn apply_scaled(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        delta: &[f32],
        beta: f32,
    ) {
        debug_assert_eq!(params.len(), delta.len());
        match *self {
            OuterOptKind::Sgd { lr } => {
                crate::tensor::kernels::scale_axpy(params, lr as f32, beta, delta);
            }
            OuterOptKind::Nesterov { lr, momentum: mu } => {
                let (lr, mu) = (lr as f32, mu as f32);
                debug_assert_eq!(momentum.len(), delta.len());
                for ((p, m), &d) in params.iter_mut().zip(momentum.iter_mut()).zip(delta) {
                    let g = -(beta * d);
                    *m = mu * *m + g;
                    *p -= lr * (g + mu * *m);
                }
            }
        }
    }
}

/// Outer optimizer state over the flat vector.
#[derive(Debug, Clone)]
pub struct OuterOpt {
    pub kind: OuterOptKind,
    /// Momentum buffer (empty for SGD).
    pub momentum: Vec<f32>,
}

impl OuterOpt {
    pub fn new(kind: OuterOptKind, n: usize) -> Self {
        let momentum = if kind.needs_momentum() { vec![0.0; n] } else { Vec::new() };
        Self { kind, momentum }
    }

    /// Apply the combined pseudo gradient `delta` to `params` in place,
    /// restricted to `[off, off+len)` (per-module application for the
    /// layer-wise EDiT sync; pass the full range otherwise).
    pub fn apply_range(&mut self, params: &mut [f32], delta: &[f32], off: usize) {
        self.apply_range_scaled(params, delta, off, 1.0);
    }

    /// [`Self::apply_range`] with the clip factor β fused in: each
    /// element applies `β·delta[i]` (one rounding for the scale, then the
    /// update — bitwise identical to scaling the delta first). The sync
    /// pipeline uses this so gradient clipping costs no extra pass over
    /// the combined pseudo gradient.
    pub fn apply_range_scaled(
        &mut self,
        params: &mut [f32],
        delta: &[f32],
        off: usize,
        beta: f32,
    ) {
        let moment = if self.kind.needs_momentum() {
            &mut self.momentum[off..off + delta.len()]
        } else {
            &mut []
        };
        self.kind
            .apply_scaled(&mut params[off..off + delta.len()], moment, delta, beta);
    }

    pub fn apply(&mut self, params: &mut [f32], delta: &[f32]) {
        debug_assert_eq!(params.len(), delta.len());
        self.apply_range(params, delta, 0);
    }

    /// Extra f32 elements of optimizer state per full replica. Under
    /// ZeRO-1 outer sharding (`TrainConfig::shard_outer`) each rank
    /// holds only its shard's slice of the momentum, so per-rank
    /// accounting passes the actual shard length (`TableShards::range`)
    /// as `n` — the range-aligned partition is uneven, so there is no
    /// closed-form `full/parts` shortcut (see
    /// `Trainer::shard_sync_high_water`).
    pub fn state_elems(&self, n: usize) -> usize {
        if self.kind.needs_momentum() { n } else { 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, check};

    #[test]
    fn sgd_lr1_is_parameter_adoption() {
        // With ν=1 the result is θ_t + Δ = the averaged local params —
        // Post Local SGD's plain averaging.
        let mut opt = OuterOpt::new(OuterOptKind::averaging(), 3);
        let anchor = vec![1.0f32, 2.0, 3.0];
        let mut params = anchor.clone();
        let delta = vec![0.5f32, -0.5, 0.25]; // mean(θ_local) - anchor
        opt.apply(&mut params, &delta);
        assert_close(&params, &[1.5, 1.5, 3.25], 1e-6, 0.0);
    }

    #[test]
    fn nesterov_first_step() {
        // m=0: m' = g; θ' = θ - ν(g + μ g) = θ + ν(1+μ)Δ
        let mut opt =
            OuterOpt::new(OuterOptKind::Nesterov { lr: 0.5, momentum: 0.8 }, 2);
        let mut params = vec![0.0f32, 0.0];
        opt.apply(&mut params, &[1.0, -2.0]);
        assert_close(&params, &[0.5 * 1.8, -0.5 * 1.8 * 2.0], 1e-6, 0.0);
        assert_close(&opt.momentum, &[-1.0, 2.0], 1e-6, 0.0);
    }

    #[test]
    fn nesterov_momentum_accumulates() {
        let mut opt =
            OuterOpt::new(OuterOptKind::Nesterov { lr: 1.0, momentum: 0.5 }, 1);
        let mut params = vec![0.0f32];
        opt.apply(&mut params, &[1.0]);
        let after1 = params[0]; // 1.5
        opt.apply(&mut params, &[1.0]);
        // m2 = 0.5*(-1) + (-1) = -1.5; step = -( -1 + 0.5*-1.5 ) = 1.75
        assert!((after1 - 1.5).abs() < 1e-6);
        assert!((params[0] - (1.5 + 1.75)).abs() < 1e-6);
    }

    #[test]
    fn zero_delta_sgd_is_identity_nesterov_coasts() {
        let mut sgd = OuterOpt::new(OuterOptKind::Sgd { lr: 1.0 }, 2);
        let p = vec![1.0f32, 2.0];
        sgd.apply(&mut p.clone(), &[0.0, 0.0]);
        assert_eq!(p, vec![1.0, 2.0]);

        let mut nes =
            OuterOpt::new(OuterOptKind::Nesterov { lr: 1.0, momentum: 0.5 }, 2);
        let mut p = vec![0.0f32, 0.0];
        nes.apply(&mut p, &[1.0, 1.0]);
        let v1 = p[0];
        // zero delta: momentum keeps pushing (coasting), decayed by μ
        nes.apply(&mut p, &[0.0, 0.0]);
        assert!(p[0] > v1);
    }

    #[test]
    fn scaled_apply_equals_scale_then_apply() {
        check("outer-scaled-apply", 25, |g| {
            let n = g.len() * 4;
            let delta = g.vec_f32(n, 1.0);
            let start = g.vec_f32(n, 1.0);
            let beta = 0.25 + g.rng.f32() * 0.75;
            for kind in [
                OuterOptKind::Sgd { lr: 0.7 },
                OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 },
            ] {
                let mut fused = OuterOpt::new(kind, n);
                let mut p_fused = start.clone();
                fused.apply_range_scaled(&mut p_fused, &delta, 0, beta);

                let mut two_pass = OuterOpt::new(kind, n);
                let mut p_two = start.clone();
                let scaled: Vec<f32> = delta.iter().map(|&d| beta * d).collect();
                two_pass.apply(&mut p_two, &scaled);

                assert_eq!(p_fused, p_two, "{kind:?}");
                assert_eq!(fused.momentum, two_pass.momentum, "{kind:?}");
            }
        });
    }

    #[test]
    fn per_module_equals_full_apply() {
        check("outer-per-module", 25, |g| {
            let n = g.len() * 4;
            let delta = g.vec_f32(n, 1.0);
            let start = g.vec_f32(n, 1.0);
            let kind = if g.bool() {
                OuterOptKind::Sgd { lr: 0.7 }
            } else {
                OuterOptKind::Nesterov { lr: 0.8, momentum: 0.85 }
            };
            let mut full = OuterOpt::new(kind, n);
            let mut p_full = start.clone();
            full.apply(&mut p_full, &delta);

            let mut ranged = OuterOpt::new(kind, n);
            let mut p_ranged = start.clone();
            let mid = n / 2;
            ranged.apply_range(&mut p_ranged, &delta[..mid], 0);
            ranged.apply_range(&mut p_ranged, &delta[mid..], mid);
            assert_close(&p_ranged, &p_full, 1e-6, 1e-5);
            if kind.needs_momentum() {
                assert_close(&ranged.momentum, &full.momentum, 1e-6, 1e-5);
            }
        });
    }
}
