//! `MethodSpec` — the compositional strategy descriptor behind the
//! method zoo.
//!
//! The paper's methods (Baseline / Post Local SGD / DiLoCo / CO2 / CO2*
//! / EDiT / A-EDiT) and its §4.4 ablations all differ along a handful
//! of **orthogonal axes**; this module makes those axes first-class
//! plain data instead of scattered predicates on an enum:
//!
//! | axis               | values                               | consumers |
//! |--------------------|--------------------------------------|-----------|
//! | `trigger`          | none / step-τ / time-τ / prob(p)     | engine round driver, cluster straggler model |
//! | `granularity`      | flat / layer-wise                    | engine sync path, overlap & memory models |
//! | `outer`            | SGD / Nesterov (+hyperparams)        | outer optimizer, memory model |
//! | `outer_staleness`  | 0 / k rounds (CO2 overlap)           | staleness queue, trace/step models |
//! | `penalty`          | per-stage toggles + hyperparams      | sync numerics, anomaly detector |
//! | `shard_outer_state`| full copy / sharded over the group   | memory model (Table 2 OOM column) |
//! | `shard_anchor`     | full copy / sharded                  | memory model |
//! | `warmup`           | DDP warmup phase applies             | engine phase logic |
//! | `payload`          | f32 / int8 / bit1 (error feedback)   | sync numerics, collectives, α-β cost model |
//!
//! Every named method is a row of this table ([`Method::spec`]), every
//! consumer (trainer, step/trace/memory models, cluster simulator)
//! dispatches on the axes, and new strategies are **registered as
//! descriptors** — no engine or simulator code to touch. `palsgd`
//! (probabilistic time-based synchronization in the style of Naganuma
//! et al., *Pseudo-Asynchronous Local SGD*, 2025) is exactly that: one
//! preset row riding the existing A-EDiT event core.
//!
//! The `custom:` grammar ([`MethodSpec::parse`]) exposes the axes on
//! the CLI, which makes the paper's §4.4 ablation rows first-class
//! runs: `--method custom:base=edit,penalty=off` or
//! `custom:base=edit,sync=flat` (see `experiments::convergence::
//! ablation_rows`).

use super::method::Method;
use super::outer::OuterOptKind;
use super::penalty::PenaltyConfig;

pub use crate::tensor::kernels::PayloadKind;

/// When does a replica become sync-eligible?
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SyncTrigger {
    /// Never: fully synchronous mini-batch DDP every step (Baseline).
    None,
    /// Every τ inner steps, barriered across all replicas.
    Step,
    /// Every τ_time simulated seconds, per-replica anchor sync with no
    /// global barrier (A-EDiT, §3.3).
    Time,
    /// Time-based deadline windows like [`SyncTrigger::Time`], but each
    /// replica joins a window's sync only with probability `prob`
    /// (stateless draw — see `engine::worker::sync_draw`); skipped
    /// replicas keep training against their stale anchor (PALSGD).
    Probabilistic { prob: f64 },
}

impl SyncTrigger {
    /// Deadline-driven (event-core) trigger, as opposed to the fixed
    /// step count? Selects the per-replica anchor-sync path.
    pub fn time_based(&self) -> bool {
        matches!(self, SyncTrigger::Time | SyncTrigger::Probabilistic { .. })
    }
}

/// Synchronization granularity at an outer boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncGranularity {
    /// One full-vector exchange + uniform pseudo-gradient mean.
    Flat,
    /// Per-module sweep (screen → weight → combine → clip → apply),
    /// overlappable with the next round's forward pass (§3.1).
    Layerwise,
}

/// Plain-data strategy descriptor: the single source of truth for every
/// behavioral axis of a training method. `Copy`, comparable, and
/// constructible from the preset table ([`Method::spec`]), the
/// `custom:` grammar ([`MethodSpec::parse`]) or field-by-field.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MethodSpec {
    pub trigger: SyncTrigger,
    pub granularity: SyncGranularity,
    /// Outer optimizer over the combined pseudo gradient.
    pub outer: OuterOptKind,
    /// Rounds of staleness on the outer update (CO2-style overlap; the
    /// update combined in round t lands in round t+k).
    pub outer_staleness: usize,
    /// Pseudo-gradient penalty stages + hyperparameters (Alg. 2).
    pub penalty: PenaltyConfig,
    /// Outer-optimizer state sharded across the shard group (vs a full
    /// copy per worker)? Drives the memory model (Table 2 OOM column)
    /// and the trainer's default runtime ZeRO-1 toggle
    /// (`TrainConfig::shard_outer` starts from this axis).
    pub shard_outer_state: bool,
    /// Extra full parameter copy (θ_t anchor) sharded?
    pub shard_anchor: bool,
    /// DDP warmup phase applies (two-phase training, Alg. 1).
    pub warmup: bool,
    /// Wire format of the pseudo-gradient payload. Quantized payloads
    /// (`int8`/`bit1`) compress the sync exchange with per-chunk scales
    /// and an error-feedback residual carried in `SyncScratch`;
    /// [`PayloadKind::F32`] is a complete code-path bypass, bitwise
    /// identical to the pre-payload-axis behavior.
    pub payload: PayloadKind,
}

impl MethodSpec {
    /// Does this strategy run periodic (local-SGD) synchronization at
    /// all? `false` only for the pure-DDP baseline.
    pub fn is_local_sgd(&self) -> bool {
        !matches!(self.trigger, SyncTrigger::None)
    }

    /// Layer-wise (per-module) synchronization?
    pub fn layerwise(&self) -> bool {
        self.granularity == SyncGranularity::Layerwise
    }

    /// Any pseudo-gradient penalty stage active?
    pub fn uses_penalty(&self) -> bool {
        self.penalty.anomaly_elimination
            || self.penalty.weighted_averaging
            || self.penalty.gradient_clip
    }

    /// Can the extra local-SGD state be staged on CPU when memory is
    /// tight? Only when the outer update is applied immediately
    /// (`outer_staleness == 0` — an overlapped in-flight buffer must
    /// stay pinned on GPU) and there is momentum worth staging.
    pub fn extra_offloadable(&self) -> bool {
        self.is_local_sgd() && self.outer_staleness == 0 && self.outer.needs_momentum()
    }

    /// Does the strategy shard the *model* state (ZeRO-3) on the mesh?
    /// Plain DDP composes with ZeRO-3; among the local-SGD strategies
    /// only the layer-wise ones do (paper §2: the All-Reduce-based
    /// methods hold complete parameters on every GPU).
    pub fn model_sharded(&self) -> bool {
        !self.is_local_sgd() || self.layerwise()
    }

    /// Canonicalize a hand-built/parsed spec: the flat sync path has no
    /// per-module statistics, so penalty stages are cleared there (the
    /// §4.4 "w/o layer-wise sync" row drops the penalty with it).
    pub fn normalize(&mut self) {
        if !self.layerwise() && self.uses_penalty() {
            self.penalty = PenaltyConfig::disabled();
        }
    }

    /// Reject axis combinations the engine does not implement.
    pub fn validate(&self) -> Result<(), String> {
        if let SyncTrigger::Probabilistic { prob } = self.trigger {
            if !(prob > 0.0 && prob <= 1.0) {
                return Err(format!(
                    "probabilistic sync needs 0 < prob <= 1, got {prob}"
                ));
            }
        }
        if self.trigger.time_based() && !self.layerwise() {
            return Err(
                "time-based/probabilistic triggers ride the per-module anchor \
                 sync; add sync=layer (or drop trigger=time/prob)"
                    .into(),
            );
        }
        if self.outer_staleness > 0 && self.layerwise() {
            return Err(
                "outer staleness (CO2 overlap) is only implemented for the \
                 flat sync path; use sync=flat with staleness=N"
                    .into(),
            );
        }
        if self.outer_staleness > 0 && self.trigger != SyncTrigger::Step {
            return Err("outer staleness requires the step-τ trigger".into());
        }
        if self.uses_penalty() && !self.layerwise() {
            return Err(
                "the pseudo-gradient penalty needs per-module statistics; \
                 use sync=layer or penalty=off"
                    .into(),
            );
        }
        if self.payload.quantized() && !self.is_local_sgd() {
            return Err(
                "payload quantization compresses the local-SGD sync exchange; \
                 it has no effect with trigger=none (pure DDP) — drop payload= \
                 or pick a syncing trigger"
                    .into(),
            );
        }
        Ok(())
    }

    /// Set one axis from its `custom:` grammar key/value (also the
    /// backing store for the `train.*` config keys — see
    /// [`CUSTOM_GRAMMAR`]).
    pub fn set_axis(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "base" => {
                let m = Method::parse(value).ok_or_else(|| {
                    format!(
                        "unknown base method '{value}' (expected one of: {})",
                        Method::name_list()
                    )
                })?;
                *self = m.spec();
            }
            "sync" => {
                self.granularity = match value {
                    "layer" | "layerwise" => SyncGranularity::Layerwise,
                    "flat" | "full" => SyncGranularity::Flat,
                    other => return Err(format!("sync must be layer|flat, got '{other}'")),
                }
            }
            "trigger" => {
                self.trigger = if value == "step" {
                    SyncTrigger::Step
                } else if value == "time" {
                    SyncTrigger::Time
                } else if value == "none" {
                    SyncTrigger::None
                } else if let Some(p) = value.strip_prefix("prob:") {
                    let prob: f64 = p
                        .parse()
                        .map_err(|_| format!("bad probability in trigger '{value}'"))?;
                    SyncTrigger::Probabilistic { prob }
                } else {
                    return Err(format!(
                        "trigger must be step|time|prob:<p>|none, got '{value}'"
                    ));
                }
            }
            "penalty" => match value {
                "on" => self.penalty = PenaltyConfig::default(),
                "off" => self.penalty = PenaltyConfig::disabled(),
                "no-ae" => self.penalty.anomaly_elimination = false,
                "no-wa" => self.penalty.weighted_averaging = false,
                "no-gc" => self.penalty.gradient_clip = false,
                other => {
                    return Err(format!(
                        "penalty must be on|off|no-ae|no-wa|no-gc, got '{other}'"
                    ))
                }
            },
            "outer" => self.outer = parse_outer(value)?,
            "staleness" => {
                self.outer_staleness = value
                    .parse()
                    .map_err(|_| format!("staleness must be an integer, got '{value}'"))?
            }
            "warmup" => self.warmup = parse_bool("warmup", value)?,
            "payload" => {
                self.payload = PayloadKind::parse(value).ok_or_else(|| {
                    format!("payload must be f32|int8|bit1, got '{value}'")
                })?
            }
            "shard" => {
                let b = parse_bool("shard", value)?;
                self.shard_outer_state = b;
                self.shard_anchor = b;
            }
            other => {
                return Err(format!(
                    "unknown custom-method key '{other}' ({CUSTOM_GRAMMAR})"
                ))
            }
        }
        Ok(())
    }

    /// Resolve a method string — a named preset (`edit`, `palsgd`, ...)
    /// or the `custom:` grammar — into `(spec, canonical label)`. The
    /// label round-trips: `parse(label)` yields the same spec.
    pub fn parse(s: &str) -> Result<(MethodSpec, String), String> {
        let raw = s.trim().to_ascii_lowercase();
        if let Some(m) = Method::parse(&raw) {
            return Ok((m.spec(), m.name().to_string()));
        }
        let Some(body) = raw.strip_prefix("custom:") else {
            return Err(format!(
                "unknown method '{s}'. valid methods: {}; or a custom \
                 descriptor ({CUSTOM_GRAMMAR})",
                Method::name_list()
            ));
        };
        let mut spec = Method::Edit.spec();
        let mut explicit_penalty = false;
        for (i, pair) in body.split(',').filter(|p| !p.trim().is_empty()).enumerate() {
            let (key, value) = pair.split_once('=').ok_or_else(|| {
                format!("custom method: expected key=value, got '{pair}' ({CUSTOM_GRAMMAR})")
            })?;
            let key = key.trim();
            // base= resets every axis, so later keys layer on top of it;
            // accepting it mid-list would silently wipe earlier keys.
            if key == "base" && i > 0 {
                return Err(
                    "base= must be the first key of a custom descriptor \
                     (it resets every axis)"
                        .into(),
                );
            }
            explicit_penalty |= key == "penalty";
            spec.set_axis(key, value.trim())?;
        }
        // An explicitly requested penalty must not be silently dropped
        // by the flat-sync normalization — that combination is an error.
        // (Penalty stages merely *inherited* from the base preset
        // normalize away quietly: that is the §4.4 flat-sync row.)
        if explicit_penalty && !spec.layerwise() && spec.uses_penalty() {
            return Err(
                "penalty=... conflicts with sync=flat (penalty stages need \
                 per-module statistics); drop the penalty key or use sync=layer"
                    .into(),
            );
        }
        spec.normalize();
        spec.validate()?;
        Ok((spec, raw))
    }
}

/// One-line help string for the `custom:` method grammar, embedded in
/// CLI errors and `edit-train` usage output.
pub const CUSTOM_GRAMMAR: &str = "custom:base=<method>[,key=value...] with keys \
base=<named method>, sync=layer|flat, trigger=step|time|prob:<p>, \
penalty=on|off|no-ae|no-wa|no-gc, outer=nesterov[:lr[:mu]]|sgd[:lr]|avg, \
staleness=<rounds>, shard=on|off, warmup=on|off, payload=f32|int8|bit1 \
— e.g. custom:base=edit,penalty=off,sync=flat";

fn parse_bool(key: &str, value: &str) -> Result<bool, String> {
    match value {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => Err(format!("{key} must be on|off, got '{other}'")),
    }
}

fn parse_outer(value: &str) -> Result<OuterOptKind, String> {
    let mut parts = value.split(':');
    let kind = parts.next().unwrap_or("");
    let lr = parts.next();
    let mu = parts.next();
    if parts.next().is_some() {
        return Err(format!("outer has too many ':' parts: '{value}'"));
    }
    let parse_f = |s: &str| -> Result<f64, String> {
        s.parse()
            .map_err(|_| format!("bad number '{s}' in outer '{value}'"))
    };
    match kind {
        "avg" | "averaging" => {
            if lr.is_some() {
                return Err("outer=avg takes no hyperparameters".into());
            }
            Ok(OuterOptKind::averaging())
        }
        "sgd" => {
            if mu.is_some() {
                return Err(format!("outer=sgd takes at most one ':lr' part: '{value}'"));
            }
            Ok(OuterOptKind::Sgd {
                lr: lr.map(parse_f).transpose()?.unwrap_or(1.0),
            })
        }
        "nesterov" => {
            let base = OuterOptKind::paper_nesterov();
            let (dlr, dmu) = match base {
                OuterOptKind::Nesterov { lr, momentum } => (lr, momentum),
                _ => unreachable!(),
            };
            Ok(OuterOptKind::Nesterov {
                lr: lr.map(parse_f).transpose()?.unwrap_or(dlr),
                momentum: mu.map(parse_f).transpose()?.unwrap_or(dmu),
            })
        }
        other => Err(format!(
            "outer must be nesterov[:lr[:mu]]|sgd[:lr]|avg, got '{other}'"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The historical predicate matrix, restated over the spec axes —
    /// the preset table must encode exactly the seed semantics.
    #[test]
    fn preset_axes_match_paper_property_matrix() {
        use Method::*;
        assert!(!Baseline.spec().is_local_sgd());
        for m in [PostLocalSgd, DiLoCo, Co2, Co2Star, Edit, AEdit, Palsgd] {
            assert!(m.spec().is_local_sgd(), "{m:?}");
        }
        assert!(Edit.spec().uses_penalty() && AEdit.spec().uses_penalty());
        assert!(!DiLoCo.spec().uses_penalty());
        assert!(Edit.spec().layerwise() && AEdit.spec().layerwise());
        assert!(!Co2.spec().layerwise() && !PostLocalSgd.spec().layerwise());
        assert_eq!(Co2.spec().outer_staleness, 1);
        assert_eq!(Co2Star.spec().outer_staleness, 1);
        assert_eq!(DiLoCo.spec().outer_staleness, 0);
        assert!(Co2Star.spec().shard_outer_state && !Co2.spec().shard_outer_state);
        assert!(Edit.spec().shard_outer_state && Edit.spec().shard_anchor);
        assert!(AEdit.spec().trigger.time_based() && !Edit.spec().trigger.time_based());
        assert_eq!(Edit.spec().trigger, SyncTrigger::Step);
        assert!(PostLocalSgd.spec().warmup && !DiLoCo.spec().warmup);
        assert_eq!(PostLocalSgd.spec().outer, OuterOptKind::averaging());
        assert_eq!(Edit.spec().outer, OuterOptKind::paper_nesterov());
        // Derived axes reproduce the seed memory-model tables.
        assert!(Baseline.spec().model_sharded());
        assert!(Edit.spec().model_sharded() && AEdit.spec().model_sharded());
        for m in [PostLocalSgd, DiLoCo, Co2, Co2Star] {
            assert!(!m.spec().model_sharded(), "{m:?}");
        }
        for m in [DiLoCo, Edit, AEdit] {
            assert!(m.spec().extra_offloadable(), "{m:?}");
        }
        for m in [Baseline, PostLocalSgd, Co2, Co2Star] {
            assert!(!m.spec().extra_offloadable(), "{m:?}");
        }
    }

    #[test]
    fn palsgd_is_a_probabilistic_aedit() {
        let p = Method::Palsgd.spec();
        assert!(matches!(p.trigger, SyncTrigger::Probabilistic { prob } if prob > 0.0));
        assert!(p.trigger.time_based());
        // Everything else rides the EDiT/A-EDiT recipe.
        let mut a = Method::AEdit.spec();
        a.trigger = p.trigger;
        assert_eq!(a, p);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn named_parse_roundtrip() {
        for m in Method::NAMED {
            let (spec, label) = MethodSpec::parse(m.name()).unwrap();
            assert_eq!(spec, m.spec(), "{m:?}");
            assert_eq!(label, m.name());
        }
        let (spec, _) = MethodSpec::parse("PALSGD").unwrap();
        assert_eq!(spec, Method::Palsgd.spec());
    }

    #[test]
    fn custom_grammar_parse_and_roundtrip() {
        let cases = [
            "custom:base=edit",
            "custom:base=edit,penalty=off",
            "custom:base=edit,sync=flat",
            "custom:base=edit,penalty=no-ae,penalty=no-gc",
            "custom:base=diloco,staleness=1",
            "custom:base=a-edit,trigger=prob:0.25",
            "custom:base=edit,outer=sgd:0.7,warmup=off,shard=off",
            "custom:base=edit,payload=int8",
            "custom:base=a-edit,payload=bit1",
            "custom:base=diloco,payload=int8",
        ];
        for s in cases {
            let (spec, label) = MethodSpec::parse(s).unwrap();
            // The canonical label round-trips to the same spec.
            let (spec2, label2) = MethodSpec::parse(&label).unwrap();
            assert_eq!(spec, spec2, "{s}");
            assert_eq!(label, label2, "{s}");
            assert!(spec.validate().is_ok(), "{s}");
        }
        // Semantic spot checks.
        let (base, _) = MethodSpec::parse("custom:base=edit").unwrap();
        assert_eq!(base, Method::Edit.spec());
        let (off, _) = MethodSpec::parse("custom:base=edit,penalty=off").unwrap();
        assert!(!off.uses_penalty() && off.layerwise());
        let (flat, _) = MethodSpec::parse("custom:base=edit,sync=flat").unwrap();
        assert!(!flat.layerwise());
        // Flat sync drops the per-module penalty with it (normalize).
        assert!(!flat.uses_penalty());
        let (noae, _) =
            MethodSpec::parse("custom:base=edit,penalty=no-ae,penalty=no-gc").unwrap();
        assert!(!noae.penalty.anomaly_elimination);
        assert!(noae.penalty.weighted_averaging);
        assert!(!noae.penalty.gradient_clip);
        let (sgd, _) =
            MethodSpec::parse("custom:base=edit,outer=sgd:0.7,warmup=off,shard=off").unwrap();
        assert_eq!(sgd.outer, OuterOptKind::Sgd { lr: 0.7 });
        assert!(!sgd.warmup && !sgd.shard_outer_state && !sgd.shard_anchor);
        // Presets default to the uncompressed wire format; payload= is
        // purely additive on top of any base.
        assert_eq!(base.payload, PayloadKind::F32);
        let (q, _) = MethodSpec::parse("custom:base=edit,payload=int8").unwrap();
        assert_eq!(q.payload, PayloadKind::Int8);
        let mut f32_again = q;
        f32_again.payload = PayloadKind::F32;
        assert_eq!(f32_again, Method::Edit.spec());
    }

    #[test]
    fn custom_grammar_rejects_bad_input() {
        for s in [
            "nope",
            "custom:granularity=layer",   // unknown key
            "custom:base=nope",           // unknown base
            "custom:base=edit,sync=diag", // bad value
            "custom:base=edit,trigger=prob:0", // prob out of range
            "custom:base=edit,trigger=prob:1.5",
            "custom:base=edit,penalty",           // missing '='
            "custom:base=edit,outer=adamw",       // unknown outer
            "custom:base=co2,trigger=time",       // staleness + time trigger
            "custom:base=edit,sync=flat,trigger=time", // flat + time trigger
            "custom:sync=flat,base=edit",         // base= must come first
            "custom:base=edit,sync=flat,penalty=on", // explicit penalty vs flat
            "custom:base=edit,payload=f16",       // unknown payload
            "custom:base=baseline,payload=int8",  // quantized + no sync
            "custom:base=edit,trigger=none,payload=bit1", // same, explicit
        ] {
            let err = MethodSpec::parse(s).unwrap_err();
            assert!(!err.is_empty(), "{s}");
        }
        // The unknown-method error lists the valid names and grammar.
        let err = MethodSpec::parse("nope").unwrap_err();
        for name in ["baseline", "edit", "a-edit", "palsgd", "custom:"] {
            assert!(err.contains(name), "error should mention '{name}': {err}");
        }
    }

    #[test]
    fn validate_rejects_unimplemented_combinations() {
        let mut s = Method::Edit.spec();
        s.outer_staleness = 1;
        assert!(s.validate().is_err(), "layerwise + staleness");
        let mut s = Method::Co2.spec();
        s.trigger = SyncTrigger::Time;
        assert!(s.validate().is_err(), "flat + time trigger");
        let mut s = Method::AEdit.spec();
        s.trigger = SyncTrigger::Probabilistic { prob: 0.0 };
        assert!(s.validate().is_err(), "prob out of range");
    }

    #[test]
    fn normalize_clears_penalty_on_flat() {
        let mut s = Method::Edit.spec();
        s.granularity = SyncGranularity::Flat;
        s.normalize();
        assert!(!s.uses_penalty());
        // Layer-wise specs are untouched.
        let mut e = Method::Edit.spec();
        e.normalize();
        assert_eq!(e, Method::Edit.spec());
    }
}
