//! Pseudo-gradient penalty (paper §3.2, Alg. 2): the stability core of
//! EDiT.  Three composable stages, each individually ablatable
//! (Fig. 7a):
//!
//!  1. **Anomaly elimination** — per (replica, module) EMA z-test on the
//!     pseudo-gradient norm G; z = (G-μ)/σ > δ ⇒ norm set to +inf so
//!     the weighting stage zeroes that replica's contribution.  μ, σ
//!     update by EMA (Eq. 1, α = 0.02), skipped for anomalous samples;
//!     a warm-up period never flags.
//!  2. **Weighted averaging** — w_i = softmax(-G_i) (Eq. 2/3):
//!     larger-norm replicas are suppressed, inf-norm replicas excluded.
//!  3. **Gradient clip** — β = min(φ/(‖Δ̄‖+ε), 1) (Eq. 4/5).
//!
//! If every replica in the group is anomalous the combined update is
//! declared a rollback (θ stays at the last synced value).
//!
//! The O(W·n) math here is the pure-Rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/penalty.py`); `rust/tests/golden_penalty.rs`
//! asserts both agree on the exported golden vectors, and the runtime
//! can execute the AOT HLO variant instead (`Engine::penalty_combine`).

use crate::tensor;

/// Penalty hyperparameters (paper defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PenaltyConfig {
    /// Clip threshold φ (paper: 10).
    pub phi: f64,
    /// z-score threshold δ (paper: 3).
    pub delta: f64,
    /// EMA coefficient α (paper: 0.02).
    pub alpha: f64,
    /// Sync steps before the z-test may flag anomalies.
    pub warmup_syncs: u64,
    /// σ is floored at this fraction of |μ| so the z-test stays robust
    /// while the EMA variance is still accumulating (the paper's
    /// "warm-up period to establish stable values" plus a guard).
    pub sigma_floor_frac: f64,
    /// Ablation toggles (Fig. 7a: w/o AE / WA / GC / ALL).
    pub anomaly_elimination: bool,
    pub weighted_averaging: bool,
    pub gradient_clip: bool,
    pub eps: f64,
}

impl Default for PenaltyConfig {
    fn default() -> Self {
        Self {
            phi: 10.0,
            delta: 3.0,
            alpha: 0.02,
            warmup_syncs: 5,
            sigma_floor_frac: 0.05,
            anomaly_elimination: true,
            weighted_averaging: true,
            gradient_clip: true,
            eps: 1e-8,
        }
    }
}

impl PenaltyConfig {
    pub fn disabled() -> Self {
        Self {
            anomaly_elimination: false,
            weighted_averaging: false,
            gradient_clip: false,
            ..Self::default()
        }
    }

    pub fn without(mut self, stage: &str) -> Self {
        match stage {
            "ae" => self.anomaly_elimination = false,
            "wa" => self.weighted_averaging = false,
            "gc" => self.gradient_clip = false,
            "all" => return Self::disabled(),
            other => panic!("unknown penalty stage '{other}'"),
        }
        self
    }
}

/// EMA z-test state for one (replica, module) norm stream (Eq. 1).
#[derive(Debug, Clone, Copy)]
struct EmaStat {
    mean: f64,
    var: f64,
    initialized: bool,
}

impl EmaStat {
    fn new() -> Self {
        Self { mean: 0.0, var: 0.0, initialized: false }
    }

    fn z(&self, x: f64, sigma_floor_frac: f64) -> f64 {
        if !self.initialized {
            return 0.0;
        }
        let sigma = self.var.sqrt().max(sigma_floor_frac * self.mean.abs());
        if sigma <= 1e-12 {
            // Degenerate spread around zero: any deviation is anomalous.
            if (x - self.mean).abs() <= 1e-12 { 0.0 } else { f64::INFINITY }
        } else {
            (x - self.mean) / sigma
        }
    }

    /// Eq. 1: EMA mean then EMA variance against the *new* mean.
    fn update(&mut self, x: f64, alpha: f64) {
        if !self.initialized {
            self.mean = x;
            self.var = 0.0;
            self.initialized = true;
            return;
        }
        let mean_new = alpha * x + (1.0 - alpha) * self.mean;
        self.var = (1.0 - alpha) * self.var + alpha * (x - mean_new) * (x - mean_new);
        self.mean = mean_new;
    }
}

/// Per-(replica, module) anomaly detector.
#[derive(Debug, Clone)]
pub struct AnomalyDetector {
    stats: Vec<EmaStat>, // [replica * modules + module]
    modules: usize,
    syncs_seen: u64,
    cfg: PenaltyConfig,
    pub anomalies_flagged: u64,
    pub rollbacks: u64,
}

impl AnomalyDetector {
    pub fn new(replicas: usize, modules: usize, cfg: PenaltyConfig) -> Self {
        Self {
            stats: vec![EmaStat::new(); replicas * modules],
            modules,
            syncs_seen: 0,
            cfg,
            anomalies_flagged: 0,
            rollbacks: 0,
        }
    }

    /// Grow state when replicas are added elastically.
    pub fn resize_replicas(&mut self, replicas: usize) {
        self.stats.resize(replicas * self.modules, EmaStat::new());
    }

    /// Adopt a (possibly ablated/re-tuned) config; the trainer calls this
    /// each sync so `TrainConfig.penalty` edits take effect immediately.
    pub fn set_config(&mut self, cfg: PenaltyConfig) {
        self.cfg = cfg;
    }

    /// Screen per-replica norms for one module into `out` (cleared
    /// first): anomalous entries are replaced by +inf and EMA state is
    /// updated. Call once per sync per module, replicas in fixed order.
    /// Allocation-free when `out` already has capacity for the replicas
    /// (the `SyncScratch` arena guarantees this in steady state).
    pub fn screen_into(&mut self, module: usize, norms: &[f64], out: &mut Vec<f64>) {
        let in_warmup = self.syncs_seen < self.cfg.warmup_syncs;
        out.clear();
        for (replica, &g) in norms.iter().enumerate() {
            let screened = self.screen_one(replica * self.modules + module, g, in_warmup);
            out.push(screened);
        }
    }

    /// Subset variant of [`Self::screen_into`] for the per-replica
    /// anchor syncs (A-EDiT event groups): `norms[i]` belongs to replica
    /// `members[i]`; only those replicas' EMA states read/update, in
    /// slice order. With `members = [0, 1, .., n-1]` this is exactly
    /// [`Self::screen_into`].
    pub fn screen_subset_into(
        &mut self,
        module: usize,
        members: &[usize],
        norms: &[f64],
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(members.len(), norms.len());
        let in_warmup = self.syncs_seen < self.cfg.warmup_syncs;
        out.clear();
        for (&replica, &g) in members.iter().zip(norms) {
            let screened = self.screen_one(replica * self.modules + module, g, in_warmup);
            out.push(screened);
        }
    }

    /// z-test one (replica, module) norm: returns +inf (flagged, EMA
    /// untouched) or the norm itself (EMA updated — Eq. 1).
    fn screen_one(&mut self, idx: usize, g: f64, in_warmup: bool) -> f64 {
        let anomalous = self.cfg.anomaly_elimination
            && !in_warmup
            && (self.stats[idx].z(g, self.cfg.sigma_floor_frac) > self.cfg.delta
                || !g.is_finite());
        if anomalous {
            self.anomalies_flagged += 1;
            f64::INFINITY
        } else {
            self.stats[idx].update(g, self.cfg.alpha);
            g
        }
    }

    /// Allocating convenience wrapper around [`Self::screen_into`].
    pub fn screen(&mut self, module: usize, norms: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(norms.len());
        self.screen_into(module, norms, &mut out);
        out
    }

    /// Advance the sync counter (call once per sync round).
    pub fn advance(&mut self) {
        self.syncs_seen += 1;
    }

    pub fn syncs_seen(&self) -> u64 {
        self.syncs_seen
    }

    /// Export the per-(replica, module) EMA z-test state for
    /// checkpointing: `(means, variances, initialized-flags)`, each of
    /// length `replicas * modules` in `stats` index order.
    pub fn export_state(&self) -> (Vec<f64>, Vec<f64>, Vec<u8>) {
        let mut mean = Vec::with_capacity(self.stats.len());
        let mut var = Vec::with_capacity(self.stats.len());
        let mut init = Vec::with_capacity(self.stats.len());
        for s in &self.stats {
            mean.push(s.mean);
            var.push(s.var);
            init.push(s.initialized as u8);
        }
        (mean, var, init)
    }

    /// Restore the EMA state written by [`Self::export_state`]. Lengths
    /// must match the detector's current `replicas * modules` layout
    /// (resize before importing when the replica count changed).
    pub fn import_state(&mut self, mean: &[f64], var: &[f64], init: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            mean.len() == self.stats.len()
                && var.len() == self.stats.len()
                && init.len() == self.stats.len(),
            "detector state length {} != expected {}",
            mean.len(),
            self.stats.len()
        );
        for (i, s) in self.stats.iter_mut().enumerate() {
            *s = EmaStat { mean: mean[i], var: var[i], initialized: init[i] != 0 };
        }
        Ok(())
    }

    /// Restore the warmup/round counter alongside
    /// [`Self::import_state`] (the z-test warmup gate keys on it).
    pub fn restore_syncs_seen(&mut self, syncs_seen: u64) {
        self.syncs_seen = syncs_seen;
    }
}

/// Result of combining one module's pseudo gradients.
#[derive(Debug, Clone)]
pub struct CombineOut {
    /// Combined clipped pseudo gradient (len = module len); empty on
    /// rollback.
    pub delta: Vec<f32>,
    pub weights: Vec<f32>,
    pub beta: f64,
    pub rollback: bool,
}

/// Weighted-average weights from screened norms (Eq. 2) into `out`
/// (cleared first), stabilized by shifting by the min finite norm.
/// Returns `false` when every replica is anomalous (all-zero weights ⇒
/// rollback). Allocation-free when `out` has capacity for the replicas.
pub fn softmax_neg_weights_into(out: &mut Vec<f32>, norms: &[f64], weighted: bool) -> bool {
    out.clear();
    let mut n_finite = 0usize;
    let mut gmin = f64::INFINITY;
    for &g in norms {
        if g.is_finite() {
            n_finite += 1;
            gmin = gmin.min(g);
        }
    }
    if n_finite == 0 {
        out.extend(norms.iter().map(|_| 0.0f32));
        return false;
    }
    if !weighted {
        // Ablation w/o WA: uniform over non-anomalous replicas.
        let w = 1.0 / n_finite as f32;
        out.extend(norms.iter().map(|&g| if g.is_finite() { w } else { 0.0 }));
        return true;
    }
    // exp is evaluated twice per norm instead of staging raws in a heap
    // buffer: the group size is the replica count (~8), so recomputation
    // is cheaper than an allocation in the per-module hot loop.
    let total: f64 = norms
        .iter()
        .filter(|g| g.is_finite())
        .map(|&g| (-(g - gmin)).exp())
        .sum();
    out.extend(norms.iter().map(|&g| {
        if g.is_finite() {
            ((-(g - gmin)).exp() / total) as f32
        } else {
            0.0
        }
    }));
    true
}

/// Allocating convenience wrapper around [`softmax_neg_weights_into`].
pub fn softmax_neg_weights(norms: &[f64], weighted: bool) -> Vec<f32> {
    let mut out = Vec::with_capacity(norms.len());
    softmax_neg_weights_into(&mut out, norms, weighted);
    out
}

/// Full Alg. 2 combine for one module across replicas.
///
/// `deltas[r]` is replica r's pseudo gradient restricted to this module;
/// `screened_norms` come from [`AnomalyDetector::screen`].
pub fn combine(
    deltas: &[&[f32]],
    screened_norms: &[f64],
    cfg: &PenaltyConfig,
) -> CombineOut {
    debug_assert_eq!(deltas.len(), screened_norms.len());
    let weights = softmax_neg_weights(screened_norms, cfg.weighted_averaging);
    if weights.iter().all(|&w| w == 0.0) {
        return CombineOut { delta: Vec::new(), weights, beta: 0.0, rollback: true };
    }
    let len = deltas[0].len();
    let mut out = vec![0.0f32; len];
    // Fused: weighted combine + its squared norm in one sweep.
    let sq = tensor::kernels::weighted_sum_sq_into(&mut out, deltas, &weights);
    let mut beta = 1.0;
    if cfg.gradient_clip {
        let norm = sq.sqrt();
        beta = (cfg.phi / (norm + cfg.eps)).min(1.0);
        if beta < 1.0 {
            tensor::scale(&mut out, beta as f32);
        }
    }
    CombineOut { delta: out, weights, beta, rollback: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, check};

    fn norms_of(deltas: &[Vec<f32>]) -> Vec<f64> {
        deltas.iter().map(|d| tensor::norm(d)).collect()
    }

    #[test]
    fn uniform_when_equal_norms() {
        let deltas = vec![vec![1.0f32; 4], vec![-1.0f32; 4]];
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let out = combine(&refs, &norms_of(&deltas), &PenaltyConfig::default());
        assert_close(&out.weights, &[0.5, 0.5], 1e-6, 0.0);
        assert_close(&out.delta, &[0.0; 4], 1e-6, 0.0);
        assert!(!out.rollback);
    }

    #[test]
    fn larger_norm_downweighted() {
        let deltas = vec![vec![0.1f32; 4], vec![10.0f32; 4]];
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let out = combine(&refs, &norms_of(&deltas), &PenaltyConfig::default());
        assert!(out.weights[0] > 0.99);
    }

    #[test]
    fn clip_engages_above_phi() {
        let deltas = vec![vec![100.0f32; 100]];
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let cfg = PenaltyConfig { phi: 1.0, ..Default::default() };
        let out = combine(&refs, &norms_of(&deltas), &cfg);
        assert!(out.beta < 1.0);
        assert!((tensor::norm(&out.delta) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn clip_disabled_by_ablation() {
        let deltas = vec![vec![100.0f32; 100]];
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let cfg = PenaltyConfig { phi: 1.0, ..Default::default() }.without("gc");
        let out = combine(&refs, &norms_of(&deltas), &cfg);
        assert_eq!(out.beta, 1.0);
        assert!(tensor::norm(&out.delta) > 100.0);
    }

    #[test]
    fn all_anomalous_rolls_back() {
        let deltas = vec![vec![1.0f32; 4], vec![2.0f32; 4]];
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let out = combine(&refs, &[f64::INFINITY, f64::INFINITY], &PenaltyConfig::default());
        assert!(out.rollback);
        assert!(out.delta.is_empty());
    }

    #[test]
    fn wa_ablation_uniform_over_survivors() {
        let w = softmax_neg_weights(&[1.0, f64::INFINITY, 5.0], false);
        assert_close(&w, &[0.5, 0.0, 0.5], 1e-6, 0.0);
    }

    #[test]
    fn weights_form_simplex() {
        check("penalty-simplex", 30, |g| {
            let n = g.len().min(8).max(2);
            let norms: Vec<f64> = (0..n)
                .map(|i| {
                    if i == 0 || !g.bool() { g.rng.f64() * 100.0 } else { f64::INFINITY }
                })
                .collect();
            let w = softmax_neg_weights(&norms, true);
            let sum: f32 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
            assert!(w.iter().all(|&x| x >= 0.0));
            for (i, &g_i) in norms.iter().enumerate() {
                if !g_i.is_finite() {
                    assert_eq!(w[i], 0.0);
                }
            }
        });
    }

    #[test]
    fn clip_never_increases_norm() {
        check("penalty-clip-bound", 25, |g| {
            let n = g.len() * 3 + 1;
            let w = g.usize(1, 5);
            let deltas: Vec<Vec<f32>> =
                (0..w).map(|_| g.vec_f32(n, 30.0)).collect();
            let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
            let cfg = PenaltyConfig { phi: 2.0, ..Default::default() };
            let out = combine(&refs, &norms_of(&deltas), &cfg);
            assert!(tensor::norm(&out.delta) <= 2.0 + 1e-3);
        });
    }

    // ---- detector ----------------------------------------------------------

    #[test]
    fn detector_never_flags_in_warmup() {
        let cfg = PenaltyConfig { warmup_syncs: 3, ..Default::default() };
        let mut det = AnomalyDetector::new(2, 1, cfg);
        for _ in 0..3 {
            let screened = det.screen(0, &[1.0, 1000.0]);
            assert!(screened.iter().all(|g| g.is_finite()));
            det.advance();
        }
    }

    #[test]
    fn detector_flags_spike_after_warmup() {
        let cfg = PenaltyConfig { warmup_syncs: 2, ..Default::default() };
        let mut det = AnomalyDetector::new(1, 1, cfg);
        // Establish a stable stream around 1.0 with a little variance.
        for i in 0..30 {
            let g = 1.0 + 0.05 * ((i % 3) as f64 - 1.0);
            det.screen(0, &[g]);
            det.advance();
        }
        let screened = det.screen(0, &[50.0]);
        assert!(screened[0].is_infinite());
        assert_eq!(det.anomalies_flagged, 1);
        // Normal value right after is still accepted (EMA not poisoned).
        let screened = det.screen(0, &[1.02]);
        assert!(screened[0].is_finite());
    }

    #[test]
    fn detector_ablation_never_flags() {
        let cfg = PenaltyConfig { warmup_syncs: 0, ..Default::default() }.without("ae");
        let mut det = AnomalyDetector::new(1, 1, cfg);
        for _ in 0..10 {
            det.screen(0, &[1.0]);
            det.advance();
        }
        let screened = det.screen(0, &[1e9]);
        assert!(screened[0].is_finite());
    }

    #[test]
    fn detector_tracks_slow_drift() {
        // Gradual norm decay (convergence trend) must NOT be flagged.
        let cfg = PenaltyConfig { warmup_syncs: 2, ..Default::default() };
        let mut det = AnomalyDetector::new(1, 1, cfg);
        let mut g = 10.0;
        for _ in 0..200 {
            let screened = det.screen(0, &[g]);
            assert!(screened[0].is_finite(), "flagged at g={g}");
            det.advance();
            g *= 0.995;
        }
    }

    #[test]
    fn detector_per_module_independent() {
        let cfg = PenaltyConfig { warmup_syncs: 1, ..Default::default() };
        let mut det = AnomalyDetector::new(1, 2, cfg);
        for i in 0..30 {
            let jitter = 0.01 * ((i % 5) as f64);
            det.screen(0, &[1.0 + jitter]);
            det.screen(1, &[100.0 + jitter]);
            det.advance();
        }
        // 100 is normal for module 1, anomalous for module 0.
        assert!(det.screen(0, &[100.0])[0].is_infinite());
        assert!(det.screen(1, &[100.0])[0].is_finite());
    }

    #[test]
    fn subset_screen_touches_only_members() {
        let cfg = PenaltyConfig { warmup_syncs: 0, ..Default::default() };
        let mut det = AnomalyDetector::new(3, 1, cfg);
        // Seed replicas 0 and 2 with a stable stream via subset screens.
        let mut out = Vec::new();
        for i in 0..25 {
            let jitter = 0.01 * ((i % 4) as f64);
            det.screen_subset_into(0, &[0, 2], &[1.0 + jitter, 1.0 + jitter], &mut out);
            assert!(out.iter().all(|g| g.is_finite()));
            det.advance();
        }
        // A spike is anomalous for the seeded members...
        det.screen_subset_into(0, &[0, 2], &[40.0, 40.0], &mut out);
        assert!(out[0].is_infinite() && out[1].is_infinite());
        // ...but replica 1 was never updated, so its first sample passes.
        det.screen_subset_into(0, &[1], &[40.0], &mut out);
        assert!(out[0].is_finite());
    }

    #[test]
    fn subset_screen_identity_matches_full() {
        let cfg = PenaltyConfig { warmup_syncs: 1, ..Default::default() };
        let mut a = AnomalyDetector::new(2, 2, cfg);
        let mut b = AnomalyDetector::new(2, 2, cfg);
        let members = [0usize, 1];
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        for i in 0..30 {
            let norms = [1.0 + 0.02 * (i % 5) as f64, 2.0 + 0.01 * (i % 3) as f64];
            for module in 0..2 {
                a.screen_into(module, &norms, &mut out_a);
                b.screen_subset_into(module, &members, &norms, &mut out_b);
                assert_eq!(out_a, out_b, "i={i} module={module}");
            }
            a.advance();
            b.advance();
        }
        assert_eq!(a.anomalies_flagged, b.anomalies_flagged);
    }

    #[test]
    fn resize_preserves_existing() {
        let cfg = PenaltyConfig { warmup_syncs: 0, ..Default::default() };
        let mut det = AnomalyDetector::new(1, 1, cfg);
        for i in 0..20 {
            det.screen(0, &[1.0 + 0.01 * (i % 3) as f64]);
            det.advance();
        }
        det.resize_replicas(3);
        let screened = det.screen(0, &[30.0, 30.0, 30.0]);
        // replica 0 has history -> flagged; new replicas unseeded -> pass.
        assert!(screened[0].is_infinite());
        assert!(screened[1].is_finite() && screened[2].is_finite());
    }

    #[test]
    fn ema_matches_eq1_by_hand() {
        let mut s = EmaStat::new();
        s.update(2.0, 0.5);
        assert_eq!((s.mean, s.var), (2.0, 0.0));
        s.update(4.0, 0.5);
        // mean = .5*4 + .5*2 = 3 ; var = .5*0 + .5*(4-3)^2 = 0.5
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.var - 0.5).abs() < 1e-12);
    }
}
