//! Deterministic discrete-event scheduler for the per-replica execution
//! core.
//!
//! The trainer's asynchronous path (A-EDiT, §3.3) orders replica sync
//! events by *simulated* time, not by arrival order: events live in a
//! binary min-heap keyed on `(clock, replica)` with `f64::total_cmp`
//! for the clock and the replica index as a stable tie-break. The pop
//! sequence is therefore a **total order** that depends only on the
//! event set — never on thread scheduling, insertion order, or host
//! timing — which is what makes the event core bitwise reproducible
//! across runs and across worker-thread counts
//! (`tests/scheduler_determinism.rs`).
//!
//! Coalescing: events whose clocks are **bitwise equal** are popped as
//! one group ([`EventQueue::pop_group`], replicas in ascending index
//! order). On a perfectly homogeneous cluster every replica accumulates
//! the identical f64 step-time sequence, so all sync events coalesce
//! into a single full-group event and the asynchronous path reduces
//! exactly to EDiT's barriered synchronization — the equivalence the
//! determinism suite asserts.
//!
//! Allocation discipline: the heap is a plain `Vec` sized once
//! ([`EventQueue::with_capacity`]) and reused via [`EventQueue::clear`],
//! so steady-state rounds push/pop without touching the allocator
//! (`tests/sync_steady_state.rs` counts on this).

/// One pending per-replica event (a worker becoming sync-eligible).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Simulated time (seconds) at which the event fires.
    pub clock: f64,
    /// Replica index — the stable tie-break for simultaneous events.
    pub replica: usize,
}

impl Event {
    /// Strict "fires earlier" order: clock first (`total_cmp`, so NaN
    /// and signed zero still order deterministically), replica index as
    /// the tie-break.
    #[inline]
    fn before(&self, other: &Event) -> bool {
        match self.clock.total_cmp(&other.clock) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.replica < other.replica,
        }
    }
}

/// Binary min-heap of [`Event`]s over a reusable `Vec` (no allocation
/// after `with_capacity` as long as occupancy stays within capacity).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: Vec<Event>,
}

impl EventQueue {
    pub fn with_capacity(n: usize) -> Self {
        Self { heap: Vec::with_capacity(n) }
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Earliest pending event, if any.
    pub fn peek(&self) -> Option<Event> {
        self.heap.first().copied()
    }

    pub fn push(&mut self, e: Event) {
        self.heap.push(e);
        let mut i = self.heap.len() - 1;
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].before(&self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let out = self.heap.pop();
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut min = i;
            if l < n && self.heap[l].before(&self.heap[min]) {
                min = l;
            }
            if r < n && self.heap[r].before(&self.heap[min]) {
                min = r;
            }
            if min == i {
                break;
            }
            self.heap.swap(i, min);
            i = min;
        }
        out
    }

    /// Pop the earliest event plus every further event whose clock is
    /// **bitwise equal** to it, appending the replica indices (in
    /// ascending order, by the tie-break) to `out`. Returns the group's
    /// shared clock, or `None` when the queue is empty.
    pub fn pop_group(&mut self, out: &mut Vec<usize>) -> Option<f64> {
        let first = self.pop()?;
        out.push(first.replica);
        while let Some(next) = self.peek() {
            if next.clock.total_cmp(&first.clock) == std::cmp::Ordering::Equal {
                self.pop();
                out.push(next.replica);
            } else {
                break;
            }
        }
        Some(first.clock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_clock_order() {
        let mut q = EventQueue::with_capacity(8);
        for (clock, replica) in [(3.0, 0), (1.0, 1), (2.0, 2), (0.5, 3)] {
            q.push(Event { clock, replica });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.replica).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_replica_index() {
        let mut q = EventQueue::with_capacity(8);
        // Inserted in scrambled order; equal clocks must pop 0,1,2.
        for replica in [2usize, 0, 1] {
            q.push(Event { clock: 4.25, replica });
        }
        q.push(Event { clock: 1.0, replica: 5 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.replica).collect();
        assert_eq!(order, vec![5, 0, 1, 2]);
    }

    #[test]
    fn pop_group_coalesces_bitwise_equal_clocks() {
        let mut q = EventQueue::with_capacity(8);
        for replica in [3usize, 1, 2] {
            q.push(Event { clock: 2.5, replica });
        }
        q.push(Event { clock: 2.5000001, replica: 0 });
        let mut group = Vec::new();
        let clock = q.pop_group(&mut group).unwrap();
        assert_eq!(clock, 2.5);
        assert_eq!(group, vec![1, 2, 3]);
        group.clear();
        assert_eq!(q.pop_group(&mut group), Some(2.5000001));
        assert_eq!(group, vec![0]);
        assert_eq!(q.pop_group(&mut group), None);
    }

    #[test]
    fn reuse_after_clear() {
        let mut q = EventQueue::with_capacity(4);
        q.push(Event { clock: 1.0, replica: 0 });
        q.clear();
        assert!(q.is_empty());
        q.push(Event { clock: 2.0, replica: 1 });
        assert_eq!(q.pop().unwrap().replica, 1);
    }

    #[test]
    fn total_order_is_permutation_invariant() {
        // Same event set in two insertion orders -> same pop sequence.
        let events = [
            Event { clock: 0.5, replica: 4 },
            Event { clock: 0.5, replica: 1 },
            Event { clock: 1.5, replica: 0 },
            Event { clock: 0.25, replica: 3 },
            Event { clock: 1.5, replica: 2 },
        ];
        let mut a = EventQueue::with_capacity(8);
        let mut b = EventQueue::with_capacity(8);
        for e in events {
            a.push(e);
        }
        for e in events.iter().rev() {
            b.push(*e);
        }
        loop {
            let (x, y) = (a.pop(), b.pop());
            assert_eq!(x, y);
            if x.is_none() {
                break;
            }
        }
    }
}
