//! Per-replica worker state machine: the inner-loop "lane" of the
//! event-driven execution core.
//!
//! One [`Lane`] per logical replica owns everything a replica's inner
//! loop touches — its token batch buffer, its round counters, its
//! partial loss sums — so the inner loops of independent replicas are
//! **data-disjoint** and can run on parallel OS worker threads
//! (`TrainConfig::worker_threads > 1`) with results bitwise identical
//! to the sequential schedule. Three properties make that true:
//!
//!  1. every stochastic input is a *stateless* function of
//!     `(seed, replica, inner_step)` — data streams
//!     (`Corpus::sequence_into`), straggler lag ([`straggler_lag`]) and
//!     poison noise all derive from pure hashes, never from a shared
//!     mutable RNG;
//!  2. the inner learning-rate is anchored to the round's base step
//!     (`base_step + steps_this_round`), not to cross-replica progress,
//!     so no lane reads another lane's counters;
//!  3. partial loss sums are folded in replica-index order after the
//!     lanes join, reproducing the sequential f64 association exactly.
//!
//! Between two synchronizations no lane reads or writes another
//! replica's state, so per-step interleavings commute; the scheduler's
//! total order (see [`super::clock`]) only needs to order the *sync*
//! events. The round driver (`Trainer::run_lanes`) enforces the rest.
//!
//! Steady-state allocation: a lane's buffers are sized at construction
//! and reused; `run_round` performs zero heap allocations
//! (`tests/sync_steady_state.rs`).
//!
//! Note on backends: lanes call the execution engine through `&Engine`,
//! which requires the backend's step methods to take `&self` (true of
//! the deterministic stub; the feature-gated PJRT backend is
//! single-threaded and incompatible with parallel lanes — see
//! `runtime/mod.rs`).

use anyhow::Result;

use crate::coordinator::spec::SyncTrigger;
use crate::data::{Corpus, Split};
use crate::runtime::Engine;
use crate::util::prng::{mix, Rng};

use super::{Replica, Straggler, TrainConfig};

/// Immutable per-round context shared by every lane (must stay `Sync`).
pub(super) struct RoundCtx<'a> {
    pub engine: &'a Engine,
    pub corpus: &'a Corpus,
    pub cfg: &'a TrainConfig,
    /// Simulated duration of one local inner step (`CommPlan`).
    pub step_time: f64,
    /// `global_step` at round start — the LR-schedule anchor.
    pub base_step: u64,
    /// A-EDiT τ_time deadline (simulated seconds); `None` = fixed-step.
    pub deadline: Option<f64>,
    /// Steps per lane: the exact count in fixed-step mode, the safety
    /// cap (4τ) in deadline mode.
    pub step_cap: u64,
    /// Per-replica fault budget (`Trainer::fault_caps`): `u64::MAX`
    /// when healthy, a crash event's `after_steps` for this round's
    /// victims, 0 for dead replicas. The effective cap for lane `j` is
    /// `step_cap.min(caps[j])` — a zero budget means zero steps, even
    /// in deadline mode (the "at least one step" rule applies only to
    /// live replicas).
    pub caps: &'a [u64],
    /// Completed sync rounds at round start (poison windows key on it).
    pub syncs: u64,
}

/// Per-replica round state (the worker's private scratch).
#[derive(Debug)]
pub(super) struct Lane {
    /// Token batch buffer (replaces the shared scratch buffer so lanes
    /// can fill batches concurrently).
    pub tokens: Vec<i32>,
    /// Partial f64 loss sum over this lane's steps this round.
    pub loss_sum: f64,
    pub loss_count: u64,
    /// Inner steps taken this round.
    pub steps: u64,
    /// Engine step invocations this round (folded into `pjrt_calls`).
    pub calls: u64,
}

impl Lane {
    pub fn with_token_capacity(cap: usize) -> Self {
        Self {
            tokens: Vec::with_capacity(cap),
            loss_sum: 0.0,
            loss_count: 0,
            steps: 0,
            calls: 0,
        }
    }

    /// Reset the round counters (token capacity is retained).
    pub fn begin_round(&mut self) {
        self.loss_sum = 0.0;
        self.loss_count = 0;
        self.steps = 0;
        self.calls = 0;
    }

    /// Run replica `j`'s inner loop for one round: fixed `step_cap`
    /// steps, or — in deadline mode — until the replica's clock passes
    /// the τ_time deadline (at least one step, at most the cap).
    pub fn run_round(&mut self, j: usize, r: &mut Replica, ctx: &RoundCtx) -> Result<()> {
        let cap = ctx.step_cap.min(ctx.caps[j]);
        match ctx.deadline {
            Some(deadline) => {
                while (r.clock < deadline || self.steps == 0) && self.steps < cap {
                    self.inner_step(j, r, ctx)?;
                }
            }
            None => {
                for _ in 0..cap {
                    self.inner_step(j, r, ctx)?;
                }
            }
        }
        Ok(())
    }

    /// Fill the lane's token buffer with the batch for (replica, step).
    /// Batch row b draws from physical worker (row = b mod M, col = j):
    /// the column's M data-parallel workers interleave into the
    /// effective column batch (same layout as the warmup DDP path).
    fn fill_batch(&mut self, j: usize, step: u64, ctx: &RoundCtx) {
        let [b, s1] = ctx.engine.manifest.token_shape;
        let m = ctx.cfg.mesh.shard;
        self.tokens.clear();
        for row in 0..b {
            let worker = ctx.cfg.mesh.rank(row % m, j);
            ctx.corpus
                .sequence_into(Split::Train, worker, step, row / m, s1, &mut self.tokens);
        }
    }

    /// One local inner step on replica `j`: fill batch → fused
    /// fwd+bwd+AdamW → poison injection → clock advance (+ straggler
    /// lag) → loss bookkeeping.
    ///
    /// LR anchoring: `lr(base_step + k)` for the lane's k-th step this
    /// round — every replica walks the same schedule segment. (The
    /// historical sequential loop derived the step from a cross-replica
    /// `min(inner_steps)` snapshot, which pinned the *last* replica of
    /// each round to `lr(base_step)` for all τ steps — an
    /// execution-order artifact, not a design choice. The uniform
    /// anchoring removes that asymmetry and the cross-lane read.)
    fn inner_step(&mut self, j: usize, r: &mut Replica, ctx: &RoundCtx) -> Result<()> {
        let lr_step = (ctx.base_step + self.steps).min(ctx.cfg.total_steps);
        let lr = ctx.cfg.inner_lr.at(lr_step) as f32;
        self.fill_batch(j, r.inner_steps, ctx);
        let lag = straggler_lag(
            &ctx.cfg.straggler,
            ctx.cfg.seed,
            j,
            r.inner_steps,
            ctx.cfg.mesh.replicas,
        );
        r.adam_t += 1;
        let adam_t = r.adam_t;
        let out =
            ctx.engine
                .train_step(&mut r.params, &mut r.m, &mut r.v, &self.tokens, lr, adam_t)?;
        self.calls += 1;
        // Fault injection: corrupt the sick replica's state (see Poison).
        for p in &ctx.cfg.poison {
            let sick = p.replica == usize::MAX || p.replica == j;
            if sick && ctx.syncs >= p.from_sync && ctx.syncs < p.to_sync {
                let mut prng = Rng::new(mix(
                    ctx.cfg.seed ^ 0xBAD,
                    (j as u64) << 32 | r.inner_steps,
                ));
                for x in r.params.iter_mut() {
                    *x += p.strength * prng.normal_f32();
                }
            }
        }
        r.clock += ctx.step_time + lag;
        r.inner_steps += 1;
        r.losses.push((ctx.base_step + self.steps + 1, out.loss));
        self.loss_sum += out.loss as f64;
        self.loss_count += 1;
        self.steps += 1;
        Ok(())
    }
}

/// Stateless straggler lag for (replica, inner_step) — a pure function
/// of the seed so lanes can draw it concurrently in any order without a
/// shared RNG. `Random` keeps the historical per-step-per-replica
/// Bernoulli(1/N) marginal (each sequential draw only ever affected the
/// replica that made it).
pub(super) fn straggler_lag(
    straggler: &Straggler,
    seed: u64,
    replica: usize,
    inner_step: u64,
    replicas: usize,
) -> f64 {
    match *straggler {
        Straggler::None => 0.0,
        Straggler::Random { lag } => {
            let key = (replica as u64) << 40 ^ inner_step;
            let mut rng = Rng::new(mix(seed ^ 0x0057_12A6, key));
            if rng.below(replicas.max(1) as u64) as usize == replica {
                lag
            } else {
                0.0
            }
        }
        Straggler::Consistent { lag, replica: victim } => {
            if victim == replica {
                lag
            } else {
                0.0
            }
        }
    }
}

/// Stateless per-(replica, deadline-window) sync draw for the
/// time-based triggers: `Time` always fires; `Probabilistic { prob }`
/// (PALSGD) fires with probability `prob`. Keyed on the run seed like
/// every other stochastic input, so the draw is reproducible across
/// reruns and worker-thread counts, and `prob = 1` is bitwise A-EDiT
/// (the draw is always true and touches no trainer state).
pub(super) fn sync_draw(trigger: &SyncTrigger, seed: u64, replica: usize, window: u64) -> bool {
    match *trigger {
        SyncTrigger::Probabilistic { prob } => {
            let key = (replica as u64) << 40 ^ window;
            let mut rng = Rng::new(mix(seed ^ 0x50A1_56D0, key));
            rng.f64() < prob
        }
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_lag_hits_only_victim() {
        let s = Straggler::Consistent { lag: 2.0, replica: 1 };
        assert_eq!(straggler_lag(&s, 7, 0, 5, 4), 0.0);
        assert_eq!(straggler_lag(&s, 7, 1, 5, 4), 2.0);
    }

    #[test]
    fn random_lag_is_pure_and_roughly_uniform() {
        let s = Straggler::Random { lag: 1.0 };
        let mut hits = 0usize;
        for step in 0..4000u64 {
            let a = straggler_lag(&s, 42, 2, step, 4);
            let b = straggler_lag(&s, 42, 2, step, 4);
            assert_eq!(a, b, "stateless draws must be reproducible");
            if a > 0.0 {
                hits += 1;
            }
        }
        // Bernoulli(1/4) over 4000 draws.
        assert!((700..1300).contains(&hits), "{hits}");
    }

    #[test]
    fn sync_draw_is_stateless_and_respects_probability() {
        // Time/Step-style triggers always fire.
        assert!(sync_draw(&SyncTrigger::Time, 7, 0, 3));
        // prob=1 always fires (f64() < 1.0 for any draw in [0,1)).
        for w in 0..64u64 {
            assert!(sync_draw(&SyncTrigger::Probabilistic { prob: 1.0 }, 7, 1, w));
        }
        // Reproducible, and roughly Bernoulli(p) over many windows.
        let t = SyncTrigger::Probabilistic { prob: 0.5 };
        let mut hits = 0usize;
        for w in 0..4000u64 {
            let a = sync_draw(&t, 42, 2, w);
            assert_eq!(a, sync_draw(&t, 42, 2, w), "stateless draws must repeat");
            hits += a as usize;
        }
        assert!((1700..2300).contains(&hits), "{hits}");
    }

    #[test]
    fn random_lag_independent_across_replicas_and_steps() {
        let s = Straggler::Random { lag: 1.0 };
        let a = straggler_lag(&s, 42, 0, 17, 8);
        let b = straggler_lag(&s, 42, 1, 17, 8);
        let c = straggler_lag(&s, 42, 0, 18, 8);
        // Not asserting specific values — just that the keys differ and
        // nothing panics; reproducibility is covered above.
        let _ = (a, b, c);
        assert_eq!(straggler_lag(&Straggler::None, 42, 0, 17, 8), 0.0);
    }
}
