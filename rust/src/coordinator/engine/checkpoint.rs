//! Checkpoint/restore of the full run state — the fault-tolerance half
//! of the elastic runtime (see `crate::fault` for the injection half).
//!
//! A checkpoint captures *everything* the training trajectory depends
//! on: the anchor and outer-optimizer momentum, every replica's
//! parameters / Adam moments / clocks / loss traces, the CO2 staleness
//! queue, the anomaly detector's EMA statistics, the run tracker, the
//! fault-plan cursor and liveness, and every counter that keys a
//! stateless draw (every stochastic input in this codebase is a pure
//! function of `(seed, replica, inner_step)` — so checkpointing the
//! counters *is* checkpointing the RNG cursors). Killing a run at any
//! round boundary and restoring therefore replays **bitwise
//! identically** to the uninterrupted run (`tests/fault_recovery.rs`).
//!
//! On-disk format (version [`RUN_STATE_VERSION`]):
//!
//! ```text
//! b"EDITCKPT" | version: u32 LE | header_len: u64 LE
//! header: JSON (RunManifest — identity + section table)
//! body: concatenated little-endian sections, in table order
//! ```
//!
//! The header's section table makes the body self-describing; integers
//! live in typed binary sections (not JSON) because the hand-rolled
//! `util::json` number is an f64 and would corrupt counters past 2^53.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::manifest::{
    RunManifest, RunSection, SectionKind, RUN_STATE_MAGIC, RUN_STATE_VERSION,
};

use super::Trainer;

/// Fixed order of the `counters` section. Extend at the END and bump
/// [`RUN_STATE_VERSION`] if the meaning of existing slots changes.
const COUNTERS: usize = 19;

struct SectionWriter {
    buf: Vec<u8>,
    sections: Vec<RunSection>,
}

impl SectionWriter {
    fn new() -> Self {
        Self { buf: Vec::new(), sections: Vec::new() }
    }

    fn write(&mut self, name: &str, kind: SectionKind, fill: impl FnOnce(&mut Vec<u8>)) {
        let start = self.buf.len();
        fill(&mut self.buf);
        let bytes = self.buf.len() - start;
        debug_assert_eq!(bytes % kind.elem_bytes(), 0, "section {name} misaligned");
        self.sections.push(RunSection {
            name: name.to_string(),
            kind,
            count: bytes / kind.elem_bytes(),
        });
    }

    fn f32s<'a>(&mut self, name: &str, parts: impl IntoIterator<Item = &'a [f32]>) {
        self.write(name, SectionKind::F32, |buf| {
            for part in parts {
                for &x in part {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        });
    }

    fn f64s(&mut self, name: &str, data: impl IntoIterator<Item = f64>) {
        self.write(name, SectionKind::F64, |buf| {
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        });
    }

    fn u64s(&mut self, name: &str, data: impl IntoIterator<Item = u64>) {
        self.write(name, SectionKind::U64, |buf| {
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        });
    }

    fn i64s(&mut self, name: &str, data: impl IntoIterator<Item = i64>) {
        self.write(name, SectionKind::I64, |buf| {
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        });
    }

    fn u8s(&mut self, name: &str, data: impl IntoIterator<Item = u8>) {
        self.write(name, SectionKind::U8, |buf| buf.extend(data));
    }
}

/// Sequential reader over the body, validating each section against the
/// manifest's table (order, name, kind) as it goes — a truncated or
/// reordered file fails loudly instead of silently misreading.
struct SectionReader<'a> {
    body: &'a [u8],
    pos: usize,
    sections: &'a [RunSection],
    idx: usize,
}

impl<'a> SectionReader<'a> {
    fn new(body: &'a [u8], sections: &'a [RunSection]) -> Self {
        Self { body, pos: 0, sections, idx: 0 }
    }

    fn expect(&mut self, name: &str, kind: SectionKind) -> Result<(usize, &'a [u8])> {
        let s = self
            .sections
            .get(self.idx)
            .with_context(|| format!("checkpoint body ends before section '{name}'"))?;
        anyhow::ensure!(
            s.name == name && s.kind == kind,
            "checkpoint section {} is '{}' ({}), expected '{name}' ({})",
            self.idx,
            s.name,
            s.kind.name(),
            kind.name()
        );
        let bytes = s.count * kind.elem_bytes();
        anyhow::ensure!(
            self.pos + bytes <= self.body.len(),
            "checkpoint body truncated inside section '{name}'"
        );
        let slice = &self.body[self.pos..self.pos + bytes];
        self.pos += bytes;
        self.idx += 1;
        Ok((s.count, slice))
    }

    fn f32s_into(&mut self, name: &str, out: &mut [f32]) -> Result<()> {
        let (count, bytes) = self.expect(name, SectionKind::F32)?;
        anyhow::ensure!(
            count == out.len(),
            "section '{name}' has {count} elements, expected {}",
            out.len()
        );
        for (dst, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
        Ok(())
    }

    fn f32s(&mut self, name: &str) -> Result<Vec<f32>> {
        let (_, bytes) = self.expect(name, SectionKind::F32)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self, name: &str) -> Result<Vec<f64>> {
        let (_, bytes) = self.expect(name, SectionKind::F64)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64s(&mut self, name: &str) -> Result<Vec<u64>> {
        let (_, bytes) = self.expect(name, SectionKind::U64)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i64s(&mut self, name: &str) -> Result<Vec<i64>> {
        let (_, bytes) = self.expect(name, SectionKind::I64)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u8s(&mut self, name: &str) -> Result<Vec<u8>> {
        let (_, bytes) = self.expect(name, SectionKind::U8)?;
        Ok(bytes.to_vec())
    }
}

impl Trainer {
    /// Serialize the complete run state to `path` (parent directories
    /// are created). Call at a round boundary — mid-round state (lane
    /// scratch, undrained sync events) is transient by design and a
    /// checkpoint taken there would not be a consistent cut.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        anyhow::ensure!(
            self.events.is_empty(),
            "checkpoint with undrained sync events (mid-round checkpoint?)"
        );
        let n = self.anchor.len();
        let mut w = SectionWriter::new();

        w.f32s("anchor", [self.anchor.as_slice()]);
        w.f32s("outer_momentum", [self.outer.momentum.as_slice()]);
        // Error-feedback residuals of the quantized payload axis:
        // replica-major flat [replicas × params] in the canonical order
        // (identical bytes whether the arena runs sharded or not), empty
        // for payload=f32. A kill/restore with residuals in flight must
        // replay bitwise — the residual is training state, not cache.
        let mut residuals = Vec::new();
        self.scratch.export_residuals_into(&mut residuals);
        w.f32s("sync_residuals", [residuals.as_slice()]);
        w.f32s("params", self.replicas.iter().map(|r| r.params.as_slice()));
        w.f32s("m", self.replicas.iter().map(|r| r.m.as_slice()));
        w.f32s("v", self.replicas.iter().map(|r| r.v.as_slice()));
        w.i64s("adam_t", self.replicas.iter().map(|r| r.adam_t as i64));
        w.f64s("clock", self.replicas.iter().map(|r| r.clock));
        w.u64s("inner_steps", self.replicas.iter().map(|r| r.inner_steps));
        w.u64s("loss_lens", self.replicas.iter().map(|r| r.losses.len() as u64));
        w.u64s(
            "loss_steps",
            self.replicas.iter().flat_map(|r| r.losses.iter().map(|&(s, _)| s)),
        );
        w.write("loss_vals", SectionKind::F32, |buf| {
            for r in &self.replicas {
                for &(_, loss) in &r.losses {
                    buf.extend_from_slice(&loss.to_le_bytes());
                }
            }
        });
        w.f32s("pending", self.pending.iter().map(|u| u.as_slice()));
        w.u64s("last_sync_version", self.last_sync_version.iter().copied());
        w.u8s("alive", self.alive.iter().map(|&a| a as u8));
        let (det_mean, det_var, det_init) = self.detector.export_state();
        w.f64s("det_mean", det_mean);
        w.f64s("det_var", det_var);
        w.u8s("det_init", det_init);
        w.u64s("tracker_steps", self.tracker.losses.iter().map(|&(s, _)| s));
        w.f64s("tracker_losses", self.tracker.losses.iter().map(|&(_, l)| l));
        w.u64s("val_steps", self.tracker.val_ppl.iter().map(|&(s, _)| s));
        w.f64s("val_ppl", self.tracker.val_ppl.iter().map(|&(_, p)| p));
        w.f64s("scalars", [self.sim_time, self.comm.seconds]);
        let counters: [u64; COUNTERS] = [
            self.global_step,
            self.syncs,
            self.sync_windows,
            self.anchor_version,
            self.max_staleness,
            self.flushed_updates,
            self.pjrt_calls,
            self.rounds,
            self.fault_cursor as u64,
            self.crashes,
            self.rejoins,
            self.evictions,
            self.degraded_syncs,
            self.evict_charge as u64,
            self.detector.syncs_seen(),
            self.detector.anomalies_flagged,
            self.detector.rollbacks,
            self.comm.ops as u64,
            self.comm.bytes as u64,
        ];
        w.u64s("counters", counters);

        let manifest = RunManifest {
            version: RUN_STATE_VERSION,
            label: self.cfg.label.clone(),
            seed: self.cfg.seed,
            replicas: self.replicas.len(),
            params: n,
            modules: self.table.num_modules(),
            sections: w.sections,
        };
        let header = manifest.to_json().to_string();
        let mut out =
            Vec::with_capacity(RUN_STATE_MAGIC.len() + 12 + header.len() + w.buf.len());
        out.extend_from_slice(RUN_STATE_MAGIC);
        out.extend_from_slice(&RUN_STATE_VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&w.buf);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        }
        std::fs::write(path, out)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Restore the run state written by [`Self::save_checkpoint`] into
    /// this trainer. The trainer must have been built with the same
    /// engine manifest, seed and strategy — identity fields are
    /// validated; the replica count is reconciled via [`Self::rescale`]
    /// before the per-replica state lands. Continuing the run afterwards
    /// is bitwise identical to never having stopped.
    pub fn restore_checkpoint(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() >= RUN_STATE_MAGIC.len() + 12 && bytes.starts_with(RUN_STATE_MAGIC),
            "{} is not a run-state checkpoint (bad magic)",
            path.display()
        );
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == RUN_STATE_VERSION,
            "checkpoint version {version} != supported {RUN_STATE_VERSION}"
        );
        let header_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        anyhow::ensure!(20 + header_len <= bytes.len(), "checkpoint header truncated");
        let header = std::str::from_utf8(&bytes[20..20 + header_len])
            .context("checkpoint header is not UTF-8")?;
        let json = crate::util::json::Json::parse(header)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e:?}"))?;
        let manifest = RunManifest::from_json(&json)?;
        let body = &bytes[20 + header_len..];
        anyhow::ensure!(
            body.len() == manifest.body_bytes(),
            "checkpoint body is {} bytes, section table says {}",
            body.len(),
            manifest.body_bytes()
        );

        let n = self.anchor.len();
        anyhow::ensure!(
            manifest.params == n,
            "checkpoint has {} params, model has {n}",
            manifest.params
        );
        anyhow::ensure!(
            manifest.modules == self.table.num_modules(),
            "checkpoint has {} modules, model has {}",
            manifest.modules,
            self.table.num_modules()
        );
        anyhow::ensure!(
            manifest.seed == self.cfg.seed,
            "checkpoint seed {} != configured seed {} (every stochastic draw keys on it)",
            manifest.seed,
            self.cfg.seed
        );
        if manifest.replicas != self.replicas.len() {
            self.rescale(manifest.replicas)?;
        }
        let replicas = manifest.replicas;

        let mut r = SectionReader::new(body, &manifest.sections);
        r.f32s_into("anchor", &mut self.anchor)?;
        r.f32s_into("outer_momentum", &mut self.outer.momentum)?;
        let residuals = r.f32s("sync_residuals")?;
        if self.scratch.residuals_enabled() {
            anyhow::ensure!(
                residuals.len() == replicas * n,
                "checkpoint sync_residuals has {} elements; this quantized-payload \
                 run needs {} (was the checkpoint written with payload=f32?)",
                residuals.len(),
                replicas * n
            );
            self.scratch.import_residuals(&residuals);
        } else {
            anyhow::ensure!(
                residuals.is_empty(),
                "checkpoint carries {} sync_residuals elements but this run has \
                 payload=f32 (strategy mismatch)",
                residuals.len()
            );
        }
        let params = r.f32s("params")?;
        let m = r.f32s("m")?;
        let v = r.f32s("v")?;
        anyhow::ensure!(
            params.len() == replicas * n && m.len() == params.len() && v.len() == params.len(),
            "checkpoint replica state has the wrong shape"
        );
        let adam_t = r.i64s("adam_t")?;
        let clocks = r.f64s("clock")?;
        let inner_steps = r.u64s("inner_steps")?;
        let loss_lens = r.u64s("loss_lens")?;
        anyhow::ensure!(
            adam_t.len() == replicas
                && clocks.len() == replicas
                && inner_steps.len() == replicas
                && loss_lens.len() == replicas,
            "checkpoint per-replica sections disagree with the replica count"
        );
        let loss_steps = r.u64s("loss_steps")?;
        let loss_vals = r.f32s("loss_vals")?;
        let total_losses: u64 = loss_lens.iter().sum();
        anyhow::ensure!(
            loss_steps.len() as u64 == total_losses && loss_vals.len() as u64 == total_losses,
            "checkpoint loss traces disagree with loss_lens"
        );
        for (j, rep) in self.replicas.iter_mut().enumerate() {
            rep.params.copy_from_slice(&params[j * n..(j + 1) * n]);
            rep.m.copy_from_slice(&m[j * n..(j + 1) * n]);
            rep.v.copy_from_slice(&v[j * n..(j + 1) * n]);
            rep.adam_t = adam_t[j] as i32;
            rep.clock = clocks[j];
            rep.inner_steps = inner_steps[j];
        }
        let mut cursor = 0usize;
        for (j, &len) in loss_lens.iter().enumerate() {
            let len = len as usize;
            let rep = &mut self.replicas[j];
            rep.losses.clear();
            rep.losses.reserve(len.max(self.loss_capacity));
            for i in cursor..cursor + len {
                rep.losses.push((loss_steps[i], loss_vals[i]));
            }
            cursor += len;
        }

        let pending_flat = r.f32s("pending")?;
        anyhow::ensure!(
            pending_flat.len() % n == 0,
            "checkpoint CO2 queue is not a multiple of the param count"
        );
        self.pending.clear();
        for chunk in pending_flat.chunks_exact(n) {
            self.pending.push_back(chunk.to_vec());
        }

        let last_sync = r.u64s("last_sync_version")?;
        anyhow::ensure!(last_sync.len() == replicas, "bad last_sync_version length");
        self.last_sync_version.copy_from_slice(&last_sync);
        let alive = r.u8s("alive")?;
        anyhow::ensure!(alive.len() == replicas, "bad alive length");
        for (dst, &a) in self.alive.iter_mut().zip(alive.iter()) {
            *dst = a != 0;
        }

        let det_mean = r.f64s("det_mean")?;
        let det_var = r.f64s("det_var")?;
        let det_init = r.u8s("det_init")?;
        self.detector.import_state(&det_mean, &det_var, &det_init)?;

        let tracker_steps = r.u64s("tracker_steps")?;
        let tracker_losses = r.f64s("tracker_losses")?;
        let val_steps = r.u64s("val_steps")?;
        let val_ppl = r.f64s("val_ppl")?;
        anyhow::ensure!(
            tracker_steps.len() == tracker_losses.len() && val_steps.len() == val_ppl.len(),
            "checkpoint tracker traces are misaligned"
        );
        self.tracker = crate::metrics::RunTracker::new();
        self.tracker.reserve(tracker_steps.len());
        for (&s, &l) in tracker_steps.iter().zip(tracker_losses.iter()) {
            // record_loss replays the tail window exactly.
            self.tracker.record_loss(s, l);
        }
        for (&s, &p) in val_steps.iter().zip(val_ppl.iter()) {
            // The val trace stores PPL (already exp'd) — pushing through
            // record_val would exponentiate twice, so land it directly.
            self.tracker.val_ppl.push((s, p));
            self.tracker.tail_ppl.push(p);
        }

        let scalars = r.f64s("scalars")?;
        anyhow::ensure!(scalars.len() == 2, "bad scalars length");
        self.sim_time = scalars[0];
        let counters = r.u64s("counters")?;
        anyhow::ensure!(
            counters.len() == COUNTERS,
            "checkpoint has {} counters, expected {COUNTERS}",
            counters.len()
        );
        self.global_step = counters[0];
        self.syncs = counters[1];
        self.sync_windows = counters[2];
        self.anchor_version = counters[3];
        self.max_staleness = counters[4];
        self.flushed_updates = counters[5];
        self.pjrt_calls = counters[6];
        self.rounds = counters[7];
        self.fault_cursor = counters[8] as usize;
        self.crashes = counters[9];
        self.rejoins = counters[10];
        self.evictions = counters[11];
        self.degraded_syncs = counters[12];
        self.evict_charge = counters[13] != 0;
        self.detector.restore_syncs_seen(counters[14]);
        self.detector.anomalies_flagged = counters[15];
        self.detector.rollbacks = counters[16];
        self.comm.ops = counters[17] as usize;
        self.comm.bytes = counters[18] as usize;
        self.comm.seconds = scalars[1];

        // Derived state: the fault caps follow liveness; transient
        // per-round scratch starts clean.
        for (cap, &a) in self.fault_caps.iter_mut().zip(self.alive.iter()) {
            *cap = if a { u64::MAX } else { 0 };
        }
        self.pending_crash.clear();
        self.events.clear();
        self.group_buf.clear();
        self.member_buf.clear();
        Ok(())
    }
}
