//! The two synchronization paths of the event-driven execution core,
//! plus the precomputed [`CommPlan`] that prices them.
//!
//! **Barrier sync** ([`barrier_sync`]) — the step-synced methods
//! (Baseline warmup, PLS, DiLoCo, CO2/CO2*, EDiT): every replica
//! participates, the pseudo-gradient combine runs per module (penalty
//! methods) or over the full vector (uniform averaging), and all clocks
//! rendezvous at `max(clocks) + sync_exposed`.
//!
//! **Anchor sync** ([`anchor_sync`]) — the A-EDiT path: a *group* of
//! replicas whose τ_time deadlines fired at the same simulated instant
//! synchronizes against the shared anchor **without waiting for the
//! other replicas**. Group membership comes from the event scheduler
//! ([`super::clock`]): bitwise-equal clocks coalesce, so a homogeneous
//! cluster forms one full group per round (A-EDiT ≡ EDiT there), while
//! a straggler's sync fires later as its own group and never stretches
//! its peers' clocks — the no-global-barrier property the paper's
//! Fig. 5 heterogeneity results rely on. Per-replica staleness (anchor
//! versions missed between consecutive syncs) is tracked on every path.
//!
//! Both paths share one numerics core, [`layerwise_sync`], with two
//! bitwise-identical implementations selected by
//! `TrainConfig::shard_outer`:
//!
//!  * the **full-matrix reference** ([`layerwise_sync_reference`]): per
//!    module — load pseudo-gradients (compact subset rows in the
//!    scratch arena) → anomaly screen → softmax weights → fused combine
//!    + clip-β → outer-optimizer apply → per-module anchor adoption;
//!  * the **sharded path** ([`layerwise_sync_sharded`], default for
//!    N > 1): ZeRO-1-style — each rank owns a contiguous range-aligned
//!    shard of the flat space (`tensor::TableShards`), pseudo-gradients
//!    are reduce-scattered into the owned shard lanes, penalty norms
//!    are folded from shard-local partials in flat range order, the
//!    weighted combine and the outer update run shard-locally (fanned
//!    out across `worker_threads`), and the updated anchor shards are
//!    all-gathered back into the members. Per-rank sync memory drops to
//!    ≈ 1/N of the full-matrix arena; results stay bitwise equal to the
//!    reference (tests/scheduler_determinism.rs, tests/sharded_sync.rs).
//!
//! Determinism invariants: group processing follows the scheduler's
//! total event order; within a group, members are visited in ascending
//! replica index; all comm charges come from the precomputed plan. No
//! step in either path allocates in steady state.
//!
//! Overlap accounting: the plan prices EDiT/A-EDiT's exposed sync cost
//! with the layer-wise pipeline model
//! ([`StepModel::layerwise_exposed`]): module k's all-reduce hides
//! behind the forward compute of the modules pipelined after it, so the
//! exposed residual is the pipeline stall (first module fully exposed),
//! not the full serial communication time.
//!
//! Overlap execution (`TrainConfig::overlap_sync`, default on): both
//! implementations additionally *run* the priced schedule — module m's
//! completion half (combine → β → apply → adopt) executes one module
//! behind the issue half (load → screen → weights), double-buffered
//! through [`ModuleLane`]s on the full-matrix path and via the
//! per-module shard combine on the sharded path. The reorder only
//! commutes data-disjoint work, so results are bitwise identical to the
//! strictly sequential sweep; the real nonblocking collectives behind
//! the same schedule live in `collectives::driver` (`start_*` /
//! `CommHandle`), where the measured `exposed_sync_fraction` bench row
//! cross-validates this plan's analytic `sync_exposed`.

use anyhow::Result;

use crate::collectives::CollOp;
use crate::coordinator::scratch::ModuleLane;
use crate::coordinator::spec::MethodSpec;
use crate::metrics::TimelineEvent;
use crate::simulator::stepmodel::StepModel;
use crate::tensor::ModuleTable;

use super::Trainer;

/// Precomputed per-round communication charges and step timings.
///
/// `MeshSpec::sync_group`/`shard_group` allocate rank vectors and the
/// α-β formulas are pure functions of (mesh, cost, module table), so the
/// trainer resolves them once at construction (and again after an
/// elastic rescale) instead of per step / per module / per sync event.
#[derive(Debug, Clone, Default)]
pub(super) struct CommPlan {
    /// (bytes, seconds) of one full-shard all-reduce per mesh row (sync
    /// group) — the warmup/DDP gradient exchange. Always f32: gradients
    /// are exchanged uncompressed (the payload axis applies to
    /// pseudo-gradients only).
    pub sync_allreduce: Vec<(usize, f64)>,
    /// (bytes, seconds) of one full-shard pseudo-gradient exchange per
    /// mesh row — the flat (uniform-averaging / DiLoCo / CO2) sync.
    /// Priced at the payload wire width ([`PayloadKind::wire_bytes`]);
    /// identical to `sync_allreduce` for `payload=f32`.
    pub flat_sync: Vec<(usize, f64)>,
    /// (bytes, seconds) of one scalar-norm exchange per mesh column
    /// (shard group) — charged per participating member per module.
    pub scalar_sync: Vec<(usize, f64)>,
    /// (bytes, seconds) of one per-module shard exchange (layer-wise
    /// barrier sync; indexed by module, charged once per mesh row). An
    /// all-reduce on the unsharded path; reduce-scatter + all-gather
    /// with `shard_outer` — the ring α-β model prices both identically
    /// bitwise (see `collectives::cost`), and the bytes record the
    /// synchronized module-shard payload either way, so plans stay
    /// comparable across the two paths.
    pub module_sync: Vec<(usize, f64)>,
    /// (bytes, seconds) of one per-module anchor push/pull (A-EDiT
    /// anchor sync; indexed by module, charged per member per mesh row).
    pub anchor_exchange: Vec<(usize, f64)>,
    /// Simulated duration of one local / one DDP inner step.
    pub step_time_local: f64,
    pub step_time_ddp: f64,
    /// Exposed sync cost at an outer boundary for the configured
    /// strategy (layer-wise pipeline residual for EDiT/A-EDiT/PALSGD).
    pub sync_exposed: f64,
}

impl CommPlan {
    pub(super) fn build(
        step_model: &StepModel,
        spec: &MethodSpec,
        table: &ModuleTable,
        shard_outer: bool,
    ) -> Self {
        let mesh = step_model.mesh;
        let param_count = table.total;
        let shard_bytes = param_count * 4 / mesh.shard;
        // Pseudo-gradient exchanges travel at the payload wire width;
        // for f32 this is exactly `shard_bytes` (bitwise-identical
        // plan), for int8/bit1 it shrinks bytes-on-wire ~3.8x/~21x.
        let flat_wire = spec.payload.wire_bytes(param_count) / mesh.shard;
        let mut plan = CommPlan {
            step_time_local: step_model.inner_step(false),
            step_time_ddp: step_model.inner_step(true),
            sync_exposed: step_model.sync_exposed(spec),
            ..Default::default()
        };
        for row in 0..mesh.shard {
            let group = mesh.sync_group(row);
            plan.sync_allreduce.push((
                shard_bytes,
                step_model.cost.time(CollOp::AllReduce, shard_bytes, &group),
            ));
            plan.flat_sync.push((
                flat_wire,
                step_model.cost.time(CollOp::AllReduce, flat_wire, &group),
            ));
        }
        for col in 0..mesh.replicas {
            let group = mesh.shard_group(col);
            plan.scalar_sync
                .push((4, step_model.cost.time(CollOp::ScalarSync, 4, &group)));
        }
        if spec.layerwise() {
            let group = mesh.sync_group(0);
            let mut module_bytes = Vec::with_capacity(table.num_modules());
            for m in 0..table.num_modules() {
                // Pseudo-gradient shards travel at the payload wire
                // width (== elems*4 for f32, so the plan is bitwise
                // unchanged there). Anchors are *parameters*, not
                // pseudo-gradients: the push/pull below stays f32.
                let full = spec.payload.wire_bytes(table.module_len(m));
                module_bytes.push(full);
                let mb = (full / mesh.shard).max(1);
                let mb_anchor = (table.module_len(m) * 4 / mesh.shard).max(1);
                let secs = if shard_outer {
                    // Sharded outer state: reduce-scatter of the
                    // pseudo-gradients into the owned shards, all-gather
                    // of the updated anchor shards — the ring model
                    // prices the pair bitwise equal to one all-reduce.
                    step_model.cost.time(CollOp::ReduceScatter, mb, &group)
                        + step_model.cost.time(CollOp::AllGather, mb, &group)
                } else {
                    step_model.cost.time(CollOp::AllReduce, mb, &group)
                };
                plan.module_sync.push((mb, secs));
                // Anchor push + pull of the module shard over the slow
                // links (no peer involvement).
                plan.anchor_exchange.push((
                    2 * mb_anchor,
                    2.0 * step_model.cost.time(CollOp::Broadcast, mb_anchor, &group),
                ));
            }
            // Layer-wise overlap: exposed = pipeline stall, not the full
            // serial comm (single source of truth in the step model).
            plan.sync_exposed = step_model.layerwise_exposed_ops(&module_bytes, shard_outer);
        }
        plan
    }
}

/// Barrier synchronization at a step-synced outer boundary (Alg. 1
/// lines 7-9 / Alg. 2): every **live** replica participates; member
/// clocks rendezvous. Without a fault plan every replica is alive and
/// this is the historical full-cluster barrier, bitwise. With a crashed
/// member the rendezvous degrades instead of aborting: the survivors
/// wait out `TrainConfig::evict_timeout` once (the round the crash is
/// detected), evict the victim from membership and sync without it —
/// its pending contribution is dropped, its clock stays frozen.
pub(super) fn barrier_sync(t: &mut Trainer) -> Result<()> {
    let n = t.replicas.len();
    t.scratch.ensure_replicas(n);

    let mut members = std::mem::take(&mut t.member_buf);
    members.clear();
    members.extend((0..n).filter(|&j| t.alive[j]));
    let degraded = members.len() < n;

    let mut rollbacks = 0u64;
    if t.cfg.spec.layerwise() {
        // Layer-wise sync: one shard exchange (all-reduce, or
        // reduce-scatter + all-gather under `shard_outer`) per module
        // per mesh row.
        let rows = t.cfg.mesh.shard;
        for &(bytes, secs) in &t.plan.module_sync {
            for _row in 0..rows {
                t.comm.record(bytes, secs);
            }
        }
        let res = layerwise_sync(t, &members);
        rollbacks = match res {
            Ok(r) => r,
            Err(e) => {
                t.member_buf = members;
                return Err(e);
            }
        };
    } else {
        // Flat strategies cannot carry a fault plan (`Trainer::new`
        // rejects the combination), so membership is always full here.
        debug_assert_eq!(members.len(), n);
        // Full-shard pseudo-gradient all-reduce per mesh row
        // (uniform-averaging methods), at the payload wire width.
        for &(bytes, secs) in &t.plan.flat_sync {
            t.comm.record(bytes, secs);
        }
        {
            let replicas = &t.replicas;
            t.scratch
                .load_full(|j| replicas[j].params.as_slice(), &t.anchor);
        }
        let staleness = t.cfg.spec.outer_staleness;
        if staleness == 0 {
            let mean = t.scratch.mean_deltas();
            t.outer.apply(&mut t.anchor, mean);
        } else {
            // CO2: apply the update combined `staleness` rounds ago.
            // Queue buffers are recycled through the scratch free list;
            // updates still in flight when `run()` ends are landed by
            // [`flush_pending`] so no combined work is silently dropped.
            let mut buf = t.scratch.take_spare();
            t.scratch.mean_deltas_into(&mut buf);
            t.pending.push_back(buf);
            if t.pending.len() > staleness {
                let stale = t.pending.pop_front().unwrap();
                t.outer.apply(&mut t.anchor, &stale);
                t.scratch.put_spare(stale);
            }
        }
        // All replicas adopt the synchronized parameters (full-vector
        // copy; the layer-wise path folds adoption into its sweep).
        for r in &mut t.replicas {
            r.params.copy_from_slice(&t.anchor);
        }
    }

    // Clock barrier + exposed sync cost over the members; a dead
    // replica's clock stays frozen where it crashed. The round a crash
    // is detected, the survivors additionally pay the evict timeout —
    // the rendezvous grace period before the victim is declared dead.
    let max_clock = members
        .iter()
        .map(|&j| t.replicas[j].clock)
        .fold(0.0f64, f64::max);
    let timeout = if t.evict_charge { t.cfg.evict_timeout } else { 0.0 };
    t.evict_charge = false;
    let after = max_clock + timeout + t.plan.sync_exposed;
    for &j in members.iter() {
        t.replicas[j].clock = after;
    }
    // Monotonic frontier: `after` can only trail `sim_time` when a
    // previously-faster replica crashed and froze ahead of the pack.
    if after > t.sim_time {
        t.sim_time = after;
    }
    if degraded {
        t.degraded_syncs += 1;
    }

    note_sync_members(t, &members, after);
    t.member_buf = members;
    if t.cfg.spec.layerwise() {
        t.detector.advance();
    }
    if rollbacks > 0 {
        t.detector.rollbacks += rollbacks;
    }
    post_sync(t)
}

/// Anchor synchronization for one event group (A-EDiT): the members
/// combine against the shared anchor and adopt it; non-members are
/// untouched — no global barrier, no shared post-sync clock.
pub(super) fn anchor_sync(t: &mut Trainer, members: &[usize]) -> Result<()> {
    debug_assert!(!members.is_empty());
    t.scratch.ensure_replicas(t.replicas.len());

    // Per-member anchor push/pull of every module shard.
    let charges = members.len() * t.cfg.mesh.shard;
    for &(bytes, secs) in &t.plan.anchor_exchange {
        for _ in 0..charges {
            t.comm.record(bytes, secs);
        }
    }

    let rollbacks = layerwise_sync(t, members)?;

    // Members advance to the group's completion time plus the exposed
    // residual; everyone else keeps their own clock.
    let max_clock = members
        .iter()
        .map(|&j| t.replicas[j].clock)
        .fold(0.0f64, f64::max);
    let after = max_clock + t.plan.sync_exposed;
    for &j in members {
        t.replicas[j].clock = after;
    }
    if after > t.sim_time {
        t.sim_time = after;
    }
    // Degradation bookkeeping: a PALSGD partial window is by design,
    // but syncing while a peer is dead is degraded membership.
    if t.alive.iter().any(|&a| !a) {
        t.degraded_syncs += 1;
    }

    note_sync_members(t, members, after);
    // Note: the anomaly detector's per-round counter (`advance`) is NOT
    // bumped here — a heterogeneous round produces several event groups
    // and the z-test warmup must count *rounds*, not groups; the round
    // driver advances it once after the event queue drains. The `syncs`
    // counter (below, via `post_sync`) intentionally does count groups:
    // each group is a real synchronization operation, so eval/log
    // cadences and the summary reflect actual sync activity.
    if rollbacks > 0 {
        t.detector.rollbacks += rollbacks;
    }
    post_sync(t)
}

/// Shared numerics core: layer-wise screen → combine → outer apply →
/// adopt, over the `members` subset. Dispatches to the sharded (ZeRO-1)
/// implementation when the scratch arena runs in sharded mode; both
/// implementations produce bitwise-identical trainer state. Returns the
/// number of rolled-back modules.
fn layerwise_sync(t: &mut Trainer, members: &[usize]) -> Result<u64> {
    if t.scratch.sharded() {
        layerwise_sync_sharded(t, members)
    } else {
        layerwise_sync_reference(t, members)
    }
}

/// Sharded outer sync (`TrainConfig::shard_outer`): the five-phase
/// ZeRO-1 pipeline over the scratch arena's shard lanes (see
/// `coordinator::scratch` for the phase walkthrough). The scalar
/// control plane (phases 2/4) runs in module order with the exact f64
/// folds of the reference sweep; the data-parallel phases (1/3) fan out
/// across `worker_threads` over the data-disjoint lanes.
fn layerwise_sync_sharded(t: &mut Trainer, members: &[usize]) -> Result<u64> {
    t.detector.set_config(t.cfg.spec.penalty);
    let threads = t.cfg.worker_threads;
    let num_modules = t.table.num_modules();
    // Overlapped schedule: module m's shard combine + β issue while the
    // scalar control plane is already screening module m+1 — the
    // trainer-side twin of the driver's issue/wait pipeline. The
    // per-part combine kernels and the β folds are unchanged and the
    // phases touch disjoint state, so results stay bitwise identical to
    // the strict phase order (tests/scheduler_determinism.rs).
    let overlap = t.cfg.overlap_sync && num_modules > 1;
    // Phase 1: reduce-scatter the members' pseudo-gradients into the
    // owned shard lanes (per-range norm partials recorded).
    {
        let replicas = &t.replicas;
        t.scratch
            .shard_load(members, |j| replicas[j].params.as_slice(), &t.anchor, threads);
    }
    // Phase 2 (scalar control plane, module order): range-order norm
    // fold → anomaly screen → scalar-norm exchange → softmax weights.
    let mut rollbacks = 0u64;
    for module in 0..num_modules {
        t.scratch.shard_fold_norms(module);
        if t.debug_norms {
            eprintln!(
                "sync {} module {module} members {members:?}: norms {:?}",
                t.syncs,
                t.scratch.norms()
            );
        }
        {
            let (norms, screened) = t.scratch.screen_buffers();
            t.detector
                .screen_subset_into(module, members, norms, screened);
        }
        for &j in members {
            let (bytes, secs) = t.plan.scalar_sync[j];
            t.comm.record(bytes, secs);
        }
        let ok = t.scratch.compute_weights(t.cfg.spec.penalty.weighted_averaging);
        t.scratch.shard_commit_weights(module, ok);
        if !ok {
            rollbacks += 1;
        }
        if overlap && module >= 1 {
            shard_combine_and_beta(t, module - 1);
        }
    }
    if overlap {
        // Drain the pipeline tail.
        shard_combine_and_beta(t, num_modules - 1);
    } else {
        // Phase 3: shard-local weighted combine.
        t.scratch.shard_combine(threads);
        // Phase 4: clip-β per module from the range-order partial fold.
        for module in 0..num_modules {
            shard_combine_beta_only(t, module);
        }
    }
    // Phase 5: shard-local outer apply over disjoint anchor/momentum
    // slices, then the all-gather adoption — each member adopts the
    // union of the updated anchor shards (rolled-back modules keep the
    // old anchor, which the copy re-imposes exactly like the reference
    // sweep's per-module adoption).
    t.scratch.shard_apply(&mut t.outer, &mut t.anchor, threads);
    let Trainer { replicas, anchor, .. } = t;
    for &j in members {
        replicas[j].params.copy_from_slice(anchor);
    }
    Ok(rollbacks)
}

/// Module `m`'s clip-β from the range-order combined-norm fold
/// (phase 4 of the sharded pipeline). Rolled-back modules keep their
/// previous β — the apply skips them anyway.
fn shard_combine_beta_only(t: &mut Trainer, m: usize) {
    if t.scratch.shard_rollback(m) {
        return;
    }
    let module_sq = t.scratch.shard_module_sq(m);
    let mut beta = 1.0f64;
    if t.cfg.spec.penalty.gradient_clip {
        let norm = module_sq.sqrt();
        beta = (t.cfg.spec.penalty.phi / (norm + t.cfg.spec.penalty.eps)).min(1.0);
    }
    t.scratch.shard_set_beta(m, beta as f32);
}

/// Overlapped-schedule completion for one module: shard-local combine of
/// exactly its parts, then the β fold — issued one module behind the
/// scalar control plane.
fn shard_combine_and_beta(t: &mut Trainer, m: usize) {
    t.scratch.shard_combine_module(m);
    shard_combine_beta_only(t, m);
}

/// Full-matrix reference implementation of the layer-wise sync (the
/// historical sequential per-module sweep; `shard_outer = false`).
fn layerwise_sync_reference(t: &mut Trainer, members: &[usize]) -> Result<u64> {
    t.detector.set_config(t.cfg.spec.penalty);
    if t.cfg.overlap_sync && t.table.num_modules() > 1 {
        return layerwise_sync_reference_overlapped(t, members);
    }
    let mut rollbacks = 0u64;
    // Module ranges partition the flat vector and each apply only
    // touches its own module, so computing Δ lazily per module from the
    // in-place-updated anchor is exact — and so is adopting the anchor
    // back into member parameters module by module.
    for module in 0..t.table.num_modules() {
        if !screen_and_weigh(t, module, members) {
            rollbacks += 1;
            // θ stays at the anchor for this module (rollback); members
            // still re-adopt it, discarding their local divergence.
            adopt_module(t, module, members);
            continue;
        }
        // Fused weighted combine + module norm, then the outer apply
        // with clip-β folded in.
        let module_sq = t.scratch.combine_module(module);
        let mut beta = 1.0f64;
        if t.cfg.spec.penalty.gradient_clip {
            let norm = module_sq.sqrt();
            beta = (t.cfg.spec.penalty.phi / (norm + t.cfg.spec.penalty.eps)).min(1.0);
        }
        t.scratch
            .apply_module(module, &mut t.outer, &mut t.anchor, beta as f32);
        adopt_module(t, module, members);
    }
    Ok(rollbacks)
}

/// Overlapped (software-pipelined) full-matrix sweep: the issue half of
/// module `m` (load → screen → weights → stage into a [`ModuleLane`])
/// runs while module `m-1`'s completion half (combine → β → outer apply
/// → adopt) is still outstanding, double-buffered across two lanes.
///
/// Bitwise-identical to the sequential sweep: the lane replays the same
/// kernel calls in the same order on staged copies of the same values,
/// and the deferred writes (anchor module `m-1`, member params module
/// `m-1`) are disjoint from the deferred reads (params/anchor module
/// `m`) because module ranges partition the flat vector. The detector
/// screen and the comm charges stay strictly in module order on the
/// issue side.
fn layerwise_sync_reference_overlapped(t: &mut Trainer, members: &[usize]) -> Result<u64> {
    let num_modules = t.table.num_modules();
    let mut rollbacks = 0u64;
    let mut lanes = t.scratch.take_overlap_lanes();
    for module in 0..num_modules {
        let ok = screen_and_weigh(t, module, members);
        if !ok {
            rollbacks += 1;
        }
        t.scratch
            .stage_module_lane(&mut lanes[module % 2], module, members.len(), !ok);
        if module >= 1 {
            complete_lane(t, &mut lanes[(module - 1) % 2], members);
        }
    }
    // Drain the pipeline tail.
    complete_lane(t, &mut lanes[(num_modules - 1) % 2], members);
    t.scratch.put_overlap_lanes(lanes);
    Ok(rollbacks)
}

/// The issue half of one module's full-matrix sweep: load the members'
/// pseudo-gradients, anomaly-screen the norms, charge the scalar
/// exchange, and compute the combine weights. Returns `false` when the
/// module rolls back (every member anomalous).
fn screen_and_weigh(t: &mut Trainer, module: usize, members: &[usize]) -> bool {
    {
        let replicas = &t.replicas;
        t.scratch.load_module_subset(
            module,
            members,
            |j| replicas[j].params.as_slice(),
            &t.anchor,
        );
    }
    if t.debug_norms {
        eprintln!(
            "sync {} module {module} members {members:?}: norms {:?}",
            t.syncs,
            t.scratch.norms()
        );
    }
    {
        let (norms, screened) = t.scratch.screen_buffers();
        t.detector
            .screen_subset_into(module, members, norms, screened);
    }
    // Scalar norm exchange in every member's shard group (cheap).
    for &j in members {
        let (bytes, secs) = t.plan.scalar_sync[j];
        t.comm.record(bytes, secs);
    }
    t.scratch.compute_weights(t.cfg.spec.penalty.weighted_averaging)
}

/// The completion half of one staged module: weighted combine, clip-β,
/// outer apply, and member adoption — all from the lane's detached
/// copies, one module behind the issue side.
fn complete_lane(t: &mut Trainer, lane: &mut ModuleLane, members: &[usize]) {
    if !lane.rolled_back {
        lane.combine();
        let mut beta = 1.0f64;
        if t.cfg.spec.penalty.gradient_clip {
            let norm = lane.sq.sqrt();
            beta = (t.cfg.spec.penalty.phi / (norm + t.cfg.spec.penalty.eps)).min(1.0);
        }
        lane.apply(&mut t.outer, &mut t.anchor, beta as f32);
    }
    adopt_module(t, lane.module, members);
}

/// Copy the anchor's module slices into each member's parameters — the
/// per-module adoption sweep that replaces the historical full-vector
/// `params ← anchor` pass (one cache-warm write per module instead of a
/// second full traversal).
fn adopt_module(t: &mut Trainer, module: usize, members: &[usize]) {
    let Trainer { scratch, replicas, anchor, .. } = t;
    for r in scratch.module_ranges_of(module) {
        let src = &anchor[r.offset..r.offset + r.len];
        for &j in members {
            replicas[j].params[r.offset..r.offset + r.len].copy_from_slice(src);
        }
    }
}

/// Apply any CO2 staleness-queue updates still in flight when the run
/// ends. Without this, the last `staleness` combined outer updates were
/// silently dropped at `run()` exit (their communication had already
/// been charged and their compute spent). Applied in FIFO order — the
/// order they would have landed in had the run continued.
pub(super) fn flush_pending(t: &mut Trainer) -> Result<()> {
    if t.pending.is_empty() {
        return Ok(());
    }
    while let Some(stale) = t.pending.pop_front() {
        t.outer.apply(&mut t.anchor, &stale);
        t.flushed_updates += 1;
        t.scratch.put_spare(stale);
    }
    for r in &mut t.replicas {
        r.params.copy_from_slice(&t.anchor);
    }
    Ok(())
}

/// Staleness + timeline bookkeeping for one sync's member set (the
/// whole live cluster at a barrier, one event group on the anchor
/// path).
fn note_sync_members(t: &mut Trainer, members: &[usize], clock: f64) {
    let v = t.anchor_version;
    for &j in members {
        note_one(t, j, v, clock);
    }
    t.anchor_version = v + 1;
}

fn note_one(t: &mut Trainer, j: usize, version: u64, clock: f64) {
    let stale = version - t.last_sync_version[j];
    if stale > t.max_staleness {
        t.max_staleness = stale;
    }
    t.last_sync_version[j] = version + 1;
    if t.cfg.trace_timeline {
        t.timeline.push(TimelineEvent {
            replica: j,
            clock,
            global_step: t.global_step,
            staleness: stale,
        });
    }
}

/// Post-sync bookkeeping shared by both paths: sync counter, periodic
/// validation, progress log.
fn post_sync(t: &mut Trainer) -> Result<()> {
    t.syncs += 1;
    if t.cfg.eval_every_syncs > 0 && t.syncs % t.cfg.eval_every_syncs == 0 {
        let val = t.evaluate()?;
        t.tracker.record_val(t.global_step, val);
    }
    if t.cfg.log_every > 0 && t.syncs % t.cfg.log_every == 0 {
        eprintln!(
            "[{}] step {:>6} sync {:>4} loss {:.4} ppl {:.2} simtime {:.1}s",
            t.cfg.label,
            t.global_step,
            t.syncs,
            t.tracker.losses.last().map(|x| x.1).unwrap_or(f64::NAN),
            t.tracker.val_ppl.last().map(|x| x.1).unwrap_or(f64::NAN),
            t.sim_time,
        );
    }
    Ok(())
}
