//! The M×N device mesh (paper §3.1, Fig. 1).
//!
//! K = M·N workers arranged so that
//!  * **model shard groups** (columns, M workers) jointly hold one full
//!    replica of the parameters, ZeRO-3 style — communication-intensive
//!    all-gather/reduce-scatter stays on the fast intra-node links;
//!  * **model sync groups** (rows, N workers) hold *identical* shards
//!    and synchronize only every τ inner steps over the slow links.
//!
//! Numerics note (DESIGN.md §4): within a shard group every worker ends
//! each inner step with identical full parameters (grads are averaged
//! every step), so the numerics path simulates one *logical replica per
//! column* with effective batch M·b, while communication volume/time is
//! accounted per physical worker through this mesh.

use crate::collectives::Topology;

/// Mesh shape: `shard` = M (shard-group size), `replicas` = N
/// (sync-group size = number of logical replicas).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshSpec {
    pub shard: usize,
    pub replicas: usize,
}

impl MeshSpec {
    pub fn new(shard: usize, replicas: usize) -> Self {
        assert!(shard > 0 && replicas > 0);
        Self { shard, replicas }
    }

    pub fn workers(&self) -> usize {
        self.shard * self.replicas
    }

    /// Global rank of worker (row=shard index i, col=replica j).
    /// Column-major so a shard group is contiguous — i.e. lives on one
    /// node when `shard <= gpus_per_node` (paper's recommended layout).
    pub fn rank(&self, row: usize, col: usize) -> usize {
        assert!(row < self.shard && col < self.replicas);
        col * self.shard + row
    }

    pub fn coords(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.workers());
        (rank % self.shard, rank / self.shard)
    }

    /// Ranks of model shard group `col` (one full replica).
    pub fn shard_group(&self, col: usize) -> Vec<usize> {
        (0..self.shard).map(|row| self.rank(row, col)).collect()
    }

    /// Ranks of model sync group `row` (identical shards across replicas).
    pub fn sync_group(&self, row: usize) -> Vec<usize> {
        (0..self.replicas).map(|col| self.rank(row, col)).collect()
    }

    /// All ranks (DDP world group).
    pub fn world(&self) -> Vec<usize> {
        (0..self.workers()).collect()
    }

    /// Whether shard groups fit within single nodes of `topo`.
    pub fn shard_groups_intra_node(&self, topo: &Topology) -> bool {
        self.shard <= topo.gpus_per_node && topo.gpus_per_node % self.shard == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn rank_coord_bijection() {
        check("mesh-bijection", 30, |g| {
            let m = MeshSpec::new(g.usize(1, 9), g.usize(1, 9));
            for rank in 0..m.workers() {
                let (r, c) = m.coords(rank);
                assert_eq!(m.rank(r, c), rank);
            }
        });
    }

    #[test]
    fn groups_partition_world() {
        let m = MeshSpec::new(4, 3);
        let mut seen = vec![false; 12];
        for col in 0..m.replicas {
            for r in m.shard_group(col) {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));

        let mut seen = vec![false; 12];
        for row in 0..m.shard {
            for r in m.sync_group(row) {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn groups_intersect_in_one_worker() {
        let m = MeshSpec::new(3, 4);
        for col in 0..m.replicas {
            for row in 0..m.shard {
                let sg = m.shard_group(col);
                let rg = m.sync_group(row);
                let inter: Vec<_> =
                    sg.iter().filter(|r| rg.contains(r)).collect();
                assert_eq!(inter.len(), 1);
                assert_eq!(*inter[0], m.rank(row, col));
            }
        }
    }

    #[test]
    fn shard_group_contiguous_on_node() {
        let m = MeshSpec::new(8, 8); // the paper's 8x8 mesh
        let topo = Topology::a100();
        assert!(m.shard_groups_intra_node(&topo));
        let sg = m.shard_group(3);
        let node = topo.node_of(sg[0]);
        assert!(sg.iter().all(|&r| topo.node_of(r) == node));
        // sync groups span all 8 nodes
        let rg = m.sync_group(0);
        let nodes: std::collections::HashSet<_> =
            rg.iter().map(|&r| topo.node_of(r)).collect();
        assert_eq!(nodes.len(), 8);
    }

    #[test]
    fn paper_mesh_sizes() {
        let m = MeshSpec::new(8, 8);
        assert_eq!(m.workers(), 64);
        assert_eq!(m.shard_group(0).len(), 8);
        assert_eq!(m.sync_group(0).len(), 8);
    }
}
