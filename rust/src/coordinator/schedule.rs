//! Inner learning-rate schedules.
//!
//! The paper applies a cosine decay across all experiments (§A.2); the
//! theoretical analysis (Thm. 1) uses η_{t,p} = η/sqrt(tτ+p+1), provided
//! here as [`LrSchedule::InvSqrt`] for the theorem-validation example.
//! The schedule runs in Rust (the HLO train step takes lr as a runtime
//! scalar) so elastic rescaling can re-shape it without re-lowering.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant { lr: f64 },
    /// Linear warmup to `lr`, then cosine decay to `lr * floor_frac`
    /// at `total_steps`.
    Cosine { lr: f64, warmup: u64, total_steps: u64, floor_frac: f64 },
    /// η / sqrt(step+1) — Theorem 1's inner schedule.
    InvSqrt { lr: f64 },
}

impl LrSchedule {
    /// Paper defaults: cosine with 1% warmup and 10% floor.
    pub fn paper_cosine(lr: f64, total_steps: u64) -> Self {
        LrSchedule::Cosine {
            lr,
            warmup: (total_steps / 100).max(1),
            total_steps,
            floor_frac: 0.1,
        }
    }

    /// Learning rate at global inner step `step` (0-based).
    pub fn at(&self, step: u64) -> f64 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::InvSqrt { lr } => lr / ((step + 1) as f64).sqrt(),
            LrSchedule::Cosine { lr, warmup, total_steps, floor_frac } => {
                if step < warmup {
                    return lr * (step + 1) as f64 / warmup as f64;
                }
                let total = total_steps.max(warmup + 1);
                let t = ((step - warmup) as f64
                    / (total - warmup) as f64)
                    .min(1.0);
                let floor = lr * floor_frac;
                floor
                    + 0.5 * (lr - floor) * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn cosine_shape() {
        let s = LrSchedule::Cosine { lr: 1.0, warmup: 10, total_steps: 110, floor_frac: 0.1 };
        // warmup ramps linearly
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(9) - 1.0).abs() < 1e-12);
        // peak then monotone decay
        let mut prev = s.at(10);
        for step in 11..110 {
            let cur = s.at(step);
            assert!(cur <= prev + 1e-12, "step {step}");
            prev = cur;
        }
        // floor reached, never undershot
        assert!((s.at(110) - 0.1).abs() < 1e-9);
        assert!(s.at(10_000) >= 0.1 - 1e-12);
    }

    #[test]
    fn inv_sqrt_matches_theorem() {
        let s = LrSchedule::InvSqrt { lr: 2.0 };
        assert_eq!(s.at(0), 2.0);
        assert!((s.at(3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_cosine_defaults() {
        let s = LrSchedule::paper_cosine(3e-4, 1000);
        match s {
            LrSchedule::Cosine { warmup, floor_frac, .. } => {
                assert_eq!(warmup, 10);
                assert!((floor_frac - 0.1).abs() < 1e-12);
            }
            _ => unreachable!(),
        }
    }
}
