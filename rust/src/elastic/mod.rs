//! Elastic-training driver (paper §4.4, Fig. 6c).
//!
//! Runs a worker-count schedule (e.g. 1→2→4→8 or 8→4→2→1 replicas),
//! rescaling the trainer at phase boundaries: new replicas clone the
//! synchronized parameters; outer momentum and anomaly statistics
//! survive; per-replica batch size stays fixed (the property EDiT's
//! LR-transfer depends on — Fig. 6a/b).
//!
//! Event-core contract: rescaling is a cluster rendezvous. This driver
//! only rescales at round boundaries — by then every pending sync event
//! of the event-driven A-EDiT path has been processed (the per-round
//! event queue drains before `run_round` returns) and `rescale()`
//! re-aligns all replica clocks to the current simulated time (it
//! errors out if the queue is not empty). Mid-round membership changes
//! — live evict on crash, live join — are driven by a fault plan
//! instead (see [`crate::fault`]).

use anyhow::Result;

use crate::coordinator::Trainer;

/// One phase of the elastic schedule.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub replicas: usize,
    pub steps: u64,
}

/// Scale-up and scale-down schedules from the paper (steps scaled by
/// the caller to the CPU regime).
pub fn paper_schedule(up: bool, steps_per_phase: u64) -> Vec<Phase> {
    let counts: [usize; 4] = if up { [1, 2, 4, 8] } else { [8, 4, 2, 1] };
    counts.iter().map(|&replicas| Phase { replicas, steps: steps_per_phase }).collect()
}

/// Validation-PPL sample taken at a phase boundary.
#[derive(Debug, Clone)]
pub struct ElasticPoint {
    pub global_step: u64,
    pub replicas: usize,
    pub val_ppl: f64,
}

/// Drive `trainer` through `phases`, rescaling between them. Returns
/// PPL checkpoints (one per phase end, plus periodic samples recorded
/// in the trainer's own tracker).
pub fn run_schedule(trainer: &mut Trainer, phases: &[Phase]) -> Result<Vec<ElasticPoint>> {
    // The phase loop retargets `total_steps` so each phase's rounds
    // stop at its boundary (and τ truncation + the LR-schedule clamp see
    // the phase end). That is a *loan*, not a config change: restore the
    // configured value afterwards — and on early error — so a schedule
    // never permanently clobbers the trainer's configuration.
    let configured_total = trainer.cfg.total_steps;
    let result = run_phases(trainer, phases);
    trainer.cfg.total_steps = configured_total;
    result
}

fn run_phases(trainer: &mut Trainer, phases: &[Phase]) -> Result<Vec<ElasticPoint>> {
    let mut points = Vec::new();
    for phase in phases {
        trainer.rescale(phase.replicas)?;
        let target = trainer.global_step + phase.steps;
        trainer.cfg.total_steps = target;
        while trainer.global_step < target {
            trainer.run_round()?;
        }
        let val = trainer.evaluate()?;
        points.push(ElasticPoint {
            global_step: trainer.global_step,
            replicas: phase.replicas,
            val_ppl: val.exp(),
        });
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_shape() {
        let up = paper_schedule(true, 100);
        assert_eq!(up.iter().map(|p| p.replicas).collect::<Vec<_>>(), vec![1, 2, 4, 8]);
        let down = paper_schedule(false, 50);
        assert_eq!(down[0].replicas, 8);
        assert!(down.iter().all(|p| p.steps == 50));
    }
}
