//! Deterministic fault injection for the elastic runtime.
//!
//! A [`FaultPlan`] is a seed-keyed schedule of worker **crash**,
//! **hang** and **rejoin** events, keyed on the trainer's local-round
//! counter and consumed by the round driver (`Trainer::local_round`)
//! before the lanes run. Every event is resolved from the plan — never
//! from wall-clock time or an ambient RNG — so a faulty run is exactly
//! as reproducible as a clean one: same seed + same plan ⇒ bitwise
//! identical trajectory, which is what lets `tests/fault_recovery.rs`
//! assert kill-at-round-k + restore against an uninterrupted run.
//!
//! Semantics (per event, applied at the *start* of the named round):
//!
//!  * `Crash { after_steps }` — the replica runs at most `after_steps`
//!    inner steps of the round (0 = dies immediately), then drops out:
//!    its pending contribution is excluded from the round's sync
//!    (A-EDiT: a per-group membership change; EDiT: the barrier falls
//!    back to a timeout-then-evict rendezvous priced at
//!    `TrainConfig::evict_timeout`), its clock freezes and it takes no
//!    further steps until a `Join` revives it.
//!  * `Hang { secs }` — a transient stall: the replica's clock jumps by
//!    `secs` before the round runs. Step-synced peers absorb the delay
//!    at the barrier; A-EDiT peers do not (no global barrier).
//!  * `Join` — revives a crashed replica, or (when targeting index
//!    `== replicas`) live-appends a brand-new one. Either way the
//!    joiner adopts the current anchor, zeroed inner-optimizer state
//!    and the present simulated clock; a revived replica's accrued
//!    anchor staleness is folded into `RunSummary::max_staleness`.
//!
//! Plans come from the `--fault-plan` CLI grammar ([`FaultPlan::parse`])
//! or the seeded generator ([`FaultPlan::random`]) used by the chaos CI
//! leg. Replica 0 is never a generated victim, so a generated plan can
//! never crash the whole cluster.
//!
//! **Wire-level kinds** (`NetDrop`, `NetDelay`, `Partition`) target the
//! socket transport, not the simulated trainer: they are consumed by
//! the collective driver (`collectives/driver.rs`) at the start of the
//! named round on the named *rank*, severing or delaying that worker's
//! TCP link to the rendezvous hub. Because reconnect + seq replay is
//! value-neutral (docs/WIRE_PROTOCOL.md §6) a net-faulted run ends at
//! the bitwise digest of the clean run — same seed + same plan ⇒ same
//! bits, exactly like the in-process kinds. The in-process trainer
//! rejects them (it has no wire to fault).

use crate::util::prng::{mix, Rng};

/// What happens to the targeted replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Drop out after at most `after_steps` inner steps of the round.
    Crash { after_steps: u64 },
    /// Clock jumps by `secs` (transient stall) before the round runs.
    Hang { secs: f64 },
    /// Revive a crashed replica, or live-append when the target index
    /// equals the current replica count.
    Join,
    /// Sever the rank's TCP link to the hub once; the worker redials
    /// and replays (wire-level, socket transport only).
    NetDrop,
    /// Stall the rank's wire activity by `ms` milliseconds before the
    /// round's first collective (wire-level).
    NetDelay { ms: u64 },
    /// Sever the rank's link *and* keep it away for `secs` seconds
    /// before redialling (wire-level). Must stay under the hub's
    /// heartbeat eviction window for a value-neutral replay.
    Partition { secs: f64 },
}

impl FaultKind {
    /// True for the wire-level kinds consumed by the socket transport
    /// driver rather than the in-process trainer.
    pub fn is_net(&self) -> bool {
        matches!(
            self,
            FaultKind::NetDrop | FaultKind::NetDelay { .. } | FaultKind::Partition { .. }
        )
    }
}

/// One scheduled fault: `kind` applied to `replica` at the start of
/// local round `round` (the trainer's post-warmup round counter).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub round: u64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// A deterministic schedule of fault events, sorted by round (stable:
/// same-round events keep their spec order, so `crash@3:1,join@3:2`
/// applies left to right).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan from explicit events (sorted by round, stably).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.round);
        Self { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Parse the `--fault-plan` grammar: comma-separated clauses
    ///
    /// ```text
    /// crash@ROUND:REPLICA        crash at round start (0 steps taken)
    /// crash@ROUND:REPLICA+STEPS  crash STEPS inner steps into the round
    /// hang@ROUND:REPLICA:SECS    clock stall of SECS simulated seconds
    /// join@ROUND:REPLICA         revive (or live-append at index = N)
    /// netdrop@ROUND:RANK         sever RANK's hub link once (wire)
    /// netdelay@ROUND:RANK:MS     delay RANK's wire by MS ms (wire)
    /// partition@ROUND:RANKS:SECS sever each of RANKS (a `+`-separated
    ///                            set, e.g. `1+2`) for SECS seconds
    /// random:PAIRS[:ROUNDS]      PAIRS seeded crash+rejoin pairs drawn
    ///                            over the first ROUNDS rounds (default
    ///                            16), keyed on the run seed
    /// random:PAIRS[:ROUNDS]:net  PAIRS seeded *wire* faults instead
    ///                            (netdrop/netdelay/partition mix)
    /// ```
    ///
    /// `seed` keys the `random:` clause; `replicas` bounds its victims.
    pub fn parse(spec: &str, seed: u64, replicas: usize) -> Result<Self, String> {
        let mut events = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(rest) = clause.strip_prefix("random:") {
                let mut it = rest.split(':').peekable();
                let pairs: usize = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("bad pair count in '{clause}'"))?;
                let rounds: u64 = match it.peek() {
                    Some(&"net") | None => 16,
                    Some(s) => {
                        let r =
                            s.parse().map_err(|_| format!("bad round count in '{clause}'"))?;
                        it.next();
                        r
                    }
                };
                let net = match it.next() {
                    Some("net") => true,
                    Some(other) => {
                        return Err(format!("trailing field '{other}' in '{clause}'"));
                    }
                    None => false,
                };
                if it.next().is_some() {
                    return Err(format!("trailing fields in '{clause}'"));
                }
                let generated = if net {
                    Self::random_net(seed, replicas, rounds, pairs)
                } else {
                    Self::random(seed, replicas, rounds, pairs)
                };
                events.extend(generated.events);
                continue;
            }
            let (kind, rest) = clause
                .split_once('@')
                .ok_or_else(|| format!("expected 'kind@round:replica' in '{clause}'"))?;
            let mut fields = rest.split(':');
            let round_field = fields
                .next()
                .ok_or_else(|| format!("missing round in '{clause}'"))?;
            let round: u64 = round_field
                .parse()
                .map_err(|_| format!("bad round '{round_field}' in '{clause}'"))?;
            let replica_field = fields
                .next()
                .ok_or_else(|| format!("missing replica in '{clause}'"))?;
            match kind {
                "crash" => {
                    let (rep, steps) = match replica_field.split_once('+') {
                        Some((r, s)) => (
                            r,
                            s.parse::<u64>()
                                .map_err(|_| format!("bad step count in '{clause}'"))?,
                        ),
                        None => (replica_field, 0),
                    };
                    let replica: usize = rep
                        .parse()
                        .map_err(|_| format!("bad replica '{rep}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent {
                        round,
                        replica,
                        kind: FaultKind::Crash { after_steps: steps },
                    });
                }
                "hang" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad replica '{replica_field}' in '{clause}'"))?;
                    let secs_field = fields
                        .next()
                        .ok_or_else(|| format!("missing seconds in '{clause}'"))?;
                    let secs: f64 = secs_field
                        .parse()
                        .map_err(|_| format!("bad seconds '{secs_field}' in '{clause}'"))?;
                    if !(secs >= 0.0) || fields.next().is_some() {
                        return Err(format!("bad hang clause '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::Hang { secs } });
                }
                "join" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad replica '{replica_field}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::Join });
                }
                "netdrop" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad rank '{replica_field}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::NetDrop });
                }
                "netdelay" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad rank '{replica_field}' in '{clause}'"))?;
                    let ms_field = fields
                        .next()
                        .ok_or_else(|| format!("missing milliseconds in '{clause}'"))?;
                    let ms: u64 = ms_field
                        .parse()
                        .map_err(|_| format!("bad milliseconds '{ms_field}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::NetDelay { ms } });
                }
                "partition" => {
                    // RANKS is a `+`-separated set: one event per rank.
                    let secs_field = fields
                        .next()
                        .ok_or_else(|| format!("missing seconds in '{clause}'"))?;
                    let secs: f64 = secs_field
                        .parse()
                        .map_err(|_| format!("bad seconds '{secs_field}' in '{clause}'"))?;
                    if !(secs >= 0.0) || fields.next().is_some() {
                        return Err(format!("bad partition clause '{clause}'"));
                    }
                    for rank in replica_field.split('+') {
                        let replica: usize = rank
                            .parse()
                            .map_err(|_| format!("bad rank '{rank}' in '{clause}'"))?;
                        events.push(FaultEvent {
                            round,
                            replica,
                            kind: FaultKind::Partition { secs },
                        });
                    }
                }
                other => return Err(format!("unknown fault kind '{other}' in '{clause}'")),
            }
        }
        Ok(Self::new(events))
    }

    /// Seeded crash+rejoin pairs for the chaos CI leg: `pairs` victims
    /// cycle over replicas `1..replicas` (never 0 — at least one
    /// survivor is guaranteed), each crashed partway into a round drawn
    /// from `[1, rounds)` and revived 1-3 rounds later. Windows on the
    /// same victim never overlap. Pure function of `(seed, replicas,
    /// rounds, pairs)`.
    pub fn random(seed: u64, replicas: usize, rounds: u64, pairs: usize) -> Self {
        let mut events = Vec::new();
        if replicas < 2 || rounds < 3 {
            return Self::new(events);
        }
        let mut rng = Rng::new(mix(seed ^ 0x00FA_0175, 0));
        // Earliest round each victim is free again (its last join + 1).
        let mut next_free = vec![1u64; replicas];
        for i in 0..pairs {
            let victim = 1 + i % (replicas - 1);
            let crash = next_free[victim] + rng.below(3);
            if crash + 2 > rounds {
                continue; // no room left for this victim's window
            }
            let after_steps = rng.below(3);
            // `crash + 2 <= rounds` above guarantees room for the join.
            let join = (crash + 1 + rng.below(3)).min(rounds - 1);
            events.push(FaultEvent {
                round: crash,
                replica: victim,
                kind: FaultKind::Crash { after_steps },
            });
            events.push(FaultEvent { round: join, replica: victim, kind: FaultKind::Join });
            next_free[victim] = join + 1;
        }
        Self::new(events)
    }

    /// Seeded *wire* faults for the chaos-multiproc CI leg: `pairs`
    /// events cycling victims over ranks `1..replicas` (never 0),
    /// each a netdrop, netdelay or short partition at a round drawn
    /// from `[1, rounds)`. Delays stay in `[10, 160)` ms and partitions
    /// under 0.7 s — comfortably inside the hub's heartbeat eviction
    /// window, so reconnect + replay keeps the run value-neutral.
    /// Pure function of `(seed, replicas, rounds, pairs)`.
    pub fn random_net(seed: u64, replicas: usize, rounds: u64, pairs: usize) -> Self {
        let mut events = Vec::new();
        if replicas < 2 || rounds < 2 {
            return Self::new(events);
        }
        let mut rng = Rng::new(mix(seed ^ 0x00FA_0175, 1));
        for i in 0..pairs {
            let victim = 1 + i % (replicas - 1);
            let round = 1 + rng.below(rounds - 1);
            let kind = match rng.below(3) {
                0 => FaultKind::NetDrop,
                1 => FaultKind::NetDelay { ms: 10 + rng.below(150) },
                _ => FaultKind::Partition { secs: 0.1 + 0.1 * rng.below(6) as f64 },
            };
            events.push(FaultEvent { round, replica: victim, kind });
        }
        Self::new(events)
    }

    /// The wire-level events scheduled for `(round, rank)`, in spec
    /// order — the per-round hook the socket driver consumes.
    pub fn net_events_at(&self, round: u64, rank: usize) -> impl Iterator<Item = &FaultEvent> {
        self.events
            .iter()
            .filter(move |e| e.round == round && e.replica == rank && e.kind.is_net())
    }

    /// Human-readable one-line rendering (logs, CSV rows).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            match e.kind {
                FaultKind::Crash { after_steps } if after_steps > 0 => {
                    out.push_str(&format!("crash@{}:{}+{}", e.round, e.replica, after_steps));
                }
                FaultKind::Crash { .. } => {
                    out.push_str(&format!("crash@{}:{}", e.round, e.replica));
                }
                FaultKind::Hang { secs } => {
                    out.push_str(&format!("hang@{}:{}:{}", e.round, e.replica, secs));
                }
                FaultKind::Join => out.push_str(&format!("join@{}:{}", e.round, e.replica)),
                FaultKind::NetDrop => {
                    out.push_str(&format!("netdrop@{}:{}", e.round, e.replica));
                }
                FaultKind::NetDelay { ms } => {
                    out.push_str(&format!("netdelay@{}:{}:{}", e.round, e.replica, ms));
                }
                FaultKind::Partition { secs } => {
                    out.push_str(&format!("partition@{}:{}:{}", e.round, e.replica, secs));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_explicit_clauses() {
        let p = FaultPlan::parse("crash@3:1, join@6:1, hang@2:0:4.5, crash@4:2+3", 42, 4).unwrap();
        assert_eq!(p.events().len(), 4);
        // Sorted by round, stable.
        assert_eq!(p.events()[0], FaultEvent {
            round: 2,
            replica: 0,
            kind: FaultKind::Hang { secs: 4.5 },
        });
        assert_eq!(p.events()[1], FaultEvent {
            round: 3,
            replica: 1,
            kind: FaultKind::Crash { after_steps: 0 },
        });
        assert_eq!(p.events()[2], FaultEvent {
            round: 4,
            replica: 2,
            kind: FaultKind::Crash { after_steps: 3 },
        });
        assert_eq!(p.events()[3], FaultEvent { round: 6, replica: 1, kind: FaultKind::Join });
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "crash3:1",
            "crash@x:1",
            "crash@3:y",
            "hang@3:1",
            "hang@3:1:-2",
            "explode@3:1",
            "join@3:1:9",
            "random:x",
        ] {
            assert!(FaultPlan::parse(bad, 42, 4).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        assert!(FaultPlan::parse("", 42, 4).unwrap().is_empty());
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn random_plan_is_seed_deterministic_and_spares_replica_zero() {
        let a = FaultPlan::random(7, 4, 12, 3);
        let b = FaultPlan::random(7, 4, 12, 3);
        assert_eq!(a, b);
        let c = FaultPlan::random(8, 4, 12, 3);
        assert_ne!(a, c, "different seeds should differ");
        assert!(!a.is_empty());
        assert!(a.events().iter().all(|e| e.replica != 0));
        // Every crash has a later join for the same victim.
        for e in a.events() {
            if let FaultKind::Crash { .. } = e.kind {
                assert!(a.events().iter().any(|j| j.replica == e.replica
                    && j.kind == FaultKind::Join
                    && j.round > e.round));
            }
        }
    }

    #[test]
    fn random_windows_never_overlap_per_victim() {
        let p = FaultPlan::random(3, 3, 40, 10);
        // Walk each victim's events in round order: must alternate
        // crash, join, crash, join...
        for victim in 1..3 {
            let mut down = false;
            for e in p.events().iter().filter(|e| e.replica == victim) {
                match e.kind {
                    FaultKind::Crash { .. } => {
                        assert!(!down, "crash while already down");
                        down = true;
                    }
                    FaultKind::Join => {
                        assert!(down, "join while alive");
                        down = false;
                    }
                    FaultKind::Hang { .. } => {}
                }
            }
        }
    }

    #[test]
    fn random_degenerate_configs_are_empty() {
        assert!(FaultPlan::random(7, 1, 20, 3).is_empty(), "single replica: no victims");
        assert!(FaultPlan::random(7, 4, 2, 3).is_empty(), "too few rounds");
    }

    #[test]
    fn describe_roundtrips_through_parse() {
        let p = FaultPlan::parse("crash@3:1+2,join@6:1,hang@2:0:4.5", 42, 4).unwrap();
        let q = FaultPlan::parse(&p.describe(), 42, 4).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn parses_net_clauses() {
        let p =
            FaultPlan::parse("netdrop@1:1, netdelay@2:0:250, partition@3:1+2:0.5", 42, 4).unwrap();
        assert_eq!(p.events().len(), 4);
        assert_eq!(p.events()[0], FaultEvent { round: 1, replica: 1, kind: FaultKind::NetDrop });
        assert_eq!(p.events()[1], FaultEvent {
            round: 2,
            replica: 0,
            kind: FaultKind::NetDelay { ms: 250 },
        });
        // The multi-rank partition set expands to one event per rank.
        assert_eq!(p.events()[2], FaultEvent {
            round: 3,
            replica: 1,
            kind: FaultKind::Partition { secs: 0.5 },
        });
        assert_eq!(p.events()[3], FaultEvent {
            round: 3,
            replica: 2,
            kind: FaultKind::Partition { secs: 0.5 },
        });
        assert!(p.events().iter().all(|e| e.kind.is_net()));
    }

    #[test]
    fn rejects_malformed_net_clauses() {
        for bad in [
            "netdrop@1",
            "netdrop@1:1:9",
            "netdelay@1:1",
            "netdelay@1:1:x",
            "partition@1:1",
            "partition@1:1:-2",
            "partition@1:1+x:0.5",
            "random:2:net:9",
            "random:2:16:net:x",
        ] {
            assert!(FaultPlan::parse(bad, 42, 4).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn net_describe_roundtrips_through_parse() {
        let p = FaultPlan::parse("netdrop@1:1,netdelay@2:0:250,partition@3:1+2:0.5", 42, 4)
            .unwrap();
        let q = FaultPlan::parse(&p.describe(), 42, 4).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn random_net_is_seed_deterministic_and_spares_rank_zero() {
        let a = FaultPlan::parse("random:4:net", 7, 3).unwrap();
        let b = FaultPlan::random_net(7, 3, 16, 4);
        assert_eq!(a, b, "CLI form must hit the same generator");
        let c = FaultPlan::random_net(8, 3, 16, 4);
        assert_ne!(a, c, "different seeds should differ");
        assert_ne!(
            FaultPlan::random(7, 3, 16, 4),
            b,
            "net stream must be decorrelated from the crash stream"
        );
        assert_eq!(a.events().len(), 4);
        assert!(a.events().iter().all(|e| e.replica != 0));
        assert!(a.events().iter().all(|e| e.kind.is_net()));
        // Every delay/partition stays under the hub eviction window.
        for e in a.events() {
            match e.kind {
                FaultKind::NetDelay { ms } => assert!(ms < 160),
                FaultKind::Partition { secs } => assert!(secs < 0.7),
                _ => {}
            }
        }
        // Explicit-rounds form with the suffix also parses.
        let d = FaultPlan::parse("random:4:8:net", 7, 3).unwrap();
        assert_eq!(d, FaultPlan::random_net(7, 3, 8, 4));
        assert!(d.events().iter().all(|e| e.round < 8));
    }

    #[test]
    fn net_events_at_filters_round_and_rank() {
        let p = FaultPlan::parse("netdrop@1:1,netdelay@1:1:20,crash@1:1,netdrop@2:1", 42, 4)
            .unwrap();
        let hits: Vec<_> = p.net_events_at(1, 1).collect();
        assert_eq!(hits.len(), 2, "crash is not a net event");
        assert_eq!(hits[0].kind, FaultKind::NetDrop);
        assert_eq!(hits[1].kind, FaultKind::NetDelay { ms: 20 });
        assert!(p.net_events_at(1, 0).next().is_none());
        assert!(p.net_events_at(3, 1).next().is_none());
    }
}
