//! Deterministic fault injection for the elastic runtime.
//!
//! A [`FaultPlan`] is a seed-keyed schedule of worker **crash**,
//! **hang** and **rejoin** events, keyed on the trainer's local-round
//! counter and consumed by the round driver (`Trainer::local_round`)
//! before the lanes run. Every event is resolved from the plan — never
//! from wall-clock time or an ambient RNG — so a faulty run is exactly
//! as reproducible as a clean one: same seed + same plan ⇒ bitwise
//! identical trajectory, which is what lets `tests/fault_recovery.rs`
//! assert kill-at-round-k + restore against an uninterrupted run.
//!
//! Semantics (per event, applied at the *start* of the named round):
//!
//!  * `Crash { after_steps }` — the replica runs at most `after_steps`
//!    inner steps of the round (0 = dies immediately), then drops out:
//!    its pending contribution is excluded from the round's sync
//!    (A-EDiT: a per-group membership change; EDiT: the barrier falls
//!    back to a timeout-then-evict rendezvous priced at
//!    `TrainConfig::evict_timeout`), its clock freezes and it takes no
//!    further steps until a `Join` revives it.
//!  * `Hang { secs }` — a transient stall: the replica's clock jumps by
//!    `secs` before the round runs. Step-synced peers absorb the delay
//!    at the barrier; A-EDiT peers do not (no global barrier).
//!  * `Join` — revives a crashed replica, or (when targeting index
//!    `== replicas`) live-appends a brand-new one. Either way the
//!    joiner adopts the current anchor, zeroed inner-optimizer state
//!    and the present simulated clock; a revived replica's accrued
//!    anchor staleness is folded into `RunSummary::max_staleness`.
//!
//! Plans come from the `--fault-plan` CLI grammar ([`FaultPlan::parse`])
//! or the seeded generator ([`FaultPlan::random`]) used by the chaos CI
//! leg. Replica 0 is never a generated victim, so a generated plan can
//! never crash the whole cluster.

use crate::util::prng::{mix, Rng};

/// What happens to the targeted replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Drop out after at most `after_steps` inner steps of the round.
    Crash { after_steps: u64 },
    /// Clock jumps by `secs` (transient stall) before the round runs.
    Hang { secs: f64 },
    /// Revive a crashed replica, or live-append when the target index
    /// equals the current replica count.
    Join,
}

/// One scheduled fault: `kind` applied to `replica` at the start of
/// local round `round` (the trainer's post-warmup round counter).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub round: u64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// A deterministic schedule of fault events, sorted by round (stable:
/// same-round events keep their spec order, so `crash@3:1,join@3:2`
/// applies left to right).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan from explicit events (sorted by round, stably).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.round);
        Self { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Parse the `--fault-plan` grammar: comma-separated clauses
    ///
    /// ```text
    /// crash@ROUND:REPLICA        crash at round start (0 steps taken)
    /// crash@ROUND:REPLICA+STEPS  crash STEPS inner steps into the round
    /// hang@ROUND:REPLICA:SECS    clock stall of SECS simulated seconds
    /// join@ROUND:REPLICA         revive (or live-append at index = N)
    /// random:PAIRS[:ROUNDS]      PAIRS seeded crash+rejoin pairs drawn
    ///                            over the first ROUNDS rounds (default
    ///                            16), keyed on the run seed
    /// ```
    ///
    /// `seed` keys the `random:` clause; `replicas` bounds its victims.
    pub fn parse(spec: &str, seed: u64, replicas: usize) -> Result<Self, String> {
        let mut events = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(rest) = clause.strip_prefix("random:") {
                let mut it = rest.split(':');
                let pairs: usize = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("bad pair count in '{clause}'"))?;
                let rounds: u64 = match it.next() {
                    Some(s) => s.parse().map_err(|_| format!("bad round count in '{clause}'"))?,
                    None => 16,
                };
                if it.next().is_some() {
                    return Err(format!("trailing fields in '{clause}'"));
                }
                events.extend(Self::random(seed, replicas, rounds, pairs).events);
                continue;
            }
            let (kind, rest) = clause
                .split_once('@')
                .ok_or_else(|| format!("expected 'kind@round:replica' in '{clause}'"))?;
            let mut fields = rest.split(':');
            let round_field = fields
                .next()
                .ok_or_else(|| format!("missing round in '{clause}'"))?;
            let round: u64 = round_field
                .parse()
                .map_err(|_| format!("bad round '{round_field}' in '{clause}'"))?;
            let replica_field = fields
                .next()
                .ok_or_else(|| format!("missing replica in '{clause}'"))?;
            match kind {
                "crash" => {
                    let (rep, steps) = match replica_field.split_once('+') {
                        Some((r, s)) => (
                            r,
                            s.parse::<u64>()
                                .map_err(|_| format!("bad step count in '{clause}'"))?,
                        ),
                        None => (replica_field, 0),
                    };
                    let replica: usize = rep
                        .parse()
                        .map_err(|_| format!("bad replica '{rep}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent {
                        round,
                        replica,
                        kind: FaultKind::Crash { after_steps: steps },
                    });
                }
                "hang" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad replica '{replica_field}' in '{clause}'"))?;
                    let secs_field = fields
                        .next()
                        .ok_or_else(|| format!("missing seconds in '{clause}'"))?;
                    let secs: f64 = secs_field
                        .parse()
                        .map_err(|_| format!("bad seconds '{secs_field}' in '{clause}'"))?;
                    if !(secs >= 0.0) || fields.next().is_some() {
                        return Err(format!("bad hang clause '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::Hang { secs } });
                }
                "join" => {
                    let replica: usize = replica_field
                        .parse()
                        .map_err(|_| format!("bad replica '{replica_field}' in '{clause}'"))?;
                    if fields.next().is_some() {
                        return Err(format!("trailing fields in '{clause}'"));
                    }
                    events.push(FaultEvent { round, replica, kind: FaultKind::Join });
                }
                other => return Err(format!("unknown fault kind '{other}' in '{clause}'")),
            }
        }
        Ok(Self::new(events))
    }

    /// Seeded crash+rejoin pairs for the chaos CI leg: `pairs` victims
    /// cycle over replicas `1..replicas` (never 0 — at least one
    /// survivor is guaranteed), each crashed partway into a round drawn
    /// from `[1, rounds)` and revived 1-3 rounds later. Windows on the
    /// same victim never overlap. Pure function of `(seed, replicas,
    /// rounds, pairs)`.
    pub fn random(seed: u64, replicas: usize, rounds: u64, pairs: usize) -> Self {
        let mut events = Vec::new();
        if replicas < 2 || rounds < 3 {
            return Self::new(events);
        }
        let mut rng = Rng::new(mix(seed ^ 0x00FA_0175, 0));
        // Earliest round each victim is free again (its last join + 1).
        let mut next_free = vec![1u64; replicas];
        for i in 0..pairs {
            let victim = 1 + i % (replicas - 1);
            let crash = next_free[victim] + rng.below(3);
            if crash + 2 > rounds {
                continue; // no room left for this victim's window
            }
            let after_steps = rng.below(3);
            // `crash + 2 <= rounds` above guarantees room for the join.
            let join = (crash + 1 + rng.below(3)).min(rounds - 1);
            events.push(FaultEvent {
                round: crash,
                replica: victim,
                kind: FaultKind::Crash { after_steps },
            });
            events.push(FaultEvent { round: join, replica: victim, kind: FaultKind::Join });
            next_free[victim] = join + 1;
        }
        Self::new(events)
    }

    /// Human-readable one-line rendering (logs, CSV rows).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            match e.kind {
                FaultKind::Crash { after_steps } if after_steps > 0 => {
                    out.push_str(&format!("crash@{}:{}+{}", e.round, e.replica, after_steps));
                }
                FaultKind::Crash { .. } => {
                    out.push_str(&format!("crash@{}:{}", e.round, e.replica));
                }
                FaultKind::Hang { secs } => {
                    out.push_str(&format!("hang@{}:{}:{}", e.round, e.replica, secs));
                }
                FaultKind::Join => out.push_str(&format!("join@{}:{}", e.round, e.replica)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_explicit_clauses() {
        let p = FaultPlan::parse("crash@3:1, join@6:1, hang@2:0:4.5, crash@4:2+3", 42, 4).unwrap();
        assert_eq!(p.events().len(), 4);
        // Sorted by round, stable.
        assert_eq!(p.events()[0], FaultEvent {
            round: 2,
            replica: 0,
            kind: FaultKind::Hang { secs: 4.5 },
        });
        assert_eq!(p.events()[1], FaultEvent {
            round: 3,
            replica: 1,
            kind: FaultKind::Crash { after_steps: 0 },
        });
        assert_eq!(p.events()[2], FaultEvent {
            round: 4,
            replica: 2,
            kind: FaultKind::Crash { after_steps: 3 },
        });
        assert_eq!(p.events()[3], FaultEvent { round: 6, replica: 1, kind: FaultKind::Join });
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "crash3:1",
            "crash@x:1",
            "crash@3:y",
            "hang@3:1",
            "hang@3:1:-2",
            "explode@3:1",
            "join@3:1:9",
            "random:x",
        ] {
            assert!(FaultPlan::parse(bad, 42, 4).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        assert!(FaultPlan::parse("", 42, 4).unwrap().is_empty());
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn random_plan_is_seed_deterministic_and_spares_replica_zero() {
        let a = FaultPlan::random(7, 4, 12, 3);
        let b = FaultPlan::random(7, 4, 12, 3);
        assert_eq!(a, b);
        let c = FaultPlan::random(8, 4, 12, 3);
        assert_ne!(a, c, "different seeds should differ");
        assert!(!a.is_empty());
        assert!(a.events().iter().all(|e| e.replica != 0));
        // Every crash has a later join for the same victim.
        for e in a.events() {
            if let FaultKind::Crash { .. } = e.kind {
                assert!(a.events().iter().any(|j| j.replica == e.replica
                    && j.kind == FaultKind::Join
                    && j.round > e.round));
            }
        }
    }

    #[test]
    fn random_windows_never_overlap_per_victim() {
        let p = FaultPlan::random(3, 3, 40, 10);
        // Walk each victim's events in round order: must alternate
        // crash, join, crash, join...
        for victim in 1..3 {
            let mut down = false;
            for e in p.events().iter().filter(|e| e.replica == victim) {
                match e.kind {
                    FaultKind::Crash { .. } => {
                        assert!(!down, "crash while already down");
                        down = true;
                    }
                    FaultKind::Join => {
                        assert!(down, "join while alive");
                        down = false;
                    }
                    FaultKind::Hang { .. } => {}
                }
            }
        }
    }

    #[test]
    fn random_degenerate_configs_are_empty() {
        assert!(FaultPlan::random(7, 1, 20, 3).is_empty(), "single replica: no victims");
        assert!(FaultPlan::random(7, 4, 2, 3).is_empty(), "too few rounds");
    }

    #[test]
    fn describe_roundtrips_through_parse() {
        let p = FaultPlan::parse("crash@3:1+2,join@6:1,hang@2:0:4.5", 42, 4).unwrap();
        let q = FaultPlan::parse(&p.describe(), 42, 4).unwrap();
        assert_eq!(p, q);
    }
}
