//! Synthetic corpus substrate (DESIGN.md §2.4).
//!
//! The paper trains on FineWeb-Edu (clean) and an in-house noisy corpus.
//! Neither is available offline, so this module builds the closest
//! synthetic equivalent that exercises the same code paths:
//!
//!  * [`Language`] — a deterministic order-1 Markov "language" with
//!    Zipfian successor distributions.  Cross-entropy against it is
//!    genuinely learnable (entropy ~2 nats vs ln(V) at init), so loss
//!    curves behave like LM loss curves.
//!  * [`Quality`] — low-quality-document injection (uniform noise /
//!    token repetition / shuffled text), reproducing the loss-spike
//!    mechanism the pseudo-gradient penalty targets (paper §3.2: small
//!    per-worker batches hit bad documents and spike).
//!  * [`Corpus`] — deterministic sharded batch iterator: the batch for
//!    `(worker, step)` is a pure function of the seed, so every method
//!    sees identical data streams and curves are comparable.

pub mod probe;

use crate::util::prng::{mix, Rng};

/// Branching factor of the Markov language (candidate successors/token).
const SUCCESSORS: usize = 8;
/// Zipf exponent over successor ranks.
const ZIPF_S: f64 = 1.2;
/// Probability mass of uniform-noise smoothing in the language itself.
const SMOOTHING: f64 = 0.05;

/// A deterministic synthetic language over `vocab` tokens.
#[derive(Debug, Clone)]
pub struct Language {
    vocab: usize,
    /// `successors[t]` = candidate next tokens after t.
    successors: Vec<[u32; SUCCESSORS]>,
    /// Cumulative Zipf weights shared by all tokens.
    cum_weights: [f64; SUCCESSORS],
}

impl Language {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= SUCCESSORS);
        let mut successors = Vec::with_capacity(vocab);
        for t in 0..vocab {
            let mut rng = Rng::new(mix(seed, t as u64));
            let mut cand = [0u32; SUCCESSORS];
            for c in cand.iter_mut() {
                *c = rng.below(vocab as u64) as u32;
            }
            successors.push(cand);
        }
        let mut weights = [0.0f64; SUCCESSORS];
        for (i, w) in weights.iter_mut().enumerate() {
            *w = 1.0 / ((i + 1) as f64).powf(ZIPF_S);
        }
        let total: f64 = weights.iter().sum();
        let mut cum = [0.0f64; SUCCESSORS];
        let mut acc = 0.0;
        for i in 0..SUCCESSORS {
            acc += weights[i] / total;
            cum[i] = acc;
        }
        Self { vocab, successors, cum_weights: cum }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample the next token after `prev`.
    pub fn next_token(&self, prev: u32, rng: &mut Rng) -> u32 {
        if rng.chance(SMOOTHING) {
            return rng.below(self.vocab as u64) as u32;
        }
        let x = rng.f64();
        let rank = self
            .cum_weights
            .iter()
            .position(|&c| x <= c)
            .unwrap_or(SUCCESSORS - 1);
        self.successors[prev as usize][rank]
    }

    /// Sample a clean document of `len` tokens.
    pub fn document(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut doc = Vec::with_capacity(len);
        let mut prev = rng.below(self.vocab as u64) as u32;
        doc.push(prev);
        for _ in 1..len {
            prev = self.next_token(prev, rng);
            doc.push(prev);
        }
        doc
    }
}

/// Low-quality document kinds (the "in-house corpus" failure modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseKind {
    /// i.i.d. uniform tokens — maximal cross-entropy.
    Uniform,
    /// One token repeated — degenerate distribution.
    Repeat,
    /// A clean document, order destroyed.
    Shuffle,
}

/// Corpus quality profile.
#[derive(Debug, Clone, Copy)]
pub struct Quality {
    /// Probability a sampled document is low-quality.
    pub noise_prob: f64,
}

impl Quality {
    /// FineWeb-Edu analog: highly curated.
    pub fn clean() -> Self {
        Self { noise_prob: 0.0 }
    }

    /// In-house analog: diverse quality (paper §4.1 / Fig. 7).
    pub fn noisy() -> Self {
        Self { noise_prob: 0.03 }
    }
}

/// Deterministic sharded batch source.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub language: Language,
    pub quality: Quality,
    seed: u64,
}

/// Stream namespaces: train and validation never overlap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    /// Validation stream `v` (several held-out streams for Table 1).
    Validation(u32),
}

impl Split {
    fn tag(self) -> u64 {
        match self {
            Split::Train => 0x7261_494e,
            Split::Validation(v) => 0x5641_4c00 ^ (v as u64) << 32,
        }
    }
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64, quality: Quality) -> Self {
        Self { language: Language::new(vocab, mix(seed, 0x4c41_4e47)), quality, seed }
    }

    /// One token sequence of length `len` for (split, worker, step, idx).
    /// Pure function of the corpus seed — identical across methods/runs.
    pub fn sequence(
        &self,
        split: Split,
        worker: usize,
        step: u64,
        idx: usize,
        len: usize,
    ) -> Vec<u32> {
        let mut buf = Vec::with_capacity(len);
        self.sequence_into(split, worker, step, idx, len, &mut buf);
        buf.iter().map(|&t| t as u32).collect()
    }

    /// Append the sequence for (split, worker, step, idx) to `out` as
    /// i32 tokens (the shape the runtime consumes). Allocation-free when
    /// `out` has capacity — the trainer's `SyncScratch` token buffer
    /// relies on this to keep the inner-step loop heap-quiet.
    ///
    /// RNG consumption order matches the historical `sequence` exactly
    /// (document sampling, then the quality coin, then corruption), so
    /// data streams are unchanged.
    pub fn sequence_into(
        &self,
        split: Split,
        worker: usize,
        step: u64,
        idx: usize,
        len: usize,
        out: &mut Vec<i32>,
    ) {
        let stream = mix(
            self.seed ^ split.tag(),
            (worker as u64) << 40 ^ step << 8 ^ idx as u64,
        );
        let mut rng = Rng::new(stream);
        let start = out.len();
        if len > 0 {
            let mut prev = rng.below(self.language.vocab as u64) as u32;
            out.push(prev as i32);
            for _ in 1..len {
                prev = self.language.next_token(prev, &mut rng);
                out.push(prev as i32);
            }
        }
        if !rng.chance(self.quality.noise_prob) {
            return;
        }
        let kind = match rng.below(3) {
            0 => NoiseKind::Uniform,
            1 => NoiseKind::Repeat,
            _ => NoiseKind::Shuffle,
        };
        let doc = &mut out[start..];
        match kind {
            NoiseKind::Uniform => {
                for t in doc.iter_mut() {
                    *t = rng.below(self.language.vocab as u64) as i32;
                }
            }
            NoiseKind::Repeat => {
                let t = rng.below(self.language.vocab as u64) as i32;
                doc.fill(t);
            }
            NoiseKind::Shuffle => rng.shuffle(doc),
        }
    }

    /// A flattened i32 batch `[batch, seq+1]` ready for the tokens literal.
    pub fn batch_i32(
        &self,
        split: Split,
        worker: usize,
        step: u64,
        batch: usize,
        seq_plus_1: usize,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_1);
        for idx in 0..batch {
            self.sequence_into(split, worker, step, idx, seq_plus_1, &mut out);
        }
        out
    }

    /// Empirical per-token entropy estimate of the clean language (nats),
    /// used by tests and EXPERIMENTS.md to sanity-check convergence floors.
    pub fn entropy_estimate(&self, samples: usize) -> f64 {
        // H ~= -E[log p(next|prev)] under the generative process.
        let mut rng = Rng::new(mix(self.seed, 0xE117));
        let zipf: Vec<f64> = {
            let mut w: Vec<f64> =
                (0..SUCCESSORS).map(|i| 1.0 / ((i + 1) as f64).powf(ZIPF_S)).collect();
            let total: f64 = w.iter().sum();
            w.iter_mut().for_each(|x| *x /= total);
            w
        };
        let v = self.language.vocab as f64;
        let mut h = 0.0;
        for _ in 0..samples {
            let prev = rng.below(self.language.vocab as u64) as u32;
            // p(next) = (1-s)*zipf[rank] (+ s/V smoothing, approximated)
            let x = rng.f64();
            let rank = {
                let mut acc = 0.0;
                let mut r = SUCCESSORS - 1;
                for (i, &w) in zipf.iter().enumerate() {
                    acc += w;
                    if x <= acc {
                        r = i;
                        break;
                    }
                }
                r
            };
            // Duplicate candidates fold probability mass together; ignore
            // (rare for V >> SUCCESSORS) — this is an estimate.
            let _ = prev;
            let p = (1.0 - SMOOTHING) * zipf[rank] + SMOOTHING / v;
            h -= p.ln() * 1.0;
        }
        h / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(512, 42, Quality::clean())
    }

    #[test]
    fn deterministic_sequences() {
        let c = corpus();
        let a = c.sequence(Split::Train, 3, 17, 1, 64);
        let b = c.sequence(Split::Train, 3, 17, 1, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn streams_disjoint_across_workers_steps_splits() {
        let c = corpus();
        let base = c.sequence(Split::Train, 0, 0, 0, 64);
        assert_ne!(base, c.sequence(Split::Train, 1, 0, 0, 64));
        assert_ne!(base, c.sequence(Split::Train, 0, 1, 0, 64));
        assert_ne!(base, c.sequence(Split::Validation(0), 0, 0, 0, 64));
        assert_ne!(
            c.sequence(Split::Validation(0), 0, 0, 0, 64),
            c.sequence(Split::Validation(1), 0, 0, 0, 64)
        );
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        for t in c.sequence(Split::Train, 0, 0, 0, 512) {
            assert!((t as usize) < 512);
        }
    }

    #[test]
    fn language_is_predictable() {
        // Successor distribution concentrated: the most frequent bigram
        // successor should dominate a uniform baseline.
        let c = corpus();
        let mut rng = Rng::new(1);
        let mut counts = std::collections::HashMap::new();
        let prev = 7u32;
        for _ in 0..2_000 {
            *counts.entry(c.language.next_token(prev, &mut rng)).or_insert(0usize) += 1;
        }
        let max = counts.values().copied().max().unwrap();
        assert!(max > 2_000 / 3, "top successor should dominate, got {max}");
    }

    #[test]
    fn noisy_corpus_injects_bad_docs() {
        let noisy = Corpus::new(512, 42, Quality { noise_prob: 0.5 });
        let n = 200;
        let mut degenerate = 0;
        for i in 0..n {
            let doc = noisy.sequence(Split::Train, 0, 0, i, 64);
            let uniq: std::collections::HashSet<_> = doc.iter().collect();
            if uniq.len() <= 1 {
                degenerate += 1; // Repeat-kind docs
            }
        }
        assert!(degenerate > 5, "expected repeat docs, got {degenerate}");
        // Clean corpus never repeats a token 64x
        for i in 0..50 {
            let doc = corpus().sequence(Split::Train, 0, 0, i, 64);
            let uniq: std::collections::HashSet<_> = doc.iter().collect();
            assert!(uniq.len() > 1);
        }
    }

    #[test]
    fn batch_layout() {
        let c = corpus();
        let b = c.batch_i32(Split::Train, 2, 5, 3, 33);
        assert_eq!(b.len(), 3 * 33);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 512));
        // Row 0 equals sequence(.., idx=0)
        let row0: Vec<i32> =
            c.sequence(Split::Train, 2, 5, 0, 33).iter().map(|&t| t as i32).collect();
        assert_eq!(&b[..33], &row0[..]);
    }

    #[test]
    fn entropy_well_below_uniform() {
        let c = corpus();
        let h = c.entropy_estimate(20_000);
        assert!(h < (512f64).ln() * 0.6, "H={h}");
        assert!(h > 0.5, "H={h}");
    }
}
