//! Evaluation probes — the offline substitute for the paper's public
//! benchmarks (Table 1: MMLU/ARC/HellaSwag/...).
//!
//! Real benchmark data is unavailable in this environment, so each
//! "benchmark" is a held-out validation stream drawn from a *shifted*
//! distribution of the synthetic language, exercising a distinct
//! generalization axis (documented substitution — DESIGN.md §1 table):
//!
//!   clean-iid      same distribution as training, fresh stream
//!   long-range     longer documents (positional generalization)
//!   rare-context   sequences seeded from rare tokens
//!   noisy-uniform  uniform-noise robustness
//!   noisy-repeat   repetition robustness
//!   noisy-shuffle  order-destroyed robustness
//!   domain-shift   a different Language seed (transfer)
//!   mixed          50/50 blend of clean and shifted
//!
//! Scores are reported as PPL (lower is better), mirroring the relative
//! ordering role Table 1 plays in the paper.

use super::{Corpus, Quality, Split};

/// One probe = a named validation stream generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    CleanIid,
    LongRange,
    RareContext,
    NoisyUniform,
    NoisyRepeat,
    NoisyShuffle,
    DomainShift,
    Mixed,
}

impl Probe {
    pub const ALL: [Probe; 8] = [
        Probe::CleanIid,
        Probe::LongRange,
        Probe::RareContext,
        Probe::NoisyUniform,
        Probe::NoisyRepeat,
        Probe::NoisyShuffle,
        Probe::DomainShift,
        Probe::Mixed,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Probe::CleanIid => "clean-iid",
            Probe::LongRange => "long-range",
            Probe::RareContext => "rare-context",
            Probe::NoisyUniform => "noisy-uniform",
            Probe::NoisyRepeat => "noisy-repeat",
            Probe::NoisyShuffle => "noisy-shuffle",
            Probe::DomainShift => "domain-shift",
            Probe::Mixed => "mixed",
        }
    }

    fn stream(&self) -> u32 {
        Probe::ALL.iter().position(|p| p == self).unwrap() as u32 + 1
    }

    /// Batches for this probe against a training corpus.
    ///
    /// Each probe perturbs the generator, not the model: we build a probe
    /// corpus derived from the training corpus seed and draw `batch`
    /// sequences from a dedicated validation namespace.
    pub fn batch_i32(
        &self,
        train: &Corpus,
        batch: usize,
        seq_plus_1: usize,
        step: u64,
    ) -> Vec<i32> {
        let split = Split::Validation(self.stream());
        match self {
            Probe::CleanIid | Probe::LongRange | Probe::RareContext => {
                // Same language, held-out streams. (LongRange/RareContext
                // differ by namespace; with fixed seq_len the length axis is
                // exercised by the caller choosing larger eval windows.)
                let clean =
                    Corpus::new(train.language.vocab(), train_seed(train), Quality::clean());
                clean.batch_i32(split, 0, step, batch, seq_plus_1)
            }
            Probe::NoisyUniform | Probe::NoisyRepeat | Probe::NoisyShuffle => {
                let noisy = Corpus::new(
                    train.language.vocab(),
                    train_seed(train),
                    Quality { noise_prob: 1.0 },
                );
                noisy.batch_i32(split, 0, step, batch, seq_plus_1)
            }
            Probe::DomainShift => {
                let shifted = Corpus::new(
                    train.language.vocab(),
                    train_seed(train) ^ 0xD0_0D,
                    Quality::clean(),
                );
                shifted.batch_i32(split, 0, step, batch, seq_plus_1)
            }
            Probe::Mixed => {
                let clean =
                    Corpus::new(train.language.vocab(), train_seed(train), Quality::clean());
                let shifted = Corpus::new(
                    train.language.vocab(),
                    train_seed(train) ^ 0xD0_0D,
                    Quality::clean(),
                );
                let half = batch / 2;
                let mut out = clean.batch_i32(split, 0, step, half.max(1), seq_plus_1);
                out.extend(shifted.batch_i32(
                    split,
                    1,
                    step,
                    batch - half.max(1).min(batch),
                    seq_plus_1,
                ));
                out.truncate(batch * seq_plus_1);
                // Pad if the halves under-filled (batch==1 edge case).
                while out.len() < batch * seq_plus_1 {
                    out.push(0);
                }
                out
            }
        }
    }
}

fn train_seed(c: &Corpus) -> u64 {
    // The corpus seed is private; derive a stable probe seed from the
    // language content instead (first successor row is seed-determined).
    c.language.vocab() as u64 ^ 0x50_52_4f_42
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Quality;

    #[test]
    fn all_probes_produce_valid_batches() {
        let train = Corpus::new(512, 42, Quality::clean());
        for probe in Probe::ALL {
            let b = probe.batch_i32(&train, 4, 33, 0);
            assert_eq!(b.len(), 4 * 33, "{}", probe.name());
            assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 512));
        }
    }

    #[test]
    fn probes_deterministic() {
        let train = Corpus::new(512, 42, Quality::clean());
        assert_eq!(
            Probe::DomainShift.batch_i32(&train, 2, 17, 3),
            Probe::DomainShift.batch_i32(&train, 2, 17, 3)
        );
    }

    #[test]
    fn probes_differ_from_each_other() {
        let train = Corpus::new(512, 42, Quality::clean());
        let a = Probe::CleanIid.batch_i32(&train, 2, 33, 0);
        let b = Probe::DomainShift.batch_i32(&train, 2, 33, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn names_unique() {
        let names: std::collections::HashSet<_> =
            Probe::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), Probe::ALL.len());
    }
}
