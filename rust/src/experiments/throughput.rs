//! Throughput harnesses over the analytic cluster simulator:
//! Table 2 (tokens/s + TFLOPS + OOM), Fig. 5 / Table 6 (stragglers,
//! bandwidth), Fig. 9 (sync timelines).

use anyhow::Result;

use crate::coordinator::Method;
use crate::metrics::{CsvWriter, Table};
use crate::simulator::{simulate, Scenario, ScaleSpec, SimConfig};

use super::ExpOpts;

/// Table 2: methods × scales grid on the two-node A100 cluster.
pub fn table2(opts: &ExpOpts) -> Result<()> {
    let methods = Method::ALL;
    let mut header = vec!["scale"];
    header.extend(methods.iter().map(|m| m.name()));
    let mut csv = CsvWriter::create(opts.result_path("table2.csv"), &header)?;
    let mut table = Table::new(&header);
    for scale in ScaleSpec::PAPER {
        let mut row = vec![scale.name.to_string()];
        for &method in &methods {
            let r = simulate(&SimConfig::table2(method, scale));
            row.push(r.cell());
        }
        csv.row(&row)?;
        table.row(row);
    }
    csv.flush()?;
    println!("\nTable 2 — simulated tokens/s / TFLOPS (2×8 A100, τ=5):");
    print!("{}", table.render());
    println!("(cells are tokens-per-sec / per-GPU TFLOPS; OOM = exceeds 34 GB usable)");
    Ok(())
}

/// Fig. 5 + Table 6: TFLOPS under random/consistent stragglers and
/// limited bandwidth (Llama 7B, 8×8 mesh).
pub fn fig5(opts: &ExpOpts) -> Result<()> {
    let methods = [Method::Baseline, Method::Edit, Method::AEdit];
    let mut csv = CsvWriter::create(
        opts.result_path("fig5_table6.csv"),
        &["scenario", "x", "baseline", "edit", "a-edit"],
    )?;

    let lags = [0.0, 1.5, 2.5, 3.5, 4.5];
    let repeats = [0u32, 10, 20, 30, 40];

    for (name, xs) in [("random-straggler", &lags[..]), ("consistent-straggler", &lags[..])] {
        let mut table = Table::new(&["lag (s)", "baseline", "edit", "a-edit"]);
        for &lag in xs {
            let mut row = vec![format!("{lag}")];
            let mut csv_row = vec![name.to_string(), format!("{lag}")];
            for &m in &methods {
                let scenario = if lag == 0.0 {
                    Scenario::Normal
                } else if name.starts_with("random") {
                    Scenario::RandomStraggler { lag }
                } else {
                    Scenario::ConsistentStraggler { lag }
                };
                let tf = simulate(&SimConfig::fig5(m, scenario))
                    .tflops_per_gpu
                    .unwrap_or(f64::NAN);
                row.push(format!("{tf:.2}"));
                csv_row.push(format!("{tf:.2}"));
            }
            csv.row(&csv_row)?;
            table.row(row);
        }
        println!("\nFig. 5 / Table 6 — {name} (TFLOPS, Llama 7B, 8×8):");
        print!("{}", table.render());
    }

    let mut table = Table::new(&["repeat", "baseline", "edit", "a-edit"]);
    for &rep in &repeats {
        let mut row = vec![format!("{rep}")];
        let mut csv_row = vec!["limited-bandwidth".to_string(), format!("{rep}")];
        for &m in &methods {
            let scenario = if rep == 0 {
                Scenario::Normal
            } else {
                Scenario::LimitedBandwidth { repeat: rep }
            };
            let tf = simulate(&SimConfig::fig5(m, scenario))
                .tflops_per_gpu
                .unwrap_or(f64::NAN);
            row.push(format!("{tf:.2}"));
            csv_row.push(format!("{tf:.2}"));
        }
        csv.row(&csv_row)?;
        table.row(row);
    }
    csv.flush()?;
    println!("\nFig. 5 / Table 6 — limited bandwidth (TFLOPS):");
    print!("{}", Table::render(&table));
    Ok(())
}

/// Fig. 9: synchronization-op timelines per method (Llama 1B, 8×8).
pub fn fig9(opts: &ExpOpts) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("fig9_timeline.csv"),
        &["method", "segment", "kind", "start_ms", "dur_ms", "exposed_ms"],
    )?;
    println!("\nFig. 9 — sync-boundary timelines (#=compute ~=overlapped !=exposed $=PCIe):");
    for method in [
        Method::Baseline,
        Method::PostLocalSgd,
        Method::DiLoCo,
        Method::Co2,
        Method::Co2Star,
        Method::Edit,
    ] {
        let tl = crate::simulator::trace::sync_timeline(method);
        print!("{}", tl.render(64));
        for seg in &tl.segments {
            csv.row(&[
                method.name().into(),
                seg.name.clone(),
                format!("{:?}", seg.kind),
                format!("{:.2}", seg.start * 1e3),
                format!("{:.2}", seg.dur * 1e3),
                format!("{:.2}", tl.exposed * 1e3),
            ])?;
        }
    }
    csv.flush()?;
    Ok(())
}

/// Measured (non-simulated) throughput of the real numerics path per
/// method — complements Table 2 with actual PJRT wall-clock on this
/// host plus the simulated cluster time. Writes `table2_measured.csv`.
pub fn measured_throughput(opts: &ExpOpts, methods: &[Method], steps: u64) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("table2_measured.csv"),
        &["method", "host_seconds", "sim_seconds", "tokens", "tokens_per_sim_sec", "pjrt_calls"],
    )?;
    let mut table = Table::new(&["method", "host s", "sim s", "tokens/sim-s"]);
    for &method in methods {
        let mut o = opts.clone();
        o.steps = steps;
        let mut t = o.trainer(method, crate::data::Quality::clean(), 3)?;
        let start = std::time::Instant::now();
        let summary = t.run()?;
        let host = start.elapsed().as_secs_f64();
        csv.row(&[
            method.name().into(),
            format!("{host:.2}"),
            format!("{:.2}", summary.sim_seconds),
            summary.tokens.to_string(),
            format!("{:.1}", summary.throughput),
            t.pjrt_calls().to_string(),
        ])?;
        table.row(vec![
            method.name().into(),
            format!("{host:.2}"),
            format!("{:.2}", summary.sim_seconds),
            format!("{:.1}", summary.throughput),
        ]);
    }
    csv.flush()?;
    println!("\nMeasured numerics-path throughput ({} model, {} steps):", opts.model, steps);
    print!("{}", table.render());
    Ok(())
}
