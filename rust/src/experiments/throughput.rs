//! Throughput harnesses over the analytic cluster simulator:
//! Table 2 (tokens/s + TFLOPS + OOM), Fig. 5 / Table 6 (stragglers,
//! bandwidth), Fig. 9 (sync timelines) — plus the Fig. 5
//! **cross-validation** harness ([`fig5_trainer`]) that re-runs the
//! straggler scenarios through the REAL event-driven trainer and
//! compares the resulting A-EDiT : EDiT speedups against the analytic
//! predictions.

use anyhow::Result;

use crate::collectives::{CostModel, Topology};
use crate::coordinator::{MeshSpec, Method, Straggler, TrainConfig, Trainer};
use crate::data::{Corpus, Quality};
use crate::metrics::{CsvWriter, Table};
use crate::simulator::{simulate, Scenario, ScaleSpec, SimConfig};

use super::ExpOpts;

/// Table 2: methods × scales grid on the two-node A100 cluster.
pub fn table2(opts: &ExpOpts) -> Result<()> {
    let methods = Method::ALL;
    let mut header = vec!["scale"];
    header.extend(methods.iter().map(|m| m.name()));
    let mut csv = CsvWriter::create(opts.result_path("table2.csv"), &header)?;
    let mut table = Table::new(&header);
    for scale in ScaleSpec::PAPER {
        let mut row = vec![scale.name.to_string()];
        for &method in &methods {
            let r = simulate(&SimConfig::table2(method, scale));
            row.push(r.cell());
        }
        csv.row(&row)?;
        table.row(row);
    }
    csv.flush()?;
    println!("\nTable 2 — simulated tokens/s / TFLOPS (2×8 A100, τ=5):");
    print!("{}", table.render());
    println!("(cells are tokens-per-sec / per-GPU TFLOPS; OOM = exceeds 34 GB usable)");
    Ok(())
}

/// Fig. 5 + Table 6: TFLOPS under random/consistent stragglers and
/// limited bandwidth (Llama 7B, 8×8 mesh).
pub fn fig5(opts: &ExpOpts) -> Result<()> {
    let methods = [Method::Baseline, Method::Edit, Method::AEdit];
    let mut csv = CsvWriter::create(
        opts.result_path("fig5_table6.csv"),
        &["scenario", "x", "baseline", "edit", "a-edit"],
    )?;

    let lags = [0.0, 1.5, 2.5, 3.5, 4.5];
    let repeats = [0u32, 10, 20, 30, 40];

    for (name, xs) in [("random-straggler", &lags[..]), ("consistent-straggler", &lags[..])] {
        let mut table = Table::new(&["lag (s)", "baseline", "edit", "a-edit"]);
        for &lag in xs {
            let mut row = vec![format!("{lag}")];
            let mut csv_row = vec![name.to_string(), format!("{lag}")];
            for &m in &methods {
                let scenario = if lag == 0.0 {
                    Scenario::Normal
                } else if name.starts_with("random") {
                    Scenario::RandomStraggler { lag }
                } else {
                    Scenario::ConsistentStraggler { lag }
                };
                let tf = simulate(&SimConfig::fig5(m, scenario))
                    .tflops_per_gpu
                    .unwrap_or(f64::NAN);
                row.push(format!("{tf:.2}"));
                csv_row.push(format!("{tf:.2}"));
            }
            csv.row(&csv_row)?;
            table.row(row);
        }
        println!("\nFig. 5 / Table 6 — {name} (TFLOPS, Llama 7B, 8×8):");
        print!("{}", table.render());
    }

    let mut table = Table::new(&["repeat", "baseline", "edit", "a-edit"]);
    for &rep in &repeats {
        let mut row = vec![format!("{rep}")];
        let mut csv_row = vec!["limited-bandwidth".to_string(), format!("{rep}")];
        for &m in &methods {
            let scenario = if rep == 0 {
                Scenario::Normal
            } else {
                Scenario::LimitedBandwidth { repeat: rep }
            };
            let tf = simulate(&SimConfig::fig5(m, scenario))
                .tflops_per_gpu
                .unwrap_or(f64::NAN);
            row.push(format!("{tf:.2}"));
            csv_row.push(format!("{tf:.2}"));
        }
        csv.row(&csv_row)?;
        table.row(row);
    }
    csv.flush()?;
    println!("\nFig. 5 / Table 6 — limited bandwidth (TFLOPS):");
    print!("{}", Table::render(&table));
    Ok(())
}

/// Fig. 9: synchronization-op timelines per method (Llama 1B, 8×8).
pub fn fig9(opts: &ExpOpts) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("fig9_timeline.csv"),
        &["method", "segment", "kind", "start_ms", "dur_ms", "exposed_ms"],
    )?;
    println!("\nFig. 9 — sync-boundary timelines (#=compute ~=overlapped !=exposed $=PCIe):");
    for method in [
        Method::Baseline,
        Method::PostLocalSgd,
        Method::DiLoCo,
        Method::Co2,
        Method::Co2Star,
        Method::Edit,
    ] {
        let tl = crate::simulator::trace::sync_timeline(method);
        print!("{}", tl.render(64));
        for seg in &tl.segments {
            csv.row(&[
                method.name().into(),
                seg.name.clone(),
                format!("{:?}", seg.kind),
                format!("{:.2}", seg.start * 1e3),
                format!("{:.2}", seg.dur * 1e3),
                format!("{:.2}", tl.exposed * 1e3),
            ])?;
        }
    }
    csv.flush()?;
    Ok(())
}

/// Fig. 5 cross-validation: drive the REAL trainer (the event-driven
/// per-replica execution core) through the straggler scenarios at CPU
/// scale and compare the A-EDiT : EDiT throughput ratios with the
/// analytic cluster simulator's paper-scale predictions for the same
/// relative slowdown (the straggler lag equals one inner-step time, so
/// the victim runs at half speed in both worlds).
///
/// Seconds-scale by construction (a few dozen steps on a tiny model),
/// so `scripts/verify.sh` runs it as the async-path smoke gate. Falls
/// back to a synthetic stub model when AOT artifacts are absent.
/// Writes `fig5_trainer.csv`.
pub fn fig5_trainer(opts: &ExpOpts) -> Result<()> {
    use crate::runtime::{Engine, Manifest};

    let mesh = MeshSpec::new(1, 4);
    let tau = opts.tau.max(2);
    let build = |method: Method, straggler: Straggler| -> Result<Trainer> {
        // Real artifacts when built; otherwise the deterministic stub
        // model (same trick as the steady-state and determinism tests).
        let engine = Engine::load(&opts.artifacts, &opts.model)
            .unwrap_or_else(|_| Engine::synthetic(Manifest::synthetic_fallback("fig5-xval")));
        let corpus =
            Corpus::new(engine.manifest.model.vocab_size, opts.seed, Quality::clean());
        let mut cfg = TrainConfig::paper_default(method, mesh, opts.steps);
        cfg.tau = tau;
        cfg.t_warm = 0;
        cfg.eval_every_syncs = 0;
        cfg.seed = opts.seed;
        cfg.straggler = straggler;
        let mut t = Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100()))?;
        // τ_time worth exactly τ steps for an unlagged worker.
        t.cfg.tau_time = tau as f64 * t.inner_step_seconds();
        Ok(t)
    };
    // Lag ≈ one step time => the victim replica runs at ~half speed.
    // The 1.1 factor keeps the victim's clock incommensurate with the
    // fast group's, so its sync events never land bitwise-equal and
    // accidentally coalesce into a barrier (coalescing is exact-tie
    // only — see `coordinator::engine::clock`). The probe trainer is
    // reused as the "normal"-scenario EDiT run below.
    let mut edit_normal = Some(build(Method::Edit, Straggler::None)?);
    let step_s = edit_normal.as_ref().unwrap().inner_step_seconds();
    let lag = 1.1 * step_s;

    // Analytic predictions at the matched relative slowdown (paper
    // scale: 7B, 8×8; lag = one baseline step).
    let sim_step = simulate(&SimConfig::fig5(Method::Edit, Scenario::Normal))
        .step_seconds
        .unwrap();
    let sim_ratio = |scenario: fn(f64) -> Scenario| -> f64 {
        let sim_lag = 1.1 * sim_step; // same relative slowdown as the trainer
        let e = simulate(&SimConfig::fig5(Method::Edit, scenario(sim_lag)))
            .tokens_per_sec
            .unwrap();
        let a = simulate(&SimConfig::fig5(Method::AEdit, scenario(sim_lag)))
            .tokens_per_sec
            .unwrap();
        a / e
    };

    let mut csv = CsvWriter::create(
        opts.result_path("fig5_trainer.csv"),
        &["scenario", "edit_tput", "aedit_tput", "trainer_ratio", "sim_ratio", "delta_pct"],
    )?;
    let mut table = Table::new(&[
        "scenario",
        "edit tok/s",
        "a-edit tok/s",
        "trainer a/e",
        "sim a/e",
        "delta",
    ]);
    let scenarios: [(&str, Straggler, Option<f64>); 3] = [
        ("normal", Straggler::None, None),
        (
            "consistent-2x",
            Straggler::Consistent { lag, replica: 0 },
            Some(sim_ratio(|l| Scenario::ConsistentStraggler { lag: l })),
        ),
        (
            "random-2x",
            Straggler::Random { lag },
            Some(sim_ratio(|l| Scenario::RandomStraggler { lag: l })),
        ),
    ];
    for (name, straggler, sim_pred) in scenarios {
        let se = match (straggler, edit_normal.take()) {
            (Straggler::None, Some(mut t)) => t.run()?,
            _ => build(Method::Edit, straggler)?.run()?,
        };
        let sa = build(Method::AEdit, straggler)?.run()?;
        let trainer_ratio = sa.throughput / se.throughput;
        let sim_r = sim_pred.unwrap_or(f64::NAN);
        let delta = if sim_r.is_finite() {
            (trainer_ratio / sim_r - 1.0) * 100.0
        } else {
            f64::NAN
        };
        csv.row(&[
            name.to_string(),
            format!("{:.1}", se.throughput),
            format!("{:.1}", sa.throughput),
            format!("{trainer_ratio:.3}"),
            format!("{sim_r:.3}"),
            format!("{delta:.1}"),
        ])?;
        table.row(vec![
            name.into(),
            format!("{:.1}", se.throughput),
            format!("{:.1}", sa.throughput),
            format!("{trainer_ratio:.3}"),
            if sim_r.is_finite() { format!("{sim_r:.3}") } else { "-".into() },
            if delta.is_finite() { format!("{delta:+.1}%") } else { "-".into() },
        ]);
        if name == "consistent-2x" {
            // The paper's headline heterogeneity claim, now exercised by
            // the real trainer rather than only the analytic model.
            anyhow::ensure!(
                trainer_ratio >= 1.5,
                "A-EDiT should be >=1.5x EDiT under a consistent 2x straggler \
                 (got {trainer_ratio:.3})"
            );
        }
    }
    csv.flush()?;
    println!("\nFig. 5 cross-validation — real trainer vs analytic simulator (lag = 1 step):");
    print!("{}", table.render());
    println!("(ratios are A-EDiT/EDiT throughput; delta = trainer vs simulator)");
    Ok(())
}

/// Measured (non-simulated) throughput of the real numerics path per
/// method — complements Table 2 with actual PJRT wall-clock on this
/// host plus the simulated cluster time. Writes `table2_measured.csv`.
pub fn measured_throughput(opts: &ExpOpts, methods: &[Method], steps: u64) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("table2_measured.csv"),
        &["method", "host_seconds", "sim_seconds", "tokens", "tokens_per_sim_sec", "pjrt_calls"],
    )?;
    let mut table = Table::new(&["method", "host s", "sim s", "tokens/sim-s"]);
    for &method in methods {
        let mut o = opts.clone();
        o.steps = steps;
        let mut t = o.trainer(method, crate::data::Quality::clean(), 3)?;
        let start = std::time::Instant::now();
        let summary = t.run()?;
        let host = start.elapsed().as_secs_f64();
        csv.row(&[
            method.name().into(),
            format!("{host:.2}"),
            format!("{:.2}", summary.sim_seconds),
            summary.tokens.to_string(),
            format!("{:.1}", summary.throughput),
            t.pjrt_calls().to_string(),
        ])?;
        table.row(vec![
            method.name().into(),
            format!("{host:.2}"),
            format!("{:.2}", summary.sim_seconds),
            format!("{:.1}", summary.throughput),
        ]);
    }
    csv.flush()?;
    println!("\nMeasured numerics-path throughput ({} model, {} steps):", opts.model, steps);
    print!("{}", table.render());
    Ok(())
}
