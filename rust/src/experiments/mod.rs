//! Experiment harnesses — one per table/figure in the paper's
//! evaluation (DESIGN.md §3 experiment index).  Each harness prints the
//! paper-shaped table and writes CSVs under `results/`.
//!
//! * [`convergence`] — Fig. 4 loss/PPL curves, Table 1 probe evals,
//!   Fig. 7 penalty ablation + per-worker spike traces, Fig. 8 scales,
//!   plus the §4.4 `custom:`-descriptor ablation rows
//!   ([`convergence::ablation_rows`]);
//! * [`throughput`]  — Table 2 tokens/s + TFLOPS + OOM grid, Fig. 5 /
//!   Table 6 straggler & bandwidth scenarios, Fig. 9 sync timelines;
//! * [`scaling`]     — Fig. 6a/b LR-transfer sweep, Fig. 6c elastic runs;
//! * [`chaos`]       — seeded fault schedules + kill/restore bitwise
//!   replay (the `fault_recovery.csv` CI leg).

pub mod chaos;
pub mod convergence;
pub mod scaling;
pub mod throughput;

use crate::collectives::{CostModel, Topology};
use crate::coordinator::{MeshSpec, Method, MethodSpec, TrainConfig, Trainer};
use crate::data::{Corpus, Quality};
use crate::runtime::Engine;

use anyhow::Result;
use std::path::PathBuf;

/// Common options for the training-based experiments.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub artifacts: PathBuf,
    pub results: PathBuf,
    /// Model preset (artifact config name: test/petite/tiny/mini).
    pub model: String,
    pub steps: u64,
    pub mesh: MeshSpec,
    pub tau: u64,
    pub seed: u64,
    pub log: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            results: PathBuf::from("results"),
            model: "test".into(),
            steps: 96,
            mesh: MeshSpec::new(2, 4),
            tau: 8,
            seed: 42,
            log: false,
        }
    }
}

impl ExpOpts {
    pub fn result_path(&self, name: &str) -> PathBuf {
        self.results.join(name)
    }

    /// Build a trainer for a named preset on a corpus of the given
    /// quality.
    pub fn trainer(&self, method: Method, quality: Quality, seed_off: u64) -> Result<Trainer> {
        self.trainer_spec(method.spec(), method.name(), quality, seed_off)
    }

    /// Build a trainer for an arbitrary strategy descriptor (the
    /// `custom:` ablation rows and descriptor-registered methods).
    pub fn trainer_spec(
        &self,
        spec: MethodSpec,
        label: &str,
        quality: Quality,
        seed_off: u64,
    ) -> Result<Trainer> {
        let engine = Engine::load(&self.artifacts, &self.model)?;
        self.trainer_with_engine(engine, spec, label, quality, seed_off)
    }

    /// [`Self::trainer_spec`] substituting the deterministic synthetic
    /// stub model when AOT artifacts are absent — the clean-box trick of
    /// `throughput::fig5_trainer`, for harnesses whose point is the
    /// strategy axes rather than the real model. The substitution is
    /// announced on stderr so stub numbers can't masquerade as the real
    /// model's.
    pub fn trainer_spec_or_synthetic(
        &self,
        spec: MethodSpec,
        label: &str,
        quality: Quality,
        seed_off: u64,
    ) -> Result<Trainer> {
        use crate::runtime::Manifest;
        let engine = match Engine::load(&self.artifacts, &self.model) {
            Ok(e) => e,
            Err(err) => {
                eprintln!(
                    "artifacts unavailable ({err:#}); using the deterministic \
                     synthetic stub model (run `make artifacts` for the real model)"
                );
                Engine::synthetic(Manifest::synthetic_fallback("exp-synthetic"))
            }
        };
        self.trainer_with_engine(engine, spec, label, quality, seed_off)
    }

    fn trainer_with_engine(
        &self,
        engine: Engine,
        spec: MethodSpec,
        label: &str,
        quality: Quality,
        seed_off: u64,
    ) -> Result<Trainer> {
        let corpus = Corpus::new(
            engine.manifest.model.vocab_size,
            self.seed + seed_off,
            quality,
        );
        let mut cfg = TrainConfig::from_spec(spec, label, self.mesh, self.steps);
        cfg.tau = self.tau;
        cfg.tau_time = self.tau as f64 * cfg.base_step_time;
        cfg.t_warm = if spec.warmup {
            (self.steps / 12).max(self.tau.min(8))
        } else {
            0
        };
        cfg.seed = self.seed + seed_off;
        cfg.eval_every_syncs = 2;
        cfg.log_every = if self.log { 1 } else { 0 };
        Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100()))
    }
}
