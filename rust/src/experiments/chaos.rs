//! Chaos harness — the CI leg behind `edit-train chaos` and the bitwise
//! kill/restore acceptance check of `tests/fault_recovery.rs`.
//!
//! For every preset × sharding mode × seed it runs the same seeded
//! fault schedule ([`FaultPlan::random`]: crash+rejoin pairs, never
//! replica 0) twice:
//!
//!  * **run A** — uninterrupted, start to finish;
//!  * **run B** — killed at the midpoint round, checkpointed
//!    ([`Trainer::save_checkpoint`]), restored into a *fresh* trainer
//!    ([`Trainer::restore_checkpoint`]) and run to completion.
//!
//! The two must agree **bitwise**: every replica's params/m/v/clock,
//! the anchor, the loss and validation traces, the simulated clock and
//! the comm ledger ([`state_mismatches`] diffs the public surface), and
//! — the stronger check — the final checkpoint files themselves must be
//! byte-identical, which also covers outer momentum, the anomaly
//! detector and every internal counter. Rows land in
//! `results/fault_recovery.csv`; any mismatch fails the run.

use super::ExpOpts;
use crate::collectives::{CostModel, Topology};
use crate::coordinator::{Method, TrainConfig, Trainer};
use crate::data::{Corpus, Quality};
use crate::fault::FaultPlan;
use crate::metrics::{format_g, CsvWriter};
use crate::runtime::{Engine, Manifest};

use anyhow::Result;

/// The presets the chaos leg exercises: the two EDiT variants plus the
/// PALSGD baseline (a different sync/trigger axis combination).
pub const CHAOS_METHODS: [Method; 3] = [Method::Edit, Method::AEdit, Method::Palsgd];

/// Build a chaos-harness trainer on the deterministic synthetic stub
/// model: preset `method`, ZeRO-1 sharding forced off when `shard` is
/// false (and left at the spec's axis when true), warmup disabled so
/// the fault plan's round keys start at round 0.
pub fn chaos_trainer(
    opts: &ExpOpts,
    method: Method,
    shard: bool,
    seed: u64,
    plan: FaultPlan,
) -> Result<Trainer> {
    let engine = Engine::synthetic(Manifest::synthetic_fallback("chaos"));
    let corpus = Corpus::new(engine.manifest.model.vocab_size, seed, Quality::clean());
    let label = format!("{}{}", method.name(), if shard { "" } else { "+noshard" });
    let mut cfg = TrainConfig::from_spec(method.spec(), label, opts.mesh, opts.steps);
    cfg.tau = opts.tau;
    cfg.tau_time = opts.tau as f64 * cfg.base_step_time;
    cfg.t_warm = 0;
    cfg.seed = seed;
    cfg.eval_every_syncs = 2;
    cfg.shard_outer = cfg.shard_outer && shard;
    cfg.fault_plan = plan;
    Trainer::new(engine, corpus, cfg, CostModel::new(Topology::a100()))
}

fn first_f32_diff(a: &[f32], b: &[f32]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(a.len().min(b.len()));
    }
    (0..a.len()).find(|&i| a[i].to_bits() != b[i].to_bits())
}

fn trace_eq(a: &[(u64, f64)], b: &[(u64, f64)]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.0 == y.0 && x.1.to_bits() == y.1.to_bits())
}

/// Diff the publicly visible trainer state of two runs, bitwise. Empty
/// means indistinguishable; each entry names one divergent field (the
/// diagnostic the CSV's `bitwise_ok=0` rows point at).
pub fn state_mismatches(a: &Trainer, b: &Trainer) -> Vec<String> {
    let mut out = Vec::new();
    if a.global_step != b.global_step {
        out.push(format!("global_step: {} vs {}", a.global_step, b.global_step));
    }
    if a.syncs != b.syncs {
        out.push(format!("syncs: {} vs {}", a.syncs, b.syncs));
    }
    if a.rounds() != b.rounds() {
        out.push(format!("rounds: {} vs {}", a.rounds(), b.rounds()));
    }
    if a.sim_time.to_bits() != b.sim_time.to_bits() {
        out.push(format!("sim_time: {} vs {}", a.sim_time, b.sim_time));
    }
    if let Some(i) = first_f32_diff(&a.anchor, &b.anchor) {
        out.push(format!("anchor diverges at [{i}]"));
    }
    if a.alive() != b.alive() {
        out.push(format!("alive: {:?} vs {:?}", a.alive(), b.alive()));
    }
    if a.pending_updates() != b.pending_updates() {
        out.push(format!(
            "pending updates: {} vs {}",
            a.pending_updates(),
            b.pending_updates()
        ));
    }
    if a.replicas.len() != b.replicas.len() {
        out.push(format!("replica count: {} vs {}", a.replicas.len(), b.replicas.len()));
    } else {
        for (j, (ra, rb)) in a.replicas.iter().zip(&b.replicas).enumerate() {
            if let Some(i) = first_f32_diff(&ra.params, &rb.params) {
                out.push(format!("replica {j} params diverge at [{i}]"));
            }
            if let Some(i) = first_f32_diff(&ra.m, &rb.m) {
                out.push(format!("replica {j} adam m diverges at [{i}]"));
            }
            if let Some(i) = first_f32_diff(&ra.v, &rb.v) {
                out.push(format!("replica {j} adam v diverges at [{i}]"));
            }
            if ra.adam_t != rb.adam_t {
                out.push(format!("replica {j} adam_t: {} vs {}", ra.adam_t, rb.adam_t));
            }
            if ra.clock.to_bits() != rb.clock.to_bits() {
                out.push(format!("replica {j} clock: {} vs {}", ra.clock, rb.clock));
            }
            if ra.inner_steps != rb.inner_steps {
                out.push(format!(
                    "replica {j} inner_steps: {} vs {}",
                    ra.inner_steps, rb.inner_steps
                ));
            }
            if ra.losses.len() != rb.losses.len()
                || ra
                    .losses
                    .iter()
                    .zip(&rb.losses)
                    .any(|(x, y)| x.0 != y.0 || x.1.to_bits() != y.1.to_bits())
            {
                out.push(format!("replica {j} loss window diverges"));
            }
        }
    }
    if !trace_eq(&a.tracker.losses, &b.tracker.losses) {
        out.push("tracker loss trace diverges".into());
    }
    if !trace_eq(&a.tracker.val_ppl, &b.tracker.val_ppl) {
        out.push("tracker val-ppl trace diverges".into());
    }
    if a.comm.ops != b.comm.ops || a.comm.bytes != b.comm.bytes {
        out.push(format!(
            "comm ledger: {} ops / {} B vs {} ops / {} B",
            a.comm.ops, a.comm.bytes, b.comm.ops, b.comm.bytes
        ));
    }
    if a.comm.seconds.to_bits() != b.comm.seconds.to_bits() {
        out.push(format!("comm seconds: {} vs {}", a.comm.seconds, b.comm.seconds));
    }
    let (sa, sb) = (a.summary(), b.summary());
    for (name, x, y) in [
        ("crashes", sa.crashes, sb.crashes),
        ("rejoins", sa.rejoins, sb.rejoins),
        ("evictions", sa.evictions, sb.evictions),
        ("degraded_syncs", sa.degraded_syncs, sb.degraded_syncs),
        ("max_staleness", sa.max_staleness, sb.max_staleness),
        ("flushed_updates", sa.flushed_updates, sb.flushed_updates),
        ("anomalies", sa.anomalies, sb.anomalies),
        ("rollbacks", sa.rollbacks, sb.rollbacks),
    ] {
        if x != y {
            out.push(format!("summary {name}: {x} vs {y}"));
        }
    }
    out
}

/// One kill/restore pair under a given fault plan. Runs A start to
/// finish, runs B to the midpoint of A's round count, checkpoints,
/// restores into a fresh trainer and finishes. Returns the finished
/// pair plus the kill round (for reporting).
pub fn kill_restore_pair(
    opts: &ExpOpts,
    method: Method,
    shard: bool,
    seed: u64,
    plan: &FaultPlan,
    ckpt: &std::path::Path,
) -> Result<(Trainer, Trainer, u64)> {
    let mut ta = chaos_trainer(opts, method, shard, seed, plan.clone())?;
    ta.run()?;
    let kill = (ta.rounds() / 2).max(1);

    let mut tb = chaos_trainer(opts, method, shard, seed, plan.clone())?;
    while tb.rounds() < kill && tb.global_step < tb.cfg.total_steps {
        tb.run_round()?;
    }
    tb.save_checkpoint(ckpt)?;
    // The restore target is a *fresh* trainer: nothing of run B's
    // in-memory state survives except what the checkpoint carries.
    let mut tb2 = chaos_trainer(opts, method, shard, seed, plan.clone())?;
    tb2.restore_checkpoint(ckpt)?;
    tb2.run()?;
    Ok((ta, tb2, kill))
}

/// The `edit-train chaos` entrypoint: `seeds` schedules per preset ×
/// sharding mode, `pairs` crash+rejoin pairs per schedule. Writes
/// `results/fault_recovery.csv` and fails if any pair is not bitwise
/// identical after restore.
pub fn run_chaos(opts: &ExpOpts, seeds: u64, pairs: usize) -> Result<()> {
    let ckpt_dir = opts.results.join("checkpoints");
    let mut csv = CsvWriter::create(
        opts.result_path("fault_recovery.csv"),
        &[
            "method",
            "shard_outer",
            "seed",
            "events",
            "kill_round",
            "crashes",
            "rejoins",
            "evictions",
            "degraded_syncs",
            "max_staleness",
            "final_loss",
            "bitwise_ok",
        ],
    )?;
    let rounds_est = (opts.steps / opts.tau.max(1)).max(3);
    let mut failures = 0usize;
    for method in CHAOS_METHODS {
        for shard in [true, false] {
            for s in 0..seeds {
                let seed = opts.seed + s;
                let plan = FaultPlan::random(seed, opts.mesh.replicas, rounds_est, pairs);
                let tag = format!(
                    "{}-{}-s{}",
                    method.name(),
                    if shard { "shard" } else { "noshard" },
                    seed
                );
                let ckpt = ckpt_dir.join(format!("chaos-{tag}.bin"));
                let (ta, tb, kill) = kill_restore_pair(opts, method, shard, seed, &plan, &ckpt)?;

                let mut diffs = state_mismatches(&ta, &tb);
                // The stronger check: the final checkpoints must be
                // byte-identical too (covers outer momentum, detector
                // state and internal counters the diff can't see).
                let fa = ckpt_dir.join(format!("chaos-{tag}-final-a.bin"));
                let fb = ckpt_dir.join(format!("chaos-{tag}-final-b.bin"));
                ta.save_checkpoint(&fa)?;
                tb.save_checkpoint(&fb)?;
                if std::fs::read(&fa)? != std::fs::read(&fb)? {
                    diffs.push("final checkpoint bytes differ".into());
                }

                let sum = tb.summary();
                let ok = diffs.is_empty();
                failures += usize::from(!ok);
                println!(
                    "chaos {tag}: rounds={} kill={} crashes={} rejoins={} evictions={} \
                     degraded={} loss={} bitwise={}",
                    ta.rounds(),
                    kill,
                    sum.crashes,
                    sum.rejoins,
                    sum.evictions,
                    sum.degraded_syncs,
                    format_g(sum.final_loss),
                    if ok { "ok" } else { "MISMATCH" },
                );
                for d in &diffs {
                    eprintln!("  mismatch: {d}");
                }
                csv.row(&[
                    method.name().to_string(),
                    (shard as u8).to_string(),
                    seed.to_string(),
                    plan.describe().replace(',', ";"),
                    kill.to_string(),
                    sum.crashes.to_string(),
                    sum.rejoins.to_string(),
                    sum.evictions.to_string(),
                    sum.degraded_syncs.to_string(),
                    sum.max_staleness.to_string(),
                    format_g(sum.final_loss),
                    (ok as u8).to_string(),
                ])?;
            }
        }
    }
    csv.flush()?;
    println!("fault recovery -> {}", opts.result_path("fault_recovery.csv").display());
    anyhow::ensure!(
        failures == 0,
        "{failures} kill/restore pair(s) were not bitwise identical after restore"
    );
    Ok(())
}
