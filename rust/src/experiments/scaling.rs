//! Scalability harnesses: Fig. 6a/b (optimal-LR transfer across worker
//! counts) and Fig. 6c (elastic up/down-scaling).

use anyhow::Result;

use crate::coordinator::{LrSchedule, MeshSpec, Method};
use crate::data::Quality;
use crate::elastic;
use crate::metrics::{format_g, CsvWriter, Table};

use super::ExpOpts;

/// Fig. 6a/b: validation PPL against inner LR for several replica
/// counts, Baseline vs EDiT, per-replica batch fixed. The paper's
/// claim: EDiT's optimal LR is invariant in the worker count while the
/// Baseline's optimum shifts. Writes `fig6ab_lr_sweep.csv`.
pub fn fig6ab(
    opts: &ExpOpts,
    lrs: &[f64],
    replica_counts: &[usize],
) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("fig6ab_lr_sweep.csv"),
        &["method", "replicas", "lr", "final_ppl", "final_loss"],
    )?;
    for method in [Method::Baseline, Method::Edit] {
        let mut table_header = vec!["lr \\ replicas".to_string()];
        table_header.extend(replica_counts.iter().map(|r| r.to_string()));
        let mut table =
            Table::new(&table_header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let mut best: Vec<(f64, f64)> = vec![(f64::INFINITY, 0.0); replica_counts.len()];
        for &lr in lrs {
            let mut row = vec![format!("{lr:.1e}")];
            for (ci, &replicas) in replica_counts.iter().enumerate() {
                let mut o = opts.clone();
                o.mesh = MeshSpec::new(opts.mesh.shard, replicas);
                let mut t = o.trainer(method, Quality::clean(), 4)?;
                t.cfg.inner_lr = LrSchedule::Cosine {
                    lr,
                    warmup: (o.steps / 20).max(1),
                    total_steps: o.steps,
                    floor_frac: 0.1,
                };
                let summary = t.run()?;
                csv.row(&[
                    method.name().into(),
                    replicas.to_string(),
                    format!("{lr:.1e}"),
                    format_g(summary.final_ppl),
                    format_g(summary.final_loss),
                ])?;
                if summary.final_ppl < best[ci].0 {
                    best[ci] = (summary.final_ppl, lr);
                }
                row.push(format_g(summary.final_ppl));
            }
            table.row(row);
        }
        let mut best_row = vec!["best lr".to_string()];
        best_row.extend(best.iter().map(|(_, lr)| format!("{lr:.1e}")));
        table.row(best_row);
        println!("\nFig. 6a/b — {} PPL vs LR per replica count:", method.name());
        print!("{}", table.render());
    }
    csv.flush()?;
    Ok(())
}

/// Fig. 6c: elastic scaling schedules (up 1→2→4→8, down 8→4→2→1) with a
/// fixed LR, Baseline vs EDiT. Writes `fig6c_elastic.csv`.
pub fn fig6c(opts: &ExpOpts, steps_per_phase: u64, lr: f64) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("fig6c_elastic.csv"),
        &["method", "direction", "global_step", "replicas", "val_ppl"],
    )?;
    let mut table = Table::new(&["method", "direction", "final PPL"]);
    for method in [Method::Baseline, Method::Edit] {
        for up in [true, false] {
            let mut o = opts.clone();
            o.steps = u64::MAX; // phases drive the length
            let mut t = o.trainer(method, Quality::clean(), 5)?;
            t.cfg.inner_lr = LrSchedule::Constant { lr };
            t.cfg.total_steps = 0;
            // ExpOpts::trainer derives t_warm from steps (u64::MAX here);
            // pin it so EDiT actually leaves the DDP warmup phase.
            t.cfg.t_warm = if t.cfg.spec.warmup { 8 } else { 0 };
            let phases = elastic::paper_schedule(up, steps_per_phase);
            let points = elastic::run_schedule(&mut t, &phases)?;
            let dir = if up { "up" } else { "down" };
            for p in &points {
                csv.row(&[
                    method.name().into(),
                    dir.into(),
                    p.global_step.to_string(),
                    p.replicas.to_string(),
                    format_g(p.val_ppl),
                ])?;
            }
            table.row(vec![
                method.name().into(),
                dir.into(),
                format_g(points.last().map(|p| p.val_ppl).unwrap_or(f64::NAN)),
            ]);
        }
    }
    csv.flush()?;
    println!("\nFig. 6c — elastic schedules (fixed lr {lr:.1e}):");
    print!("{}", table.render());
    Ok(())
}
