//! Convergence & generalization harnesses: Fig. 4, Table 1, Fig. 7,
//! Fig. 8 (+ Table 5 probe grid for the scale sweep).

use anyhow::Result;

use crate::coordinator::Method;
use crate::data::Quality;
use crate::metrics::{format_g, CsvWriter, Table};

use super::ExpOpts;

/// Fig. 4: loss + validation-PPL curves for every method on a clean
/// (FineWeb-Edu analog) or noisy (in-house analog) corpus.  Writes
/// `fig4_<tag>_curves.csv` (method, step, loss, val_ppl) and prints the
/// final-value table the figure annotates.
pub fn fig4(opts: &ExpOpts, methods: &[Method], noisy: bool) -> Result<Vec<(Method, f64, f64)>> {
    let tag = if noisy { "noisy" } else { "clean" };
    let quality = if noisy { Quality::noisy() } else { Quality::clean() };
    let mut curves = CsvWriter::create(
        opts.result_path(&format!("fig4_{tag}_curves.csv")),
        &["method", "step", "train_loss", "val_ppl"],
    )?;
    let mut finals = Vec::new();
    let mut table =
        Table::new(&["method", "final loss", "final PPL", "syncs", "anomalies", "rollbacks"]);

    for &method in methods {
        let mut t = opts.trainer(method, quality, 0)?;
        let summary = t.run()?;
        // Merge loss and val curves on step index.
        let mut val_iter = t.tracker.val_ppl.iter().peekable();
        for &(step, loss) in &t.tracker.losses {
            let val = match val_iter.peek() {
                Some(&&(vs, vp)) if vs <= step => {
                    val_iter.next();
                    vp
                }
                _ => f64::NAN,
            };
            curves.row(&[
                method.name().into(),
                step.to_string(),
                format_g(loss),
                if val.is_nan() { String::new() } else { format_g(val) },
            ])?;
        }
        table.row(vec![
            method.name().into(),
            format_g(summary.final_loss),
            format_g(summary.final_ppl),
            summary.syncs.to_string(),
            summary.anomalies.to_string(),
            summary.rollbacks.to_string(),
        ]);
        finals.push((method, summary.final_loss, summary.final_ppl));
    }
    curves.flush()?;
    println!("\nFig. 4 ({tag} corpus) — final values (mean of last 10):");
    print!("{}", table.render());
    Ok(finals)
}

/// Table 1: probe-stream PPLs per method (the offline substitute for
/// the public benchmarks). Writes `table1_<tag>.csv`.
pub fn table1(opts: &ExpOpts, methods: &[Method], noisy: bool) -> Result<()> {
    let tag = if noisy { "noisy" } else { "clean" };
    let quality = if noisy { Quality::noisy() } else { Quality::clean() };
    let probe_names: Vec<&str> =
        crate::data::probe::Probe::ALL.iter().map(|p| p.name()).collect();
    let mut header = vec!["probe"];
    let method_names: Vec<String> = methods.iter().map(|m| m.name().to_string()).collect();
    header.extend(method_names.iter().map(|s| s.as_str()));
    let mut csv = CsvWriter::create(
        opts.result_path(&format!("table1_{tag}.csv")),
        &header,
    )?;
    let mut grid: Vec<Vec<f64>> = vec![Vec::new(); probe_names.len()];
    for &method in methods {
        let mut t = opts.trainer(method, quality, 0)?;
        t.run()?;
        for (i, (_, ppl)) in t.probe_ppls()?.into_iter().enumerate() {
            grid[i].push(ppl);
        }
    }
    let mut table = Table::new(&header);
    for (i, name) in probe_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(grid[i].iter().map(|&p| format_g(p)));
        csv.row(&row)?;
        table.row(row);
    }
    // Average row (paper Table 1 bottom line), PPL: lower is better.
    let mut avg_row = vec!["average (PPL ↓)".to_string()];
    for j in 0..methods.len() {
        let avg: f64 =
            grid.iter().map(|r| r[j]).sum::<f64>() / probe_names.len() as f64;
        avg_row.push(format_g(avg));
    }
    csv.row(&avg_row)?;
    table.row(avg_row);
    csv.flush()?;
    println!("\nTable 1 ({tag}) — probe PPLs (benchmark substitute):");
    print!("{}", table.render());
    Ok(())
}

/// Fig. 7a: penalty ablation on the noisy corpus; Fig. 7b/c: per-worker
/// loss traces for DiLoCo vs EDiT. Writes `fig7a_ablation.csv` and
/// `fig7bc_worker_losses.csv`.
pub fn fig7(opts: &ExpOpts) -> Result<()> {
    let variants: [(&str, &str); 5] = [
        ("edit", ""),
        ("w/o AE", "ae"),
        ("w/o WA", "wa"),
        ("w/o GC", "gc"),
        ("w/o ALL", "all"),
    ];
    let mut csv = CsvWriter::create(
        opts.result_path("fig7a_ablation.csv"),
        &["variant", "step", "train_loss", "val_ppl"],
    )?;
    let mut table = Table::new(&["variant", "final PPL", "anomalies", "rollbacks", "loss spikes"]);
    // Noisy corpus + fault injection: replica 1's state drifts for two
    // sync rounds (Fig. 7b scenario), then EVERY replica drifts for one
    // round (the all-anomalous rollback path, Fig. 7c). At 96-step
    // scale this produces the per-worker divergence the paper sees
    // organically over 150k steps on the in-house corpus, so every
    // penalty stage has work to do. Fault injection is harness-side
    // (DESIGN.md §6), not a change to the algorithm. φ is rescaled to
    // this model's pseudo-gradient-norm magnitude (paper's φ=10 is
    // calibrated to billion-parameter norms).
    let ablation_quality = Quality { noise_prob: 0.05 };
    let poison = vec![
        crate::coordinator::Poison { replica: 1, from_sync: 5, to_sync: 7, strength: 1e-2 },
        crate::coordinator::Poison {
            replica: usize::MAX,
            from_sync: 9,
            to_sync: 10,
            strength: 1e-2,
        },
    ];
    for (name, stage) in variants {
        let mut t = opts.trainer(Method::Edit, ablation_quality, 1)?;
        t.cfg.spec.penalty.warmup_syncs = 3;
        // The paper's α=0.02 tracks norm drift at τ=128 over 100k steps;
        // our compressed runs see ~25% norm decay PER SYNC, so the EMA
        // needs a faster time constant to play the same role.
        t.cfg.spec.penalty.alpha = 0.3;
        t.cfg.spec.penalty.phi = 0.3;
        t.cfg.poison = poison.clone();
        if !stage.is_empty() {
            t.cfg.spec.penalty = t.cfg.spec.penalty.without(stage);
        }
        let summary = t.run()?;
        let mut val_iter = t.tracker.val_ppl.iter().peekable();
        for &(step, loss) in &t.tracker.losses {
            let val = match val_iter.peek() {
                Some(&&(vs, vp)) if vs <= step => {
                    val_iter.next();
                    vp
                }
                _ => f64::NAN,
            };
            csv.row(&[
                name.into(),
                step.to_string(),
                format_g(loss),
                if val.is_nan() { String::new() } else { format_g(val) },
            ])?;
        }
        // Spikes counted on per-replica traces (round means smooth them).
        let spikes: usize = t
            .replicas
            .iter()
            .map(|r| {
                count_spikes(
                    &r.losses.iter().map(|&(s, l)| (s, l as f64)).collect::<Vec<_>>(),
                )
            })
            .sum();
        table.row(vec![
            name.into(),
            format_g(summary.final_ppl),
            summary.anomalies.to_string(),
            summary.rollbacks.to_string(),
            spikes.to_string(),
        ]);
    }
    csv.flush()?;
    println!("\nFig. 7a — pseudo-gradient-penalty ablation (noisy corpus):");
    print!("{}", table.render());

    // 7b/c: per-replica loss traces.
    let mut csv = CsvWriter::create(
        opts.result_path("fig7bc_worker_losses.csv"),
        &["method", "worker", "step", "loss"],
    )?;
    for method in [Method::DiLoCo, Method::Edit] {
        let mut t = opts.trainer(method, ablation_quality, 1)?;
        t.cfg.spec.penalty.warmup_syncs = 3;
        t.cfg.spec.penalty.alpha = 0.3;
        t.cfg.spec.penalty.phi = 0.3;
        t.cfg.poison = poison.clone();
        t.run()?;
        for (w, r) in t.replicas.iter().enumerate() {
            for &(step, loss) in &r.losses {
                csv.row(&[
                    method.name().into(),
                    w.to_string(),
                    step.to_string(),
                    format_g(loss as f64),
                ])?;
            }
        }
    }
    csv.flush()?;
    println!("per-worker traces -> fig7bc_worker_losses.csv");
    Ok(())
}

/// §4.4 ablation rows as first-class `custom:` descriptor runs: each
/// row is one `--method custom:...` string, trained end-to-end through
/// the REAL trainer at CPU scale AND priced by the analytic cluster
/// simulator at paper scale (Table-2 setting, 1B) — the two worlds the
/// acceptance criteria pair. Writes `table4_ablation_rows.csv`.
pub fn ablation_rows(opts: &ExpOpts) -> Result<()> {
    use crate::coordinator::MethodSpec;
    use crate::simulator::{simulate, ScaleSpec, SimConfig};

    let rows: [(&str, &str); 7] = [
        ("edit (full)", "custom:base=edit"),
        ("w/o penalty", "custom:base=edit,penalty=off"),
        ("w/o layer-wise sync", "custom:base=edit,sync=flat"),
        ("w/o warmup", "custom:base=edit,warmup=off"),
        ("probabilistic sync", "custom:base=edit,trigger=prob:0.5"),
        ("int8 payload", "custom:base=edit,payload=int8"),
        ("1-bit payload", "custom:base=edit,payload=bit1"),
    ];
    let mut csv = CsvWriter::create(
        opts.result_path("table4_ablation_rows.csv"),
        &[
            "row",
            "descriptor",
            "final_loss",
            "final_ppl",
            "syncs",
            "sim_tflops_1b",
            "sim_tokens_per_sec_1b",
        ],
    )?;
    let mut table = Table::new(&[
        "row",
        "descriptor",
        "final loss",
        "final PPL",
        "syncs",
        "sim TFLOPS@1B",
    ]);
    let scale = ScaleSpec::by_name("1B").unwrap();
    for (row, descriptor) in rows {
        let (spec, label) =
            MethodSpec::parse(descriptor).map_err(|e| anyhow::anyhow!(e))?;
        // Real trainer at CPU scale (synthetic stub when artifacts are
        // absent, so the ablation table runs on a clean box).
        let mut t = opts.trainer_spec_or_synthetic(spec, &label, Quality::clean(), 7)?;
        let summary = t.run()?;
        // Analytic simulator at paper scale, same descriptor.
        let sim = simulate(&SimConfig::table2_spec(spec, label.as_str(), scale));
        let tflops = sim.tflops_per_gpu.unwrap_or(f64::NAN);
        let tput = sim.tokens_per_sec.unwrap_or(f64::NAN);
        // CsvWriter does no quoting, so the comma-separated descriptor
        // is written with ';' axis separators to keep the row rectangular.
        csv.row(&[
            row.into(),
            label.replace(',', ";"),
            format_g(summary.final_loss),
            format_g(summary.final_ppl),
            summary.syncs.to_string(),
            format!("{tflops:.1}"),
            format!("{tput:.3e}"),
        ])?;
        table.row(vec![
            row.into(),
            label,
            format_g(summary.final_loss),
            format_g(summary.final_ppl),
            summary.syncs.to_string(),
            if sim.oom { "OOM".into() } else { format!("{tflops:.1}") },
        ]);
    }
    csv.flush()?;
    println!("\n§4.4 ablation rows — real trainer (CPU scale) + analytic simulator (1B):");
    print!("{}", table.render());
    Ok(())
}

/// Loss spikes: count steps where loss jumps >10% above the running min.
pub fn count_spikes(losses: &[(u64, f64)]) -> usize {
    let mut run_min = f64::INFINITY;
    let mut spikes = 0;
    for &(_, l) in losses {
        if l > run_min * 1.10 {
            spikes += 1;
        }
        run_min = run_min.min(l);
    }
    spikes
}

/// Fig. 8 / Table 5: EDiT across model scales (the CPU-trainable
/// presets substitute for 350M–7B). Writes `fig8_scales.csv`.
pub fn fig8(opts: &ExpOpts, models: &[&str]) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.result_path("fig8_scales.csv"),
        &["model", "params", "step", "train_loss", "val_ppl"],
    )?;
    let mut table = Table::new(&["model", "params", "final loss", "final PPL"]);
    for &model in models {
        let mut o = opts.clone();
        o.model = model.to_string();
        let mut t = o.trainer(Method::Edit, Quality::clean(), 2)?;
        let params = t.num_params();
        let summary = t.run()?;
        let mut val_iter = t.tracker.val_ppl.iter().peekable();
        for &(step, loss) in &t.tracker.losses {
            let val = match val_iter.peek() {
                Some(&&(vs, vp)) if vs <= step => {
                    val_iter.next();
                    vp
                }
                _ => f64::NAN,
            };
            csv.row(&[
                model.into(),
                params.to_string(),
                step.to_string(),
                format_g(loss),
                if val.is_nan() { String::new() } else { format_g(val) },
            ])?;
        }
        table.row(vec![
            model.into(),
            params.to_string(),
            format_g(summary.final_loss),
            format_g(summary.final_ppl),
        ]);
    }
    csv.flush()?;
    println!("\nFig. 8 — EDiT across model scales:");
    print!("{}", table.render());
    Ok(())
}
