//! `edit-train` — the launcher / leader entrypoint.
//!
//! Subcommands:
//!   train      one training run (method/model/mesh/steps configurable,
//!              optionally from a TOML config in configs/)
//!   sweep      convergence experiments: --exp fig4|table1|fig8
//!   simulate   cluster simulator: --exp table2|fig5|fig9|measured
//!   ablation   Fig. 7 pseudo-gradient-penalty ablation
//!   elastic    Fig. 6c elastic schedules; lr-sweep = Fig. 6a/b
//!   rendezvous multi-process hub: rank assignment + socket collectives
//!   worker     one EDiT driver rank: --join a hub, or --local N threads
//!   probe      evaluate a trained run's probe PPLs (Table 1 style)
//!   info       print artifact manifest / platform info
//!
//! `--set section.key=value,...` overrides any config key; every
//! experiment writes CSVs under --results (default results/).

use anyhow::Result;

use edit_train::collectives::{CostModel, Topology};
use edit_train::coordinator::{
    LrSchedule, MeshSpec, Method, MethodSpec, Straggler, TrainConfig, Trainer,
};
use edit_train::data::{Corpus, Quality};
use edit_train::experiments::{chaos, convergence, scaling, throughput, ExpOpts};
use edit_train::fault::FaultPlan;
use edit_train::metrics::format_g;
use edit_train::runtime::{Engine, Manifest};
use edit_train::util::cfg::{Config, Value};
use edit_train::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> &'static str {
    "usage: edit-train <train|sweep|simulate|ablation|elastic|chaos|rendezvous|worker|probe|info> [options]
  common: --artifacts DIR --results DIR --model test|petite|tiny|mini
          --mesh MxN --steps N --tau N --seed N --config FILE --set k=v,...
  train:    --method baseline|pls|diloco|co2|co2*|edit|a-edit|palsgd
            or --method custom:base=edit,penalty=off,sync=flat,... (the
            MethodSpec grammar; axes also settable via train.* config
            keys: sync/trigger/penalty/outer/staleness/shard/warmup/
            payload — payload=f32|int8|bit1 compresses the sync wire)
            --lr X --noise P --straggler none|random:LAG|consistent:LAG[:REPLICA]
            --threads N --timeline FILE.csv --out curves.csv --log
            --no-shard-outer (disable ZeRO-1 outer-state sharding)
            --fault-plan 'crash@R:N[+S],hang@R:N:SECS,join@R:N,random:PAIRS[:ROUNDS]'
            --evict-timeout SECS --checkpoint-every ROUNDS --checkpoint-dir DIR
            --restore FILE.bin (resume from a checkpoint before training)
  sweep:    --exp fig4|table1|fig8|ablations [--noisy] [--methods a,b,c]
  simulate: --exp table2|fig5|fig5-trainer|fig9|measured
  ablation: (fig7)
  elastic:  --exp fig6ab|fig6c --phase-steps N --lr X
  chaos:    --seeds N --pairs N (seeded fault schedules; kill/restore
            bitwise replay -> results/fault_recovery.csv)
  rendezvous: --bind ADDR --world N [--op-timeout-ms MS --hb-timeout-ms MS
            --join-timeout-ms MS] (hub for the socket backend; prints the
            bound address, serves N workers, prints a membership report)
  worker:   --join ADDR (connect a rank to a rendezvous hub) or --local N
            (reference run on N in-process threads); --params N --rounds N
            --inner-steps N --seed N --payload f32|int8 --modules N
            --overlap (nonblocking layer-wise schedule, bitwise equal to
            blocking) — both paths print digest=0x... lines that must
            match bitwise at equal configs
            --net-plan 'netdrop@R:N,netdelay@R:N:MS,partition@R:NS:SECS,
            random:PAIRS:net' (wire-level chaos; digests must still match
            a clean run) --checkpoint-every ROUNDS --checkpoint-dir DIR
            --restore FILE.bin ({rank} in FILE expands to the assigned
            rank; rejoins a fresh hub and replays bitwise)
  info:     [--model NAME]"
}

fn opts_from(args: &Args, cfg: &Config) -> ExpOpts {
    let mesh = parse_mesh(&args.str("mesh", &cfg.str("mesh.shape", "2x4")));
    ExpOpts {
        artifacts: args.str("artifacts", "artifacts").into(),
        results: args.str("results", "results").into(),
        model: args.str("model", &cfg.str("model.name", "test")),
        steps: args.u64("steps", cfg.i64("train.steps", 96) as u64),
        mesh,
        tau: args.u64("tau", cfg.i64("train.tau", 8) as u64),
        seed: args.u64("seed", cfg.i64("train.seed", 42) as u64),
        log: args.flag("log"),
    }
}

fn parse_mesh(s: &str) -> MeshSpec {
    let (m, n) = s.split_once(['x', 'X']).unwrap_or(("2", "4"));
    MeshSpec::new(m.trim().parse().unwrap_or(2), n.trim().parse().unwrap_or(4))
}

fn parse_methods(args: &Args) -> Vec<Method> {
    match args.opt("methods") {
        None => Method::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .filter_map(|s| Method::parse(s.trim()))
            .collect(),
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => {
            Config::load(std::path::Path::new(path)).map_err(|e| anyhow::anyhow!(e))?
        }
        None => Config::parse("").unwrap(),
    };
    for (k, v) in args.set_overrides() {
        // Accept bare strings for convenience: try raw, then quoted.
        if cfg.set(&k, &v).is_err() {
            cfg.set(&k, &format!("\"{v}\"")).map_err(|e| anyhow::anyhow!(e))?;
        }
    }
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = opts_from(args, &cfg);
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args, &cfg, &opts),
        Some("sweep") => cmd_sweep(args, &opts),
        Some("simulate") => cmd_simulate(args, &opts),
        Some("ablation") => convergence::fig7(&opts),
        Some("elastic") => cmd_elastic(args, &cfg, &opts),
        Some("chaos") => chaos::run_chaos(&opts, args.u64("seeds", 2), args.usize("pairs", 2)),
        Some("rendezvous") => cmd_rendezvous(args),
        Some("worker") => cmd_worker(args),
        Some("probe") => cmd_probe(args, &opts),
        Some("info") => cmd_info(&opts),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Apply `train.*` strategy-axis config keys (sync/trigger/penalty/
/// outer/staleness/shard/warmup/payload) over a parsed spec, then re-normalize
/// and validate — the config-file twin of the `custom:` grammar.
/// Returns the applied `key=value` pairs so the caller can fold them
/// into the run label (the label must describe what actually runs).
fn apply_spec_cfg(spec: &mut MethodSpec, cfg: &Config) -> Result<Vec<String>> {
    let mut applied = Vec::new();
    for key in [
        "sync", "trigger", "penalty", "outer", "staleness", "shard", "warmup", "payload",
    ] {
        let Some(v) = cfg.get(&format!("train.{key}")) else {
            continue;
        };
        let value = match v {
            Value::Str(s) => s.clone(),
            Value::Int(i) => i.to_string(),
            Value::Float(f) => f.to_string(),
            Value::Bool(true) => "on".to_string(),
            Value::Bool(false) => "off".to_string(),
            Value::Arr(_) => {
                anyhow::bail!("train.{key}: expected a scalar value, got an array")
            }
        };
        spec.set_axis(key, &value)
            .map_err(|e| anyhow::anyhow!("train.{key}: {e}"))?;
        applied.push(format!("{key}={value}"));
    }
    // Same contract as the custom: grammar: an explicitly requested
    // penalty must not be silently normalized away by flat sync.
    let explicit_penalty = applied.iter().any(|a| a.starts_with("penalty="));
    if explicit_penalty && !spec.layerwise() && spec.uses_penalty() {
        anyhow::bail!(
            "train.penalty conflicts with sync=flat (penalty stages need \
             per-module statistics); drop train.penalty or use sync=layer"
        );
    }
    spec.normalize();
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(applied)
}

fn cmd_train(args: &Args, cfg: &Config, opts: &ExpOpts) -> Result<()> {
    // `--method` accepts every named preset plus the custom: grammar;
    // parse errors list the valid names and the grammar.
    let raw_method = args.str("method", &cfg.str("train.method", "edit"));
    let (mut spec, mut label) =
        MethodSpec::parse(&raw_method).map_err(|e| anyhow::anyhow!(e))?;
    let overrides = apply_spec_cfg(&mut spec, cfg)?;
    if !overrides.is_empty() {
        // The label must name what actually runs, not just what
        // --method said (train.* keys may have changed the axes).
        label = format!("{label}+{}", overrides.join("+"));
    }
    let noise = args.f64("noise", cfg.f64("data.noise", 0.0));
    // Without AOT artifacts (`make artifacts`), train on the
    // deterministic synthetic stub model instead of erroring — loudly,
    // so nobody mistakes a stub run for the real model.
    let engine = match Engine::load(&opts.artifacts, &opts.model) {
        Ok(e) => e,
        Err(err) => {
            eprintln!(
                "artifacts unavailable ({err:#}); training the deterministic \
                 synthetic stub model (run `make artifacts` for the real model)"
            );
            Engine::synthetic(Manifest::synthetic_fallback("train-synthetic"))
        }
    };
    let corpus = Corpus::new(
        engine.manifest.model.vocab_size,
        opts.seed,
        Quality { noise_prob: noise },
    );
    let mut tc = TrainConfig::from_spec(spec, label.clone(), opts.mesh, opts.steps);
    tc.tau = opts.tau;
    tc.tau_time = cfg.f64("train.tau_time", opts.tau as f64 * tc.base_step_time);
    tc.seed = opts.seed;
    tc.t_warm = args.u64("t-warm", cfg.i64("train.t_warm", tc.t_warm as i64) as u64);
    tc.log_every = if args.flag("log") { 1 } else { 0 };
    if let Some(lr) = args.opt("lr") {
        tc.inner_lr = LrSchedule::paper_cosine(lr.parse()?, opts.steps);
    }
    tc.worker_threads = args.usize("threads", 1).max(1);
    tc.trace_timeline = args.opt("timeline").is_some();
    // Runtime ZeRO-1 toggle: defaults to the spec's sharding axis
    // (layer-wise presets on, `custom:...,shard=off` off); the flag and
    // `train.shard_outer = 0` force the full-matrix reference path
    // (bitwise identical numerics either way).
    tc.shard_outer =
        tc.shard_outer && !args.flag("no-shard-outer") && cfg.i64("train.shard_outer", 1) != 0;
    tc.straggler = match args.str("straggler", "none").split_once(':') {
        Some(("random", lag)) => Straggler::Random { lag: lag.parse()? },
        Some(("consistent", rest)) => {
            // consistent:LAG or consistent:LAG:REPLICA
            let (lag, replica) = match rest.split_once(':') {
                Some((l, r)) => (l.parse()?, r.parse()?),
                None => (rest.parse()?, 0),
            };
            Straggler::Consistent { lag, replica }
        }
        _ => Straggler::None,
    };
    // Fault-tolerance surface: a deterministic fault schedule, the
    // barrier evict grace period, and round-boundary checkpointing.
    if let Some(spec) = args.opt("fault-plan") {
        tc.fault_plan = FaultPlan::parse(spec, opts.seed, opts.mesh.replicas)
            .map_err(|e| anyhow::anyhow!("--fault-plan: {e}"))?;
    }
    tc.evict_timeout = args.f64("evict-timeout", tc.evict_timeout);
    tc.checkpoint_every = args.u64("checkpoint-every", 0);
    // backend=socket is rejected by Trainer::new with a pointer to the
    // `rendezvous`/`worker` subcommands; parsing it here keeps the
    // config surface honest (`train.backend` / `--backend`).
    let backend = args.str("backend", &cfg.str("train.backend", "thread"));
    tc.backend = edit_train::collectives::CommBackend::parse(&backend)
        .ok_or_else(|| anyhow::anyhow!("--backend: expected thread|socket, got '{backend}'"))?;
    tc.checkpoint_dir = args
        .opt("checkpoint-dir")
        .map(std::path::PathBuf::from)
        .or_else(|| (tc.checkpoint_every > 0).then(|| opts.results.join("checkpoints")));

    println!(
        "training: method={} model={} mesh={}x{} steps={} tau={} params={}",
        label,
        opts.model,
        opts.mesh.shard,
        opts.mesh.replicas,
        opts.steps,
        opts.tau,
        engine.manifest.total_params,
    );
    let mut trainer =
        Trainer::new(engine, corpus, tc, CostModel::new(Topology::a100()))?;
    if let Some(path) = args.opt("restore") {
        trainer.restore_checkpoint(std::path::Path::new(path))?;
        println!(
            "restored {path} (round {}, step {})",
            trainer.rounds(),
            trainer.global_step
        );
    }
    let start = std::time::Instant::now();
    let summary = trainer.run()?;
    let host = start.elapsed().as_secs_f64();

    println!(
        "done: final_loss={} final_ppl={} syncs={} anomalies={} rollbacks={} max_staleness={}",
        format_g(summary.final_loss),
        format_g(summary.final_ppl),
        summary.syncs,
        summary.anomalies,
        summary.rollbacks,
        summary.max_staleness,
    );
    if summary.crashes + summary.rejoins + summary.evictions > 0 {
        println!(
            "faults: crashes={} rejoins={} evictions={} degraded_syncs={}",
            summary.crashes, summary.rejoins, summary.evictions, summary.degraded_syncs,
        );
    }
    println!(
        "time: host={host:.1}s simulated={:.1}s tokens={} throughput={} tok/sim-s comm={} MB",
        summary.sim_seconds,
        summary.tokens,
        format_g(summary.throughput),
        summary.comm.bytes / (1 << 20),
    );

    if let Some(out) = args
        .opt("out")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
    {
        let mut w = edit_train::metrics::CsvWriter::create(
            opts.results.join(&out),
            &["step", "train_loss"],
        )?;
        for &(step, loss) in &trainer.tracker.losses {
            w.row(&[step.to_string(), format_g(loss)])?;
        }
        w.flush()?;
        println!("curves -> {}", opts.results.join(&out).display());
    }
    if let Some(path) = args.opt("timeline") {
        let dest = opts.results.join(path);
        trainer.timeline.write_csv(&dest)?;
        println!(
            "timeline -> {} ({} sync events)",
            dest.display(),
            trainer.timeline.events.len()
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args, opts: &ExpOpts) -> Result<()> {
    let methods = parse_methods(args);
    match args.str("exp", "fig4").as_str() {
        "fig4" => {
            convergence::fig4(opts, &methods, args.flag("noisy"))?;
        }
        "table1" => convergence::table1(opts, &methods, args.flag("noisy"))?,
        "fig8" => {
            let models: Vec<String> = args
                .str("models", "test,tiny")
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            let refs: Vec<&str> = models.iter().map(String::as_str).collect();
            convergence::fig8(opts, &refs)?;
        }
        "ablations" => convergence::ablation_rows(opts)?,
        other => anyhow::bail!("unknown sweep exp '{other}'"),
    }
    Ok(())
}

fn cmd_simulate(args: &Args, opts: &ExpOpts) -> Result<()> {
    match args.str("exp", "table2").as_str() {
        "table2" => throughput::table2(opts),
        "fig5" => throughput::fig5(opts),
        "fig5-trainer" => throughput::fig5_trainer(opts),
        "fig9" => throughput::fig9(opts),
        "measured" => throughput::measured_throughput(
            opts,
            &parse_methods(args),
            args.u64("steps", 16),
        ),
        other => anyhow::bail!("unknown simulate exp '{other}'"),
    }
}

fn cmd_elastic(args: &Args, cfg: &Config, opts: &ExpOpts) -> Result<()> {
    match args.str("exp", "fig6c").as_str() {
        "fig6ab" => {
            let lrs: Vec<f64> = args
                .str("lrs", "1e-3,2e-3,4e-3,8e-3,1.6e-2")
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            let counts: Vec<usize> = args
                .str("replicas", "1,2,4")
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            scaling::fig6ab(opts, &lrs, &counts)
        }
        "fig6c" => scaling::fig6c(
            opts,
            args.u64("phase-steps", cfg.i64("elastic.phase_steps", 24) as u64),
            args.f64("lr", cfg.f64("elastic.lr", 2e-3)),
        ),
        other => anyhow::bail!("unknown elastic exp '{other}'"),
    }
}

/// Hub for the multi-process socket backend: binds, prints the chosen
/// address (port 0 OK — scripts parse the printed line), serves `world`
/// workers through their collective rounds, then reports membership.
fn cmd_rendezvous(args: &Args) -> Result<()> {
    use edit_train::collectives::{Rendezvous, RendezvousConfig};
    use std::time::Duration;
    let d = RendezvousConfig::default();
    let rcfg = RendezvousConfig {
        world: args.usize("world", d.world),
        op_timeout: Duration::from_millis(
            args.u64("op-timeout-ms", d.op_timeout.as_millis() as u64),
        ),
        heartbeat_timeout: Duration::from_millis(
            args.u64("hb-timeout-ms", d.heartbeat_timeout.as_millis() as u64),
        ),
        accept_timeout: Duration::from_millis(
            args.u64("join-timeout-ms", d.accept_timeout.as_millis() as u64),
        ),
    };
    let bind = args.str("bind", "127.0.0.1:0");
    let world = rcfg.world;
    let mut hub = Rendezvous::bind(&bind, rcfg)?;
    // The exact line scripts/smoke_multiproc.sh greps for the address.
    println!("rendezvous listening on {} world={world}", hub.addr());
    use std::io::Write as _;
    std::io::stdout().flush()?;
    let report = hub.wait();
    println!(
        "rendezvous done: joined={} generations={} evicted={:?} ops={}",
        report.joined, report.generations, report.evicted, report.ops_done,
    );
    Ok(())
}

/// One EDiT driver rank. `--join ADDR` speaks the socket backend to a
/// rendezvous hub; `--local N` runs the same rounds on N in-process
/// threads over a ThreadComm — the bitwise reference. Both print the
/// anchor digest; at equal configs the lines must match exactly.
fn cmd_worker(args: &Args) -> Result<()> {
    use edit_train::collectives::driver::{
        run_local_group, run_worker_resumed, DriverConfig, DriverPayload, WorkerCheckpoint,
    };
    use edit_train::collectives::{Collective, ConnectOpts, SocketComm};
    let payload = args.str("payload", "f32");
    let d = DriverConfig::default();
    let mut dcfg = DriverConfig {
        params: args.usize("params", d.params),
        rounds: args.usize("rounds", d.rounds),
        inner_steps: args.usize("inner-steps", d.inner_steps),
        seed: args.u64("seed", d.seed),
        inner_lr: args.f64("inner-lr", d.inner_lr as f64) as f32,
        payload: DriverPayload::parse(&payload)
            .ok_or_else(|| anyhow::anyhow!("--payload: expected f32|int8, got '{payload}'"))?,
        modules: args.usize("modules", d.modules).max(1),
        overlap: args.flag("overlap"),
        checkpoint_every: args.usize("checkpoint-every", 0),
        checkpoint_dir: args.opt("checkpoint-dir").map(std::path::PathBuf::from),
        ..d
    };
    if let Some(dir) = &dcfg.checkpoint_dir {
        std::fs::create_dir_all(dir)?;
    }
    // The wire-chaos plan needs the world size for `random:PAIRS:net`,
    // so it is parsed per-branch once membership is known.
    let parse_net_plan = |world: usize, seed: u64| -> Result<Option<FaultPlan>> {
        args.opt("net-plan")
            .map(|spec| {
                FaultPlan::parse(spec, seed, world)
                    .map_err(|e| anyhow::anyhow!("--net-plan: {e}"))
            })
            .transpose()
    };

    if let Some(addr) = args.opt("join") {
        let mut comm = SocketComm::connect(addr, ConnectOpts::default())
            .map_err(|e| anyhow::anyhow!("join {addr}: {e}"))?;
        let (rank, world) = (comm.rank(), comm.size());
        if let Some(plan) = parse_net_plan(world, dcfg.seed)? {
            dcfg.net_plan = plan;
        }
        let restored = match args.opt("restore") {
            Some(tpl) => {
                let path = tpl.replace("{rank}", &rank.to_string());
                let ck = WorkerCheckpoint::load(std::path::Path::new(&path))
                    .map_err(|e| anyhow::anyhow!("--restore {path}: {e}"))?;
                ck.validate(&dcfg, rank, world)
                    .map_err(|e| anyhow::anyhow!("--restore {path}: {e}"))?;
                eprintln!("worker rank={rank} restored {path} (resuming at round {})", ck.round);
                Some(ck)
            }
            None => None,
        };
        eprintln!("worker rank={rank} world={world} joined {addr}");
        let out = run_worker_resumed(&comm, &dcfg, restored.as_ref())?;
        let stats = comm.wire_stats();
        let world = comm.size(); // may have grown via mid-run joins
        comm.close();
        println!(
            "worker rank={rank} world={world} rounds={} digest={:#018x} evicted={:?} \
             tx_bytes={} rx_bytes={} reconnects={}",
            out.rounds_done,
            out.digest,
            out.evictions,
            stats.tx_bytes,
            stats.rx_bytes,
            stats.reconnects,
        );
    } else {
        let world = args.usize("local", 2);
        if let Some(plan) = parse_net_plan(world, dcfg.seed)? {
            dcfg.net_plan = plan;
        }
        let outs = run_local_group(world, &dcfg)?;
        for (rank, out) in outs.iter().enumerate() {
            println!(
                "worker rank={rank} world={world} rounds={} digest={:#018x} evicted={:?}",
                out.rounds_done, out.digest, out.evictions,
            );
        }
    }
    Ok(())
}

fn cmd_probe(args: &Args, opts: &ExpOpts) -> Result<()> {
    let (spec, label) = MethodSpec::parse(&args.str("method", "edit"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut t = opts.trainer_spec(spec, &label, Quality::clean(), 0)?;
    t.run()?;
    println!("probe PPLs for {} after {} steps:", label, opts.steps);
    for (name, ppl) in t.probe_ppls()? {
        println!("  {name:<14} {}", format_g(ppl));
    }
    Ok(())
}

fn cmd_info(opts: &ExpOpts) -> Result<()> {
    let engine = Engine::load(&opts.artifacts, &opts.model)?;
    let m = &engine.manifest;
    println!("platform: {}", engine.platform());
    println!(
        "model '{}': {} params, {} layers, hidden {}, vocab {}, seq {}, batch {}",
        m.model.name,
        m.total_params,
        m.model.num_layers,
        m.model.hidden_size,
        m.model.vocab_size,
        m.model.seq_len,
        m.model.batch_size,
    );
    println!("programs: {:?}", m.programs.keys().collect::<Vec<_>>());
    println!(
        "penalty programs (sync-group sizes): {:?}",
        m.penalty_programs.keys().collect::<Vec<_>>()
    );
    println!("modules (layer-wise sync units): {}", m.table.num_modules());
    Ok(())
}
