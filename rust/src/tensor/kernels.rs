//! Fused, SIMD-friendly vector kernels — the L3 sync hot path.
//!
//! Every op is written in a chunked, multi-accumulator style the
//! auto-vectorizer reliably turns into packed SIMD: elementwise ops run
//! over `chunks_exact` blocks (no bounds checks inside the block), and
//! reductions carry [`LANES`] independent f64 accumulators so the
//! f32→f64 convert-and-accumulate chain has no loop-carried dependency
//! on a single register.
//!
//! Numerics contract (asserted by `tests/kernels_fused.rs`):
//!  * elementwise kernels (`axpy`, `sub`, `scale`, `add`, `scale_axpy`,
//!    the weighted-sum output) are **bitwise identical** to the naive
//!    [`reference`] ops — they perform the same f32 operations per
//!    element in the same order;
//!  * reductions (`dot`, `sq_norm`, and the fused `*_sq` variants)
//!    reassociate the f64 accumulation across [`LANES`] lanes, so they
//!    agree with [`reference`] to relative 1e-6 rather than bitwise.
//!    All fused reductions share one lane schedule, so e.g.
//!    `weighted_sum_sq_into`'s norm is bitwise equal to calling
//!    [`sq_norm`] on its output.
//!
//! The fused ops exist because the synchronization pipeline
//! (`coordinator::engine::Trainer::synchronize`) was multi-pass: the
//! pseudo-gradient subtraction, its per-module norm, the weighted
//! combine, the combined norm, and the clip-β scaling each re-walked
//! the same cache-cold megabyte-scale vectors. Each fused op does one
//! sweep:
//!  * [`sub_sq_norm_into`]  — Δ = a − b and ‖Δ‖² in one pass;
//!  * [`weighted_sum_sq_into`] / [`weighted_sum_sq_strided`] — the
//!    softmax-weighted combine and its squared norm in one pass;
//!  * [`scale_axpy`]        — clip-β folded into the outer-optimizer
//!    apply (y += α·(β·x), two roundings, matching the reference
//!    scale-then-axpy exactly).

/// Accumulator lanes for f64 reductions (maps to one AVX2 f64x4 /
/// two NEON f64x2 registers).
pub const LANES: usize = 4;

/// Elements per quantization chunk: each chunk of the pseudo-gradient
/// carries one f32 scale on the wire. 64 is a multiple of [`LANES`]
/// (the per-chunk norm accumulation keeps the global lane schedule) and
/// small enough that per-chunk ranges track local gradient magnitude.
pub const QUANT_CHUNK: usize = 64;

/// Wire encoding of a synchronized pseudo-gradient payload — the
/// `MethodSpec` payload axis (`payload=f32|int8|bit1`). `F32` is the
/// uncompressed historical path (bit-for-bit; no quantization code
/// runs); the compressed kinds quantize per [`QUANT_CHUNK`] chunk with
/// an error-feedback residual maintained by the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PayloadKind {
    /// Raw f32 payload (4 bytes/element, no scales, no residuals).
    F32,
    /// Symmetric int8: per-chunk scale = max|v|/127, deterministic
    /// round-to-nearest codes in [-127, 127].
    Int8,
    /// Sign bit + per-chunk mean-|v| magnitude (1-bit SGD style).
    Bit1,
}

impl PayloadKind {
    pub fn name(self) -> &'static str {
        match self {
            PayloadKind::F32 => "f32",
            PayloadKind::Int8 => "int8",
            PayloadKind::Bit1 => "bit1",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "f32" | "full" => PayloadKind::F32,
            "int8" | "i8" => PayloadKind::Int8,
            "bit1" | "1bit" | "sign" => PayloadKind::Bit1,
            _ => return None,
        })
    }

    /// Does this payload run the quantize/dequantize + error-feedback
    /// machinery at all? `F32` bypasses it completely (bitwise contract
    /// with pre-payload-axis behavior).
    pub fn quantized(self) -> bool {
        !matches!(self, PayloadKind::F32)
    }

    /// Bytes on the wire for `elems` f32 elements: codes plus one f32
    /// scale per [`QUANT_CHUNK`] chunk. `F32` is exactly `elems * 4`,
    /// so cost-model call sites stay bit-identical on the default path.
    pub fn wire_bytes(self, elems: usize) -> usize {
        match self {
            PayloadKind::F32 => elems * 4,
            PayloadKind::Int8 => elems + elems.div_ceil(QUANT_CHUNK) * 4,
            PayloadKind::Bit1 => elems.div_ceil(8) + elems.div_ceil(QUANT_CHUNK) * 4,
        }
    }
}

/// Fold the lane accumulators in a fixed tree order. Every reduction in
/// this module uses this exact order, which is what makes the fused
/// `*_sq` results bitwise equal to their two-pass kernel counterparts.
#[inline]
fn fold_lanes(acc: [f64; LANES]) -> f64 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += alpha * xb[i];
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * xi;
    }
}

/// y += x (the alpha = 1 fold used by the striped collectives).
#[inline]
pub fn add(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += xb[i];
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += xi;
    }
}

/// x *= alpha
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    let mut c = x.chunks_exact_mut(LANES);
    for b in &mut c {
        for i in 0..LANES {
            b[i] *= alpha;
        }
    }
    for xi in c.into_remainder() {
        *xi *= alpha;
    }
}

/// y += alpha * (beta * x) — the clip-β fused outer-optimizer apply.
///
/// Two roundings per element (β·x first, then the axpy), bitwise equal
/// to `reference::scale` followed by `reference::axpy`.
#[inline]
pub fn scale_axpy(y: &mut [f32], alpha: f32, beta: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += alpha * (beta * xb[i]);
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * (beta * xi);
    }
}

/// out = a - b
#[inline]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            ob[i] = ab[i] - bb[i];
        }
    }
    for ((o, &ai), &bi) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = ai - bi;
    }
}

/// Squared L2 norm, f64 lane accumulation.
#[inline]
pub fn sq_norm(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut c = x.chunks_exact(LANES);
    for b in &mut c {
        for i in 0..LANES {
            let v = b[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, &xi) in c.remainder().iter().enumerate() {
        let v = xi as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// Dot product, f64 lane accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ab, bb) in (&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            acc[i] += ab[i] as f64 * bb[i] as f64;
        }
    }
    for (i, (&ai, &bi)) in ac.remainder().iter().zip(bc.remainder()).enumerate() {
        acc[i] += ai as f64 * bi as f64;
    }
    fold_lanes(acc)
}

/// Fused pseudo-gradient: out = a - b, returning ‖out‖² from the same
/// sweep. The subtraction is bitwise `reference::sub`; the norm uses the
/// shared lane schedule (bitwise equal to `sq_norm(out)`).
#[inline]
pub fn sub_sq_norm_into(out: &mut [f32], a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            let d = ab[i] - bb[i];
            ob[i] = d;
            let v = d as f64;
            acc[i] += v * v;
        }
    }
    for (i, ((o, &ai), &bi)) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
        .enumerate()
    {
        let d = ai - bi;
        *o = d;
        let v = d as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// Fused weighted combine: `out = Σ_j weights[j]·rows[j]`, returning
/// ‖out‖² from the same sweep. Zero-weight rows are skipped, and the
/// per-element accumulation runs in ascending row order — bitwise equal
/// to `reference::weighted_sum_into` (and the norm to `sq_norm(out)`).
pub fn weighted_sum_sq_into(out: &mut [f32], rows: &[&[f32]], weights: &[f32]) -> f64 {
    assert_eq!(rows.len(), weights.len());
    for row in rows {
        assert_eq!(row.len(), out.len());
    }
    let len = out.len();
    let mut acc = [0.0f64; LANES];
    let blocks = len / LANES;
    for blk in 0..blocks {
        let base = blk * LANES;
        let mut s = [0.0f32; LANES];
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                let rb = &row[base..base + LANES];
                for i in 0..LANES {
                    s[i] += w * rb[i];
                }
            }
        }
        out[base..base + LANES].copy_from_slice(&s);
        for i in 0..LANES {
            let v = s[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, idx) in (blocks * LANES..len).enumerate() {
        let mut s = 0.0f32;
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                s += w * row[idx];
            }
        }
        out[idx] = s;
        let v = s as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// [`weighted_sum_sq_into`] over rows stored as one flat row-major
/// matrix (`flat[j*stride + off ..]` is row j's slice) — the shape the
/// `SyncScratch` delta arena keeps, so the sync pipeline never has to
/// materialize a `Vec<&[f32]>` of row views per module.
pub fn weighted_sum_sq_strided(
    out: &mut [f32],
    flat: &[f32],
    stride: usize,
    off: usize,
    weights: &[f32],
) -> f64 {
    let len = out.len();
    assert!(off + len <= stride);
    assert!(weights.len() * stride <= flat.len() + (stride - off - len));
    let mut acc = [0.0f64; LANES];
    let blocks = len / LANES;
    for blk in 0..blocks {
        let base = off + blk * LANES;
        let mut s = [0.0f32; LANES];
        for (j, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                let rb = &flat[j * stride + base..j * stride + base + LANES];
                for i in 0..LANES {
                    s[i] += w * rb[i];
                }
            }
        }
        out[blk * LANES..blk * LANES + LANES].copy_from_slice(&s);
        for i in 0..LANES {
            let v = s[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, idx) in (blocks * LANES..len).enumerate() {
        let mut s = 0.0f32;
        for (j, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                s += w * flat[j * stride + off + idx];
            }
        }
        out[idx] = s;
        let v = s as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// One int8 chunk (≤ [`QUANT_CHUNK`] elems): `x` holds the
/// residual-corrected value v on entry; on exit `x` holds the
/// dequantized value d = round(v/scale)·scale and `r` the new residual
/// v − d. Scale is max|v|/127; an all-zero chunk passes v through
/// untouched (d = v, r = 0) so signed zeros survive.
#[inline]
fn qdq_chunk_int8(x: &mut [f32], r: &mut [f32]) {
    let mut mx = 0.0f32;
    for &v in x.iter() {
        mx = mx.max(v.abs());
    }
    if mx == 0.0 {
        r.fill(0.0);
        return;
    }
    let scale = mx / 127.0;
    let inv = 1.0 / scale;
    for (xi, ri) in x.iter_mut().zip(r.iter_mut()) {
        let v = *xi;
        let q = (v * inv).round().clamp(-127.0, 127.0);
        let d = q * scale;
        *ri = v - d;
        *xi = d;
    }
}

/// One 1-bit chunk: d = sign(v)·mean|v| (mean accumulated in f64),
/// residual update as in [`qdq_chunk_int8`].
#[inline]
fn qdq_chunk_bit1(x: &mut [f32], r: &mut [f32]) {
    let mut sum = 0.0f64;
    for &v in x.iter() {
        sum += v.abs() as f64;
    }
    let scale = (sum / x.len() as f64) as f32;
    for (xi, ri) in x.iter_mut().zip(r.iter_mut()) {
        let v = *xi;
        let d = if v.is_sign_positive() { scale } else { -scale };
        *ri = v - d;
        *xi = d;
    }
}

/// Fused error-feedback quantize→dequantize, in place: per chunk,
/// v = x + residual, then x ← dequant(quant(v)) and residual ← v − d.
/// `F32` is the identity (x and residual untouched). Exactly the
/// arithmetic of [`sub_qdq_ef_sq_norm_into`] when `x` already holds the
/// raw pseudo-gradient, and of [`reference::quant_dequant_ef`].
pub fn quant_dequant_ef(kind: PayloadKind, x: &mut [f32], residual: &mut [f32]) {
    if !kind.quantized() {
        return;
    }
    assert_eq!(x.len(), residual.len());
    for (xc, rc) in x.chunks_mut(QUANT_CHUNK).zip(residual.chunks_mut(QUANT_CHUNK)) {
        for (xi, &ri) in xc.iter_mut().zip(rc.iter()) {
            *xi += ri;
        }
        match kind {
            PayloadKind::Int8 => qdq_chunk_int8(xc, rc),
            PayloadKind::Bit1 => qdq_chunk_bit1(xc, rc),
            PayloadKind::F32 => unreachable!(),
        }
    }
}

/// The quantized-payload pseudo-gradient sweep: out = qdq(a − b +
/// residual) per [`QUANT_CHUNK`] chunk, residual updated in place,
/// returning ‖out‖² with the shared [`LANES`] schedule (bitwise equal
/// to [`sq_norm`]`(out)` — `QUANT_CHUNK % LANES == 0`, so per-chunk
/// accumulation preserves the global lane assignment). `F32` falls
/// through to [`sub_sq_norm_into`] untouched — the compressed path adds
/// zero work to the default payload.
pub fn sub_qdq_ef_sq_norm_into(
    kind: PayloadKind,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    residual: &mut [f32],
) -> f64 {
    if !kind.quantized() {
        return sub_sq_norm_into(out, a, b);
    }
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    assert_eq!(out.len(), residual.len());
    let mut acc = [0.0f64; LANES];
    let n = out.len();
    let mut pos = 0;
    while pos < n {
        let end = (pos + QUANT_CHUNK).min(n);
        let oc = &mut out[pos..end];
        let rc = &mut residual[pos..end];
        for (i, o) in oc.iter_mut().enumerate() {
            *o = (a[pos + i] - b[pos + i]) + rc[i];
        }
        match kind {
            PayloadKind::Int8 => qdq_chunk_int8(oc, rc),
            PayloadKind::Bit1 => qdq_chunk_bit1(oc, rc),
            PayloadKind::F32 => unreachable!(),
        }
        let mut c = oc.chunks_exact(LANES);
        for blk in &mut c {
            for i in 0..LANES {
                let v = blk[i] as f64;
                acc[i] += v * v;
            }
        }
        for (i, &xi) in c.remainder().iter().enumerate() {
            let v = xi as f64;
            acc[i] += v * v;
        }
        pos = end;
    }
    fold_lanes(acc)
}

/// The original single-pass scalar implementations, kept verbatim as the
/// testing oracle: `tests/kernels_fused.rs` asserts every fused kernel
/// against these across remainder-lane-exercising lengths.
pub mod reference {
    use super::{PayloadKind, QUANT_CHUNK};
    /// y += alpha * x
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// x *= alpha
    pub fn scale(x: &mut [f32], alpha: f32) {
        for xi in x.iter_mut() {
            *xi *= alpha;
        }
    }

    /// out = a - b
    pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        debug_assert_eq!(out.len(), b.len());
        for ((o, &ai), &bi) in out.iter_mut().zip(a).zip(b) {
            *o = ai - bi;
        }
    }

    /// Squared L2 norm, sequential f64 accumulation.
    pub fn sq_norm(x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &xi in x {
            acc += (xi as f64) * (xi as f64);
        }
        acc
    }

    /// Dot product, sequential f64 accumulation.
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0.0f64;
        for (&ai, &bi) in a.iter().zip(b) {
            acc += ai as f64 * bi as f64;
        }
        acc
    }

    /// `out = Σ_j weights[j]·rows[j]`, skipping zero weights.
    pub fn weighted_sum_into(out: &mut [f32], rows: &[&[f32]], weights: &[f32]) {
        debug_assert_eq!(rows.len(), weights.len());
        out.fill(0.0);
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                axpy(out, w, row);
            }
        }
    }

    /// Naive error-feedback quantize→dequantize: plain multi-pass
    /// per-chunk loops with the same formulas as the fused kernel
    /// (scale = max|v|/127 for int8, sign·mean|v| for bit1; v = x +
    /// residual; residual ← v − d). The fused op is asserted bitwise
    /// against this.
    pub fn quant_dequant_ef(kind: PayloadKind, x: &mut [f32], residual: &mut [f32]) {
        if !kind.quantized() {
            return;
        }
        debug_assert_eq!(x.len(), residual.len());
        for (xc, rc) in x.chunks_mut(QUANT_CHUNK).zip(residual.chunks_mut(QUANT_CHUNK)) {
            // v = x + r
            for (xi, &ri) in xc.iter_mut().zip(rc.iter()) {
                *xi += ri;
            }
            match kind {
                PayloadKind::Int8 => {
                    let mut mx = 0.0f32;
                    for &v in xc.iter() {
                        mx = mx.max(v.abs());
                    }
                    if mx == 0.0 {
                        rc.fill(0.0);
                        continue;
                    }
                    let scale = mx / 127.0;
                    let inv = 1.0 / scale;
                    for (xi, ri) in xc.iter_mut().zip(rc.iter_mut()) {
                        let v = *xi;
                        let d = (v * inv).round().clamp(-127.0, 127.0) * scale;
                        *ri = v - d;
                        *xi = d;
                    }
                }
                PayloadKind::Bit1 => {
                    let mut sum = 0.0f64;
                    for &v in xc.iter() {
                        sum += v.abs() as f64;
                    }
                    let scale = (sum / xc.len() as f64) as f32;
                    for (xi, ri) in xc.iter_mut().zip(rc.iter_mut()) {
                        let v = *xi;
                        let d = if v.is_sign_positive() { scale } else { -scale };
                        *ri = v - d;
                        *xi = d;
                    }
                }
                PayloadKind::F32 => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vec_pattern(n: usize, salt: u32) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt) % 1000;
                h as f32 / 250.0 - 2.0
            })
            .collect()
    }

    /// Lengths that exercise empty, single, chunk-boundary and bulk paths.
    fn lens() -> Vec<usize> {
        vec![0, 1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3, 1023, 1024, 4097]
    }

    #[test]
    fn axpy_bitwise_matches_reference() {
        for n in lens() {
            let x = vec_pattern(n, 1);
            let mut y = vec_pattern(n, 2);
            let mut yr = y.clone();
            axpy(&mut y, 1.7, &x);
            reference::axpy(&mut yr, 1.7, &x);
            assert_eq!(y, yr, "n={n}");
        }
    }

    #[test]
    fn sub_bitwise_matches_reference() {
        for n in lens() {
            let a = vec_pattern(n, 3);
            let b = vec_pattern(n, 4);
            let mut out = vec![0.0; n];
            let mut outr = vec![0.0; n];
            sub(&mut out, &a, &b);
            reference::sub(&mut outr, &a, &b);
            assert_eq!(out, outr, "n={n}");
        }
    }

    #[test]
    fn add_equals_axpy_one() {
        for n in lens() {
            let x = vec_pattern(n, 5);
            let mut y = vec_pattern(n, 6);
            let mut y2 = y.clone();
            add(&mut y, &x);
            reference::axpy(&mut y2, 1.0, &x);
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn reductions_close_to_reference() {
        for n in lens() {
            let a = vec_pattern(n, 7);
            let b = vec_pattern(n, 8);
            let tol = 1e-6 * (n.max(1) as f64);
            assert!((sq_norm(&a) - reference::sq_norm(&a)).abs() <= tol * 4.0, "n={n}");
            assert!((dot(&a, &b) - reference::dot(&a, &b)).abs() <= tol * 4.0, "n={n}");
        }
    }

    #[test]
    fn fused_sub_norm_consistent() {
        for n in lens() {
            let a = vec_pattern(n, 9);
            let b = vec_pattern(n, 10);
            let mut out = vec![0.0; n];
            let sq = sub_sq_norm_into(&mut out, &a, &b);
            let mut outr = vec![0.0; n];
            reference::sub(&mut outr, &a, &b);
            assert_eq!(out, outr, "n={n}");
            // Same lane schedule => bitwise equal to the two-pass kernel.
            assert_eq!(sq.to_bits(), sq_norm(&out).to_bits(), "n={n}");
        }
    }

    #[test]
    fn fused_weighted_sum_consistent() {
        for n in lens() {
            let rows_owned: Vec<Vec<f32>> =
                (0..4).map(|j| vec_pattern(n, 11 + j)).collect();
            let rows: Vec<&[f32]> = rows_owned.iter().map(|r| r.as_slice()).collect();
            let w = [0.5f32, 0.0, 0.3, 0.2];
            let mut out = vec![0.0; n];
            let sq = weighted_sum_sq_into(&mut out, &rows, &w);
            let mut outr = vec![0.0; n];
            reference::weighted_sum_into(&mut outr, &rows, &w);
            assert_eq!(out, outr, "n={n}");
            assert_eq!(sq.to_bits(), sq_norm(&out).to_bits(), "n={n}");
        }
    }

    #[test]
    fn strided_matches_rows_variant() {
        let n = 2 * LANES + 3;
        let stride = n + 5;
        let off = 5;
        let rows_owned: Vec<Vec<f32>> = (0..3).map(|j| vec_pattern(stride, 20 + j)).collect();
        let flat: Vec<f32> = rows_owned.concat();
        let rows: Vec<&[f32]> =
            rows_owned.iter().map(|r| &r[off..off + n]).collect();
        let w = [0.25f32, 0.5, 0.25];
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let sq_a = weighted_sum_sq_into(&mut a, &rows, &w);
        let sq_b = weighted_sum_sq_strided(&mut b, &flat, stride, off, &w);
        assert_eq!(a, b);
        assert_eq!(sq_a.to_bits(), sq_b.to_bits());
    }

    #[test]
    fn scale_axpy_matches_two_pass() {
        for n in lens() {
            let x = vec_pattern(n, 30);
            let mut y = vec_pattern(n, 31);
            let mut y2 = y.clone();
            scale_axpy(&mut y, 0.8, 0.37, &x);
            let mut xs = x.clone();
            reference::scale(&mut xs, 0.37);
            reference::axpy(&mut y2, 0.8, &xs);
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn scale_axpy_beta_one_is_axpy() {
        let x = vec_pattern(77, 40);
        let mut y = vec_pattern(77, 41);
        let mut y2 = y.clone();
        scale_axpy(&mut y, 0.9, 1.0, &x);
        axpy(&mut y2, 0.9, &x);
        assert_eq!(y, y2);
    }

    #[test]
    fn sq_norm_f64_stable_at_scale() {
        let x = vec![1e-3f32; 10_000_000];
        let got = sq_norm(&x);
        assert!((got - 10.0).abs() < 1e-6, "{got}");
    }

    #[test]
    fn payload_wire_bytes_and_names() {
        for (kind, name) in [
            (PayloadKind::F32, "f32"),
            (PayloadKind::Int8, "int8"),
            (PayloadKind::Bit1, "bit1"),
        ] {
            assert_eq!(kind.name(), name);
            assert_eq!(PayloadKind::parse(name), Some(kind));
        }
        assert_eq!(PayloadKind::parse("f16"), None);
        // F32 is exactly the historical elems*4 expression.
        assert_eq!(PayloadKind::F32.wire_bytes(1000), 4000);
        // Int8: 1 byte/elem + one f32 scale per chunk.
        assert_eq!(PayloadKind::Int8.wire_bytes(QUANT_CHUNK), QUANT_CHUNK + 4);
        assert_eq!(PayloadKind::Int8.wire_bytes(QUANT_CHUNK + 1), QUANT_CHUNK + 1 + 8);
        // Bit1: 1 bit/elem + scales.
        assert_eq!(PayloadKind::Bit1.wire_bytes(QUANT_CHUNK), QUANT_CHUNK / 8 + 4);
        assert_eq!(PayloadKind::Bit1.wire_bytes(0), 0);
        // The headline ratio: int8 compresses f32 by ~3.8x at scale
        // (4 bytes -> 1 + 4/QUANT_CHUNK = 1.0625 bytes per element).
        let elems = 1 << 20;
        let ratio = PayloadKind::F32.wire_bytes(elems) as f64
            / PayloadKind::Int8.wire_bytes(elems) as f64;
        assert!(ratio >= 3.5, "{ratio}");
    }

    #[test]
    fn qdq_fused_bitwise_matches_reference_and_bounds() {
        for kind in [PayloadKind::Int8, PayloadKind::Bit1] {
            for n in lens() {
                let a = vec_pattern(n, 50);
                let b = vec_pattern(n, 51);
                let mut r_f = vec_pattern(n, 52);
                for x in r_f.iter_mut() {
                    *x *= 1e-3; // residual-sized
                }
                let mut r_r = r_f.clone();
                let mut out = vec![0.0f32; n];
                let sq = sub_qdq_ef_sq_norm_into(kind, &mut out, &a, &b, &mut r_f);
                // Reference: explicit sub, then the naive qdq.
                let mut out_r = vec![0.0f32; n];
                reference::sub(&mut out_r, &a, &b);
                reference::quant_dequant_ef(kind, &mut out_r, &mut r_r);
                assert_eq!(out, out_r, "{kind:?} n={n}");
                assert_eq!(r_f, r_r, "{kind:?} n={n} residuals");
                // Norm shares the global lane schedule.
                assert_eq!(sq.to_bits(), sq_norm(&out).to_bits(), "{kind:?} n={n}");
                // And the in-place variant agrees when fed the raw sub.
                let mut x2 = vec![0.0f32; n];
                sub(&mut x2, &a, &b);
                let mut r2 = r_f.clone();
                // Start from the same pre-round residual.
                r2.copy_from_slice(&{
                    let mut r0 = vec_pattern(n, 52);
                    for x in r0.iter_mut() {
                        *x *= 1e-3;
                    }
                    r0
                });
                quant_dequant_ef(kind, &mut x2, &mut r2);
                assert_eq!(x2, out, "{kind:?} n={n} in-place variant");
                assert_eq!(r2, r_f, "{kind:?} n={n} in-place residuals");
            }
        }
    }

    #[test]
    fn qdq_error_feedback_identity_per_element() {
        // d + r reconstructs v to f32 rounding: the residual IS the
        // quantization error, so nothing is lost across rounds.
        let n = 3 * QUANT_CHUNK + 7;
        for kind in [PayloadKind::Int8, PayloadKind::Bit1] {
            let v = vec_pattern(n, 60);
            let mut x = v.clone();
            let mut r = vec![0.0f32; n];
            quant_dequant_ef(kind, &mut x, &mut r);
            for i in 0..n {
                // r was computed as fl(v - d); adding d back must be exact
                // or within one ulp of v.
                let rec = x[i] + r[i];
                let err = (rec - v[i]).abs();
                assert!(err <= v[i].abs() * 1e-6 + 1e-12, "{kind:?} i={i}: {rec} vs {}", v[i]);
            }
        }
    }

    #[test]
    fn qdq_int8_per_chunk_error_bound() {
        // |d - v| <= scale/2 per element, scale = chunk max|v|/127.
        let n = 4 * QUANT_CHUNK + 19;
        let v = vec_pattern(n, 70);
        let mut x = v.clone();
        let mut r = vec![0.0f32; n];
        quant_dequant_ef(PayloadKind::Int8, &mut x, &mut r);
        for (ci, chunk) in v.chunks(QUANT_CHUNK).enumerate() {
            let mx = chunk.iter().fold(0.0f32, |m, &y| m.max(y.abs()));
            let half_step = mx / 127.0 / 2.0;
            for (i, &vi) in chunk.iter().enumerate() {
                let d = x[ci * QUANT_CHUNK + i];
                assert!(
                    (d - vi).abs() <= half_step * (1.0 + 1e-5) + 1e-12,
                    "chunk {ci} elem {i}: |{d} - {vi}| > {half_step}"
                );
            }
        }
    }

    #[test]
    fn qdq_f32_is_identity_and_zero_chunks_pass_through() {
        let mut x = vec_pattern(100, 80);
        let orig = x.clone();
        let mut r = vec![0.5f32; 100];
        quant_dequant_ef(PayloadKind::F32, &mut x, &mut r);
        assert_eq!(x, orig);
        assert_eq!(r, vec![0.5f32; 100]);
        // All-zero chunk: values pass through, residual zeroed.
        let mut z = vec![0.0f32; QUANT_CHUNK];
        z[3] = -0.0;
        let mut rz = vec![0.0f32; QUANT_CHUNK];
        quant_dequant_ef(PayloadKind::Int8, &mut z, &mut rz);
        assert_eq!(z[3].to_bits(), (-0.0f32).to_bits());
        assert!(rz.iter().all(|&x| x == 0.0));
    }
}
