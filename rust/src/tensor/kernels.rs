//! Fused, SIMD-friendly vector kernels — the L3 sync hot path.
//!
//! Every op is written in a chunked, multi-accumulator style the
//! auto-vectorizer reliably turns into packed SIMD: elementwise ops run
//! over `chunks_exact` blocks (no bounds checks inside the block), and
//! reductions carry [`LANES`] independent f64 accumulators so the
//! f32→f64 convert-and-accumulate chain has no loop-carried dependency
//! on a single register.
//!
//! Numerics contract (asserted by `tests/kernels_fused.rs`):
//!  * elementwise kernels (`axpy`, `sub`, `scale`, `add`, `scale_axpy`,
//!    the weighted-sum output) are **bitwise identical** to the naive
//!    [`reference`] ops — they perform the same f32 operations per
//!    element in the same order;
//!  * reductions (`dot`, `sq_norm`, and the fused `*_sq` variants)
//!    reassociate the f64 accumulation across [`LANES`] lanes, so they
//!    agree with [`reference`] to relative 1e-6 rather than bitwise.
//!    All fused reductions share one lane schedule, so e.g.
//!    `weighted_sum_sq_into`'s norm is bitwise equal to calling
//!    [`sq_norm`] on its output.
//!
//! The fused ops exist because the synchronization pipeline
//! (`coordinator::engine::Trainer::synchronize`) was multi-pass: the
//! pseudo-gradient subtraction, its per-module norm, the weighted
//! combine, the combined norm, and the clip-β scaling each re-walked
//! the same cache-cold megabyte-scale vectors. Each fused op does one
//! sweep:
//!  * [`sub_sq_norm_into`]  — Δ = a − b and ‖Δ‖² in one pass;
//!  * [`weighted_sum_sq_into`] / [`weighted_sum_sq_strided`] — the
//!    softmax-weighted combine and its squared norm in one pass;
//!  * [`scale_axpy`]        — clip-β folded into the outer-optimizer
//!    apply (y += α·(β·x), two roundings, matching the reference
//!    scale-then-axpy exactly).

/// Accumulator lanes for f64 reductions (maps to one AVX2 f64x4 /
/// two NEON f64x2 registers).
pub const LANES: usize = 4;

/// Fold the lane accumulators in a fixed tree order. Every reduction in
/// this module uses this exact order, which is what makes the fused
/// `*_sq` results bitwise equal to their two-pass kernel counterparts.
#[inline]
fn fold_lanes(acc: [f64; LANES]) -> f64 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += alpha * xb[i];
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * xi;
    }
}

/// y += x (the alpha = 1 fold used by the striped collectives).
#[inline]
pub fn add(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += xb[i];
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += xi;
    }
}

/// x *= alpha
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    let mut c = x.chunks_exact_mut(LANES);
    for b in &mut c {
        for i in 0..LANES {
            b[i] *= alpha;
        }
    }
    for xi in c.into_remainder() {
        *xi *= alpha;
    }
}

/// y += alpha * (beta * x) — the clip-β fused outer-optimizer apply.
///
/// Two roundings per element (β·x first, then the axpy), bitwise equal
/// to `reference::scale` followed by `reference::axpy`.
#[inline]
pub fn scale_axpy(y: &mut [f32], alpha: f32, beta: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for i in 0..LANES {
            yb[i] += alpha * (beta * xb[i]);
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * (beta * xi);
    }
}

/// out = a - b
#[inline]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            ob[i] = ab[i] - bb[i];
        }
    }
    for ((o, &ai), &bi) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = ai - bi;
    }
}

/// Squared L2 norm, f64 lane accumulation.
#[inline]
pub fn sq_norm(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut c = x.chunks_exact(LANES);
    for b in &mut c {
        for i in 0..LANES {
            let v = b[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, &xi) in c.remainder().iter().enumerate() {
        let v = xi as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// Dot product, f64 lane accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ab, bb) in (&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            acc[i] += ab[i] as f64 * bb[i] as f64;
        }
    }
    for (i, (&ai, &bi)) in ac.remainder().iter().zip(bc.remainder()).enumerate() {
        acc[i] += ai as f64 * bi as f64;
    }
    fold_lanes(acc)
}

/// Fused pseudo-gradient: out = a - b, returning ‖out‖² from the same
/// sweep. The subtraction is bitwise `reference::sub`; the norm uses the
/// shared lane schedule (bitwise equal to `sq_norm(out)`).
#[inline]
pub fn sub_sq_norm_into(out: &mut [f32], a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            let d = ab[i] - bb[i];
            ob[i] = d;
            let v = d as f64;
            acc[i] += v * v;
        }
    }
    for (i, ((o, &ai), &bi)) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
        .enumerate()
    {
        let d = ai - bi;
        *o = d;
        let v = d as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// Fused weighted combine: out = Σ_j weights[j]·rows[j], returning
/// ‖out‖² from the same sweep. Zero-weight rows are skipped, and the
/// per-element accumulation runs in ascending row order — bitwise equal
/// to `reference::weighted_sum_into` (and the norm to `sq_norm(out)`).
pub fn weighted_sum_sq_into(out: &mut [f32], rows: &[&[f32]], weights: &[f32]) -> f64 {
    assert_eq!(rows.len(), weights.len());
    for row in rows {
        assert_eq!(row.len(), out.len());
    }
    let len = out.len();
    let mut acc = [0.0f64; LANES];
    let blocks = len / LANES;
    for blk in 0..blocks {
        let base = blk * LANES;
        let mut s = [0.0f32; LANES];
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                let rb = &row[base..base + LANES];
                for i in 0..LANES {
                    s[i] += w * rb[i];
                }
            }
        }
        out[base..base + LANES].copy_from_slice(&s);
        for i in 0..LANES {
            let v = s[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, idx) in (blocks * LANES..len).enumerate() {
        let mut s = 0.0f32;
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                s += w * row[idx];
            }
        }
        out[idx] = s;
        let v = s as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// [`weighted_sum_sq_into`] over rows stored as one flat row-major
/// matrix (`flat[j*stride + off ..]` is row j's slice) — the shape the
/// `SyncScratch` delta arena keeps, so the sync pipeline never has to
/// materialize a `Vec<&[f32]>` of row views per module.
pub fn weighted_sum_sq_strided(
    out: &mut [f32],
    flat: &[f32],
    stride: usize,
    off: usize,
    weights: &[f32],
) -> f64 {
    let len = out.len();
    assert!(off + len <= stride);
    assert!(weights.len() * stride <= flat.len() + (stride - off - len));
    let mut acc = [0.0f64; LANES];
    let blocks = len / LANES;
    for blk in 0..blocks {
        let base = off + blk * LANES;
        let mut s = [0.0f32; LANES];
        for (j, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                let rb = &flat[j * stride + base..j * stride + base + LANES];
                for i in 0..LANES {
                    s[i] += w * rb[i];
                }
            }
        }
        out[blk * LANES..blk * LANES + LANES].copy_from_slice(&s);
        for i in 0..LANES {
            let v = s[i] as f64;
            acc[i] += v * v;
        }
    }
    for (i, idx) in (blocks * LANES..len).enumerate() {
        let mut s = 0.0f32;
        for (j, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                s += w * flat[j * stride + off + idx];
            }
        }
        out[idx] = s;
        let v = s as f64;
        acc[i] += v * v;
    }
    fold_lanes(acc)
}

/// The original single-pass scalar implementations, kept verbatim as the
/// testing oracle: `tests/kernels_fused.rs` asserts every fused kernel
/// against these across remainder-lane-exercising lengths.
pub mod reference {
    /// y += alpha * x
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// x *= alpha
    pub fn scale(x: &mut [f32], alpha: f32) {
        for xi in x.iter_mut() {
            *xi *= alpha;
        }
    }

    /// out = a - b
    pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        debug_assert_eq!(out.len(), b.len());
        for ((o, &ai), &bi) in out.iter_mut().zip(a).zip(b) {
            *o = ai - bi;
        }
    }

    /// Squared L2 norm, sequential f64 accumulation.
    pub fn sq_norm(x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &xi in x {
            acc += (xi as f64) * (xi as f64);
        }
        acc
    }

    /// Dot product, sequential f64 accumulation.
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0.0f64;
        for (&ai, &bi) in a.iter().zip(b) {
            acc += ai as f64 * bi as f64;
        }
        acc
    }

    /// out = Σ_j weights[j]·rows[j], skipping zero weights.
    pub fn weighted_sum_into(out: &mut [f32], rows: &[&[f32]], weights: &[f32]) {
        debug_assert_eq!(rows.len(), weights.len());
        out.fill(0.0);
        for (row, &w) in rows.iter().zip(weights) {
            if w != 0.0 {
                axpy(out, w, row);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vec_pattern(n: usize, salt: u32) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt) % 1000;
                h as f32 / 250.0 - 2.0
            })
            .collect()
    }

    /// Lengths that exercise empty, single, chunk-boundary and bulk paths.
    fn lens() -> Vec<usize> {
        vec![0, 1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3, 1023, 1024, 4097]
    }

    #[test]
    fn axpy_bitwise_matches_reference() {
        for n in lens() {
            let x = vec_pattern(n, 1);
            let mut y = vec_pattern(n, 2);
            let mut yr = y.clone();
            axpy(&mut y, 1.7, &x);
            reference::axpy(&mut yr, 1.7, &x);
            assert_eq!(y, yr, "n={n}");
        }
    }

    #[test]
    fn sub_bitwise_matches_reference() {
        for n in lens() {
            let a = vec_pattern(n, 3);
            let b = vec_pattern(n, 4);
            let mut out = vec![0.0; n];
            let mut outr = vec![0.0; n];
            sub(&mut out, &a, &b);
            reference::sub(&mut outr, &a, &b);
            assert_eq!(out, outr, "n={n}");
        }
    }

    #[test]
    fn add_equals_axpy_one() {
        for n in lens() {
            let x = vec_pattern(n, 5);
            let mut y = vec_pattern(n, 6);
            let mut y2 = y.clone();
            add(&mut y, &x);
            reference::axpy(&mut y2, 1.0, &x);
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn reductions_close_to_reference() {
        for n in lens() {
            let a = vec_pattern(n, 7);
            let b = vec_pattern(n, 8);
            let tol = 1e-6 * (n.max(1) as f64);
            assert!((sq_norm(&a) - reference::sq_norm(&a)).abs() <= tol * 4.0, "n={n}");
            assert!((dot(&a, &b) - reference::dot(&a, &b)).abs() <= tol * 4.0, "n={n}");
        }
    }

    #[test]
    fn fused_sub_norm_consistent() {
        for n in lens() {
            let a = vec_pattern(n, 9);
            let b = vec_pattern(n, 10);
            let mut out = vec![0.0; n];
            let sq = sub_sq_norm_into(&mut out, &a, &b);
            let mut outr = vec![0.0; n];
            reference::sub(&mut outr, &a, &b);
            assert_eq!(out, outr, "n={n}");
            // Same lane schedule => bitwise equal to the two-pass kernel.
            assert_eq!(sq.to_bits(), sq_norm(&out).to_bits(), "n={n}");
        }
    }

    #[test]
    fn fused_weighted_sum_consistent() {
        for n in lens() {
            let rows_owned: Vec<Vec<f32>> =
                (0..4).map(|j| vec_pattern(n, 11 + j)).collect();
            let rows: Vec<&[f32]> = rows_owned.iter().map(|r| r.as_slice()).collect();
            let w = [0.5f32, 0.0, 0.3, 0.2];
            let mut out = vec![0.0; n];
            let sq = weighted_sum_sq_into(&mut out, &rows, &w);
            let mut outr = vec![0.0; n];
            reference::weighted_sum_into(&mut outr, &rows, &w);
            assert_eq!(out, outr, "n={n}");
            assert_eq!(sq.to_bits(), sq_norm(&out).to_bits(), "n={n}");
        }
    }

    #[test]
    fn strided_matches_rows_variant() {
        let n = 2 * LANES + 3;
        let stride = n + 5;
        let off = 5;
        let rows_owned: Vec<Vec<f32>> = (0..3).map(|j| vec_pattern(stride, 20 + j)).collect();
        let flat: Vec<f32> = rows_owned.concat();
        let rows: Vec<&[f32]> =
            rows_owned.iter().map(|r| &r[off..off + n]).collect();
        let w = [0.25f32, 0.5, 0.25];
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let sq_a = weighted_sum_sq_into(&mut a, &rows, &w);
        let sq_b = weighted_sum_sq_strided(&mut b, &flat, stride, off, &w);
        assert_eq!(a, b);
        assert_eq!(sq_a.to_bits(), sq_b.to_bits());
    }

    #[test]
    fn scale_axpy_matches_two_pass() {
        for n in lens() {
            let x = vec_pattern(n, 30);
            let mut y = vec_pattern(n, 31);
            let mut y2 = y.clone();
            scale_axpy(&mut y, 0.8, 0.37, &x);
            let mut xs = x.clone();
            reference::scale(&mut xs, 0.37);
            reference::axpy(&mut y2, 0.8, &xs);
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn scale_axpy_beta_one_is_axpy() {
        let x = vec_pattern(77, 40);
        let mut y = vec_pattern(77, 41);
        let mut y2 = y.clone();
        scale_axpy(&mut y, 0.9, 1.0, &x);
        axpy(&mut y2, 0.9, &x);
        assert_eq!(y, y2);
    }

    #[test]
    fn sq_norm_f64_stable_at_scale() {
        let x = vec![1e-3f32; 10_000_000];
        let got = sq_norm(&x);
        assert!((got - 10.0).abs() < 1e-6, "{got}");
    }
}
