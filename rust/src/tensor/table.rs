//! Module table: the per-tensor / per-layer view over the flat vector.
//!
//! Mirrors the `tensors` section of `artifacts/<config>/manifest.json`
//! written by `python/compile/aot.py`.  The EDiT coordinator uses it to
//! drive *layer-wise* synchronization (Alg. 1 lines 7-9): per-module
//! pseudo-gradient norms, per-module combine, and the layer-by-layer
//! communication schedule that the prefetch/overlap timing model
//! consumes.
//!
//! Stacked tensors (`layers.*`, leading dim = num_layers) are stored
//! once in the flat vector with layer `l`'s slice at
//! `offset + l * (size / L)` — contiguous per layer, which is what makes
//! the per-layer range view cheap.

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    /// true if the leading dim is the layer axis (stacked `layers.*`).
    pub stacked: bool,
}

/// A contiguous range of the flat vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    pub offset: usize,
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct ModuleTable {
    pub tensors: Vec<TensorEntry>,
    pub num_layers: usize,
    pub total: usize,
}

impl ModuleTable {
    pub fn new(tensors: Vec<TensorEntry>, num_layers: usize) -> Self {
        let total = tensors.iter().map(|t| t.size).sum();
        Self { tensors, num_layers, total }
    }

    pub fn from_manifest(manifest: &Json) -> anyhow::Result<Self> {
        let num_layers = manifest
            .at(&["config", "num_layers"])
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing config.num_layers"))?;
        let arr = manifest
            .at(&["tensors"])
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing tensors"))?;
        let mut tensors = Vec::with_capacity(arr.len());
        for t in arr {
            tensors.push(TensorEntry {
                name: t
                    .at(&["name"])
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tensor missing name"))?
                    .to_string(),
                shape: t
                    .at(&["shape"])
                    .and_then(Json::as_arr)
                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                offset: t.at(&["offset"]).and_then(Json::as_usize).unwrap_or(0),
                size: t.at(&["size"]).and_then(Json::as_usize).unwrap_or(0),
                stacked: t.at(&["stacked"]).and_then(Json::as_bool).unwrap_or(false),
            });
        }
        let total = manifest
            .at(&["total_params"])
            .and_then(Json::as_usize)
            .unwrap_or_else(|| tensors.iter().map(|t| t.size).sum());
        anyhow::ensure!(
            total == tensors.iter().map(|t| t.size).sum::<usize>(),
            "manifest total_params inconsistent with tensor table"
        );
        Ok(Self { tensors, num_layers, total })
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorEntry> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Number of sync "modules": one per transformer layer plus one for
    /// the non-stacked remainder (embed / head / final norm).
    pub fn num_modules(&self) -> usize {
        self.num_layers + 1
    }

    /// Flat-vector ranges belonging to module `m`.
    ///
    /// Modules `0..num_layers` are the transformer layers (slices of the
    /// stacked tensors); module `num_layers` collects every non-stacked
    /// tensor. Together the modules partition `0..total` exactly.
    pub fn module_ranges(&self, m: usize) -> Vec<Range> {
        assert!(m < self.num_modules());
        let mut out = Vec::new();
        if m < self.num_layers {
            for t in &self.tensors {
                if t.stacked {
                    let per_layer = t.size / self.num_layers;
                    out.push(Range { offset: t.offset + m * per_layer, len: per_layer });
                }
            }
        } else {
            for t in &self.tensors {
                if !t.stacked {
                    out.push(Range { offset: t.offset, len: t.size });
                }
            }
        }
        out
    }

    /// Total element count of module `m`.
    pub fn module_len(&self, m: usize) -> usize {
        self.module_ranges(m).iter().map(|r| r.len).sum()
    }

    /// Squared L2 norm of module `m` within `flat`.
    pub fn module_sq_norm(&self, flat: &[f32], m: usize) -> f64 {
        self.module_ranges(m)
            .iter()
            .map(|r| super::sq_norm(&flat[r.offset..r.offset + r.len]))
            .sum()
    }

    /// Apply `f(range_slice)` over every range of module `m` in `flat`.
    pub fn for_module_mut<F: FnMut(&mut [f32])>(&self, flat: &mut [f32], m: usize, mut f: F) {
        for r in self.module_ranges(m) {
            f(&mut flat[r.offset..r.offset + r.len]);
        }
    }
}

/// Shared unit-test fixture: embed(8) + 2 stacked layers (b: 2×2,
/// w: 2×6) + head(4) = 28 flat elements, 3 sync modules. One definition
/// serves the tensor and coordinator test suites so the layout can't
/// drift between them.
#[cfg(test)]
pub(crate) fn toy_table() -> ModuleTable {
    ModuleTable::new(
        vec![
            TensorEntry {
                name: "embed".into(),
                shape: vec![4, 2],
                offset: 0,
                size: 8,
                stacked: false,
            },
            TensorEntry {
                name: "layers.b".into(),
                shape: vec![2, 2],
                offset: 8,
                size: 4,
                stacked: true,
            },
            TensorEntry {
                name: "layers.w".into(),
                shape: vec![2, 3, 2],
                offset: 12,
                size: 12,
                stacked: true,
            },
            TensorEntry {
                name: "head".into(),
                shape: vec![2, 2],
                offset: 24,
                size: 4,
                stacked: false,
            },
        ],
        2,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modules_partition_vector() {
        let t = toy_table();
        let mut covered = vec![false; t.total];
        for m in 0..t.num_modules() {
            for r in t.module_ranges(m) {
                for i in r.offset..r.offset + r.len {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn layer_ranges_are_per_layer_slices() {
        let t = toy_table();
        let m0 = t.module_ranges(0);
        let m1 = t.module_ranges(1);
        // layers.b: layer0 at 8..10, layer1 at 10..12
        assert!(m0.contains(&Range { offset: 8, len: 2 }));
        assert!(m1.contains(&Range { offset: 10, len: 2 }));
        // layers.w: layer0 at 12..18, layer1 at 18..24
        assert!(m0.contains(&Range { offset: 12, len: 6 }));
        assert!(m1.contains(&Range { offset: 18, len: 6 }));
    }

    #[test]
    fn tail_module_collects_unstacked() {
        let t = toy_table();
        let tail = t.module_ranges(2);
        assert_eq!(tail, vec![Range { offset: 0, len: 8 }, Range { offset: 24, len: 4 }]);
        assert_eq!(t.module_len(2), 12);
    }

    #[test]
    fn module_sq_norm_sums_ranges() {
        let t = toy_table();
        let flat: Vec<f32> = (0..t.total).map(|i| if i < 8 { 1.0 } else { 0.0 }).collect();
        assert_eq!(t.module_sq_norm(&flat, 2), 8.0);
        assert_eq!(t.module_sq_norm(&flat, 0), 0.0);
    }

    #[test]
    fn from_manifest_parses() {
        let j = Json::parse(
            r#"{
  "config": {"num_layers": 2},
  "total_params": 28,
  "tensors": [
    {"name": "embed", "shape": [4,2], "offset": 0, "size": 8, "stacked": false},
    {"name": "layers.b", "shape": [2,2], "offset": 8, "size": 4, "stacked": true},
    {"name": "layers.w", "shape": [2,3,2], "offset": 12, "size": 12, "stacked": true},
    {"name": "head", "shape": [2,2], "offset": 24, "size": 4, "stacked": false}
  ]}"#,
        )
        .unwrap();
        let t = ModuleTable::from_manifest(&j).unwrap();
        assert_eq!(t.total, 28);
        assert_eq!(t.num_modules(), 3);
        assert_eq!(t.tensor("layers.w").unwrap().size, 12);
    }

    #[test]
    fn from_manifest_rejects_inconsistent_total() {
        let j = Json::parse(
            r#"{"config": {"num_layers": 1}, "total_params": 99,
                "tensors": [{"name": "x", "shape": [2], "offset": 0, "size": 2, "stacked": false}]}"#,
        )
        .unwrap();
        assert!(ModuleTable::from_manifest(&j).is_err());
    }
}
