//! ZeRO-3 / FSDP-style shard arithmetic for the model shard groups.
//!
//! Parameters are sharded *uniformly* across the N workers of a model
//! shard group (paper §3.1): worker `r` owns the contiguous range
//! `[r*ceil(P/N), min((r+1)*ceil(P/N), P))` of the flat vector, with the
//! last shard possibly short.  The same spec shards the outer-optimizer
//! state (pseudo-gradient momentum) so EDiT's memory advantage over
//! CO2 is reproduced faithfully in the memory model.

/// Sharding of a flat vector of `total` elements across `parts` owners.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub total: usize,
    pub parts: usize,
}

impl ShardSpec {
    pub fn new(total: usize, parts: usize) -> Self {
        assert!(parts > 0);
        Self { total, parts }
    }

    /// Elements per full shard (ceil division).
    pub fn shard_elems(&self) -> usize {
        self.total.div_ceil(self.parts)
    }

    /// The (offset, len) of shard `r`; len may be short or 0 at the tail.
    pub fn range(&self, r: usize) -> (usize, usize) {
        assert!(r < self.parts);
        let per = self.shard_elems();
        let start = (r * per).min(self.total);
        let end = ((r + 1) * per).min(self.total);
        (start, end - start)
    }

    /// Which shard owns flat index `i`.
    pub fn owner(&self, i: usize) -> usize {
        assert!(i < self.total);
        i / self.shard_elems()
    }

    /// Slice of `flat` owned by shard `r`.
    pub fn slice<'a>(&self, flat: &'a [f32], r: usize) -> &'a [f32] {
        let (off, len) = self.range(r);
        &flat[off..off + len]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], r: usize) -> &'a mut [f32] {
        let (off, len) = self.range(r);
        &mut flat[off..off + len]
    }

    /// Bytes held per worker for one f32 copy of the sharded vector.
    pub fn bytes_per_worker(&self) -> usize {
        self.shard_elems() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition() {
        for total in [0usize, 1, 7, 16, 100, 101] {
            for parts in [1usize, 2, 3, 8] {
                let s = ShardSpec::new(total, parts);
                let mut pos = 0;
                for r in 0..parts {
                    let (off, len) = s.range(r);
                    assert_eq!(off, pos.min(total));
                    pos = off + len;
                }
                assert_eq!(pos, total, "total={total} parts={parts}");
            }
        }
    }

    #[test]
    fn owner_consistent_with_range() {
        let s = ShardSpec::new(103, 4);
        for i in 0..103 {
            let r = s.owner(i);
            let (off, len) = s.range(r);
            assert!(i >= off && i < off + len, "i={i} r={r}");
        }
    }

    #[test]
    fn uneven_tail() {
        let s = ShardSpec::new(10, 4); // per=3: 3,3,3,1
        assert_eq!(s.range(0), (0, 3));
        assert_eq!(s.range(3), (9, 1));
    }

    #[test]
    fn slice_roundtrip() {
        let s = ShardSpec::new(10, 3);
        let mut flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        s.slice_mut(&mut flat, 1).iter_mut().for_each(|x| *x = -*x);
        assert_eq!(s.slice(&flat, 1), &[-4.0, -5.0, -6.0, -7.0]);
        assert_eq!(s.slice(&flat, 0), &[0.0, 1.0, 2.0, 3.0]);
    }
}
