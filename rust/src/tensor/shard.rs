//! ZeRO-3 / FSDP-style shard arithmetic for the model shard groups.
//!
//! Parameters are sharded *uniformly* across the N workers of a model
//! shard group (paper §3.1): worker `r` owns the contiguous range
//! `[r*ceil(P/N), min((r+1)*ceil(P/N), P))` of the flat vector, with the
//! last shard possibly short.  The same spec shards the outer-optimizer
//! state (pseudo-gradient momentum) so EDiT's memory advantage over
//! CO2 is reproduced faithfully in the memory model.
//!
//! [`TableShards`] is the ZeRO-1-style counterpart used by the sharded
//! outer synchronization path: a contiguous partition of the flat space
//! whose boundaries are *snapped to `ModuleTable` range boundaries*, so
//! every per-module range is wholly owned by exactly one rank and the
//! shard-local pseudo-gradient-penalty partial sums can be folded back
//! in global range order — bitwise identical to the unsharded sweep.

use super::table::{ModuleTable, Range};

/// Sharding of a flat vector of `total` elements across `parts` owners.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub total: usize,
    pub parts: usize,
}

impl ShardSpec {
    pub fn new(total: usize, parts: usize) -> Self {
        assert!(parts > 0);
        Self { total, parts }
    }

    /// Elements per full shard (ceil division).
    pub fn shard_elems(&self) -> usize {
        self.total.div_ceil(self.parts)
    }

    /// The (offset, len) of shard `r`; len may be short or 0 at the tail.
    pub fn range(&self, r: usize) -> (usize, usize) {
        assert!(r < self.parts);
        let per = self.shard_elems();
        let start = (r * per).min(self.total);
        let end = ((r + 1) * per).min(self.total);
        (start, end - start)
    }

    /// Which shard owns flat index `i`.
    pub fn owner(&self, i: usize) -> usize {
        assert!(i < self.total);
        i / self.shard_elems()
    }

    /// Slice of `flat` owned by shard `r`.
    pub fn slice<'a>(&self, flat: &'a [f32], r: usize) -> &'a [f32] {
        let (off, len) = self.range(r);
        &flat[off..off + len]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], r: usize) -> &'a mut [f32] {
        let (off, len) = self.range(r);
        &mut flat[off..off + len]
    }

    /// Bytes held per worker for one f32 copy of the sharded vector.
    pub fn bytes_per_worker(&self) -> usize {
        self.shard_elems() * 4
    }
}

/// Range-aligned contiguous partition of a [`ModuleTable`]'s flat space
/// across `parts` owners (the sharded-outer sync path's layout).
///
/// Unlike [`ShardSpec`], boundaries never split a module range: each
/// shard is a contiguous run of whole ranges, greedily balanced toward
/// `ceil(total/parts)` elements (a shard absorbs the next range when
/// that leaves it closer to the target than stopping short). Trailing
/// shards may be empty when there are fewer ranges than parts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TableShards {
    pub total: usize,
    bounds: Vec<(usize, usize)>,
}

impl TableShards {
    pub fn from_table(table: &ModuleTable, parts: usize) -> Self {
        assert!(parts > 0);
        // All module ranges in flat order — together they partition
        // [0, total) (asserted module-table invariant).
        let mut ranges: Vec<Range> = (0..table.num_modules())
            .flat_map(|m| table.module_ranges(m))
            .collect();
        ranges.sort_by_key(|r| r.offset);
        let per = table.total.div_ceil(parts).max(1);
        let mut bounds: Vec<(usize, usize)> = Vec::with_capacity(parts);
        let mut start = 0usize;
        let mut cursor = 0usize;
        for r in &ranges {
            debug_assert_eq!(r.offset, cursor, "module ranges must partition the flat space");
            let cur = cursor - start;
            let close = bounds.len() + 1 < parts
                && cur > 0
                && (cur >= per || (cur + r.len > per && cur + r.len - per > per - cur));
            if close {
                bounds.push((start, cur));
                start = cursor;
            }
            cursor += r.len;
        }
        debug_assert_eq!(cursor, table.total);
        bounds.push((start, table.total - start));
        while bounds.len() < parts {
            bounds.push((table.total, 0));
        }
        Self { total: table.total, bounds }
    }

    pub fn parts(&self) -> usize {
        self.bounds.len()
    }

    /// The (offset, len) of shard `s`; len may be 0 at the tail.
    pub fn range(&self, s: usize) -> (usize, usize) {
        self.bounds[s]
    }

    /// Which shard owns flat offset `off` (must be < total).
    pub fn owner_of(&self, off: usize) -> usize {
        assert!(off < self.total);
        // bounds are sorted by offset and partition [0, total).
        self.bounds
            .partition_point(|&(o, l)| o + l <= off)
            .min(self.bounds.len() - 1)
    }

    /// Largest shard length (the per-rank high-water unit).
    pub fn max_len(&self) -> usize {
        self.bounds.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::table::toy_table;

    #[test]
    fn ranges_partition() {
        for total in [0usize, 1, 7, 16, 100, 101] {
            for parts in [1usize, 2, 3, 8] {
                let s = ShardSpec::new(total, parts);
                let mut pos = 0;
                for r in 0..parts {
                    let (off, len) = s.range(r);
                    assert_eq!(off, pos.min(total));
                    pos = off + len;
                }
                assert_eq!(pos, total, "total={total} parts={parts}");
            }
        }
    }

    #[test]
    fn owner_consistent_with_range() {
        let s = ShardSpec::new(103, 4);
        for i in 0..103 {
            let r = s.owner(i);
            let (off, len) = s.range(r);
            assert!(i >= off && i < off + len, "i={i} r={r}");
        }
    }

    #[test]
    fn uneven_tail() {
        let s = ShardSpec::new(10, 4); // per=3: 3,3,3,1
        assert_eq!(s.range(0), (0, 3));
        assert_eq!(s.range(3), (9, 1));
    }

    #[test]
    fn slice_roundtrip() {
        let s = ShardSpec::new(10, 3);
        let mut flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        s.slice_mut(&mut flat, 1).iter_mut().for_each(|x| *x = -*x);
        assert_eq!(s.slice(&flat, 1), &[-4.0, -5.0, -6.0, -7.0]);
        assert_eq!(s.slice(&flat, 0), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn table_shards_partition_contiguously() {
        let t = toy_table();
        for parts in [1usize, 2, 3, 4, 7, 16] {
            let s = TableShards::from_table(&t, parts);
            assert_eq!(s.parts(), parts);
            let mut pos = 0;
            for i in 0..parts {
                let (off, len) = s.range(i);
                assert_eq!(off, pos, "parts={parts} shard {i}");
                pos = off + len;
            }
            assert_eq!(pos, t.total, "parts={parts}");
        }
    }

    #[test]
    fn table_shards_never_split_a_range() {
        let t = toy_table();
        for parts in [2usize, 3, 4, 5] {
            let s = TableShards::from_table(&t, parts);
            for m in 0..t.num_modules() {
                for r in t.module_ranges(m) {
                    if r.len == 0 {
                        continue;
                    }
                    let owner = s.owner_of(r.offset);
                    let (off, len) = s.range(owner);
                    assert!(
                        r.offset >= off && r.offset + r.len <= off + len,
                        "parts={parts} module {m} range {r:?} split across shards"
                    );
                    assert_eq!(s.owner_of(r.offset + r.len - 1), owner);
                }
            }
        }
    }

    #[test]
    fn table_shards_roughly_balanced() {
        let t = toy_table();
        let s = TableShards::from_table(&t, 3);
        // Greedy target is ceil(28/3) = 10; no shard may exceed the
        // target by more than the largest single range (8).
        assert!(s.max_len() <= 10 + 8, "max {}", s.max_len());
        assert!(s.max_len() >= t.total.div_ceil(3));
    }

    #[test]
    fn table_shards_more_parts_than_ranges() {
        let t = toy_table();
        // 8 ranges total; 16 parts leaves empty tail shards but still
        // partitions exactly.
        let s = TableShards::from_table(&t, 16);
        let covered: usize = (0..16).map(|i| s.range(i).1).sum();
        assert_eq!(covered, t.total);
        assert_eq!(s.range(15).1, 0);
    }
}
