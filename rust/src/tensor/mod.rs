//! Flat-tensor substrate: all coordinator-side math runs over flat f32
//! vectors (the contract with the AOT-exported HLO programs — see
//! `python/compile/model.py`).
//!
//! Submodules:
//!  * [`kernels`]: chunked / fused SIMD-friendly vector ops — the L3
//!    hot path. The top-level functions here are thin delegates kept
//!    for API stability; `kernels::reference` holds the naive scalar
//!    oracles the fused ops are tested against;
//!  * [`table`]: the per-tensor / per-layer view over the flat vector
//!    (drives layer-wise synchronization accounting);
//!  * [`shard`]: ZeRO-3-style shard arithmetic for the model shard
//!    groups, plus the range-aligned [`TableShards`] partition behind
//!    the ZeRO-1-style sharded outer synchronization path.

pub mod kernels;
pub mod shard;
pub mod table;

pub use kernels::{PayloadKind, QUANT_CHUNK};
pub use shard::{ShardSpec, TableShards};
pub use table::{ModuleTable, TensorEntry};

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    kernels::axpy(y, alpha, x);
}

/// y = x (memcpy helper with the length check in one place)
#[inline]
pub fn copy(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    kernels::scale(x, alpha);
}

/// out = a - b  (pseudo-gradient: theta_{t,tau} - theta_t)
#[inline]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    kernels::sub(out, a, b);
}

/// Squared L2 norm, accumulated in f64 for stability at 10^7+ elements.
#[inline]
pub fn sq_norm(x: &[f32]) -> f64 {
    kernels::sq_norm(x)
}

pub fn norm(x: &[f32]) -> f64 {
    sq_norm(x).sqrt()
}

pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    kernels::dot(a, b)
}

/// `out = sum_i weights[i] * rows[i]`; rows must share a common length.
/// Norm-free variant — callers that also need ‖out‖² should use the
/// fused [`kernels::weighted_sum_sq_into`] instead of re-reducing.
pub fn weighted_sum_into(out: &mut [f32], rows: &[&[f32]], weights: &[f32]) {
    debug_assert_eq!(rows.len(), weights.len());
    out.fill(0.0);
    for (row, &w) in rows.iter().zip(weights) {
        if w != 0.0 {
            kernels::axpy(out, w, row);
        }
    }
}

/// Uniform average of rows into `out`.
pub fn mean_into(out: &mut [f32], rows: &[&[f32]]) {
    let w = 1.0 / rows.len() as f32;
    out.fill(0.0);
    for row in rows {
        kernels::axpy(out, w, row);
    }
}

/// Max |a-b| — test helper.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn sub_and_norm() {
        let mut out = vec![0.0; 3];
        sub(&mut out, &[4.0, 5.0, 6.0], &[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![3.0, 3.0, 3.0]);
        assert!((norm(&out) - 27.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sq_norm_f64_accumulation() {
        // 1e7 elements of 1e-3: f32 accumulation would drift noticeably.
        let x = vec![1e-3f32; 10_000_000];
        let got = sq_norm(&x);
        assert!((got - 10.0).abs() < 1e-6, "{got}");
    }

    #[test]
    fn weighted_sum_matches_manual() {
        let r1 = vec![1.0, 0.0];
        let r2 = vec![0.0, 2.0];
        let mut out = vec![9.0; 2];
        weighted_sum_into(&mut out, &[&r1, &r2], &[0.25, 0.5]);
        assert_eq!(out, vec![0.25, 1.0]);
    }

    #[test]
    fn mean_matches_weighted() {
        let r1 = vec![2.0, 4.0];
        let r2 = vec![4.0, 8.0];
        let mut a = vec![0.0; 2];
        let mut b = vec![0.0; 2];
        mean_into(&mut a, &[&r1, &r2]);
        weighted_sum_into(&mut b, &[&r1, &r2], &[0.5, 0.5]);
        assert_eq!(a, b);
    }

    #[test]
    fn dot_symmetry() {
        let a = vec![1.0, -2.0, 3.0];
        let b = vec![0.5, 0.25, -1.0];
        assert_eq!(dot(&a, &b), dot(&b, &a));
    }
}
