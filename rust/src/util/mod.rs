//! First-party utility substrates (the vendored dependency set contains
//! only the `xla` closure, so JSON/config/CLI/PRNG are built here —
//! Cargo.toml header note).

pub mod cfg;
pub mod cli;
pub mod json;
pub mod prng;
