//! Tiny CLI argument parser (no clap in the vendored set).
//!
//! Grammar: `edit-train <subcommand> [--flag] [--key value]... [positional]`
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// All `--set k=v` style repeated overrides (single key supported by
    /// writing `--set a=1 --set2 b=2` is NOT needed; we collect from the
    /// comma-separated value instead: `--set a=1,b=2`).
    pub fn set_overrides(&self) -> Vec<(String, String)> {
        self.opt("set")
            .map(|s| {
                s.split(',')
                    .filter_map(|kv| {
                        kv.split_once('=')
                            .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                    })
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE grammar: `--name value` is an option; a bare `--name` at
        // the end (or before another --option) is a flag. Positionals
        // therefore come before bare flags: `train out.csv --quiet`.
        let a = parse("train --config tiny --steps 100 out.csv --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str("config", ""), "tiny");
        assert_eq!(a.usize("steps", 0), 100);
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("simulate --exp=table2 --scale=7b");
        assert_eq!(a.str("exp", ""), "table2");
        assert_eq!(a.str("scale", ""), "7b");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("train --verbose");
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("verbose"), None);
    }

    #[test]
    fn set_overrides_parse() {
        let a = parse("train --set train.steps=5,mesh.rows=2");
        assert_eq!(
            a.set_overrides(),
            vec![
                ("train.steps".to_string(), "5".to_string()),
                ("mesh.rows".to_string(), "2".to_string())
            ]
        );
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.f64("phi", 10.0), 10.0);
        assert_eq!(a.u64("seed", 42), 42);
    }
}
