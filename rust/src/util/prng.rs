//! Deterministic PRNG substrate (SplitMix64 + xoshiro256**).
//!
//! Every stochastic component in the stack (data sharding, noise
//! injection, straggler lag, property tests) derives its stream from an
//! explicit seed so runs are bit-reproducible; there is no global RNG.

/// SplitMix64: used for seeding and cheap stateless hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot stateless mix, handy for hierarchical seed derivation.
#[inline]
pub fn mix(seed: u64, stream: u64) -> u64 {
    SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

/// xoshiro256**: the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child stream (e.g. per worker / per doc).
    pub fn child(&self, stream: u64) -> Self {
        Self::new(mix(self.s[0] ^ self.s[2], stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-ish reduction; the bias is
        // negligible for our n (< 2^32) and keeps the hot path branch-free.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — for property tests.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(1e-300).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn child_streams_independent() {
        let root = Rng::new(7);
        let mut c1 = root.child(0);
        let mut c2 = root.child(1);
        assert_ne!(c1.next_u64(), c2.next_u64());
        // child is a pure function of the parent state
        let mut c1b = root.child(0);
        assert_eq!(c1.next_u64(), { c1b.next_u64(); c1b.next_u64() });
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_probability() {
        let mut r = Rng::new(19);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2_200..2_800).contains(&hits), "{hits}");
    }
}
