//! TOML-subset config parser for the run-config system.
//!
//! Supports the subset the launcher needs (and nothing more):
//!   * `[section]` and `[section.sub]` headers,
//!   * `key = value` with string / integer / float / bool / inline array
//!     values, `#` comments, blank lines.
//!
//! Values land in a flat `"section.key" -> Value` map; typed getters do
//! the coercion. See `configs/*.toml` for the shipped presets.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(head) = line.strip_prefix('[') {
                let head = head
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section header", lineno + 1))?;
                section = head.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{}.{}", section, key.trim())
            };
            values.insert(
                full_key,
                parse_value(val.trim())
                    .map_err(|e| format!("line {}: {}", lineno + 1, e))?,
            );
        }
        Ok(Self { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.i64(key, default as i64).max(0) as usize
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Override a key (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<(), String> {
        self.values.insert(key.to_string(), parse_value(raw)?);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str) -> Result<Value, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err("empty value".into());
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {raw}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {raw}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {raw}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
name = "fig4"            # inline comment
[train]
steps = 300
inner_lr = 1.5e-4
use_penalty = true
[mesh]
shape = [2, 4]
[data]
noise = 0.03
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "fig4");
        assert_eq!(c.i64("train.steps", 0), 300);
        assert!((c.f64("train.inner_lr", 0.0) - 1.5e-4).abs() < 1e-12);
        assert!(c.bool("train.use_penalty", false));
        assert!((c.f64("data.noise", 0.0) - 0.03).abs() < 1e-12);
    }

    #[test]
    fn arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        match c.get("mesh.shape") {
            Some(Value::Arr(items)) => {
                assert_eq!(items, &[Value::Int(2), Value::Int(4)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.i64("missing", 7), 7);
        assert_eq!(c.str("missing", "d"), "d");
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("train.steps", "500").unwrap();
        assert_eq!(c.i64("train.steps", 0), 500);
        c.set("train.method", "\"edit\"").unwrap();
        assert_eq!(c.str("train.method", ""), "edit");
    }

    #[test]
    fn hash_in_string_kept() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }

    #[test]
    fn errors_are_positioned() {
        let err = Config::parse("x ==").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(Config::parse("[oops").is_err());
        assert!(Config::parse("k = @").is_err());
    }
}
