//! Minimal JSON substrate (parser + writer).
//!
//! The vendored dependency set has no `serde`, so the artifact manifest,
//! golden vectors, and result files go through this first-party
//! implementation. It supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null) and preserves object key
//! order (insertion order) so written files diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved via the side vector; map gives O(log n) lookup.
    Obj(Obj),
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Obj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl Obj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}
impl From<Obj> for Json {
    fn from(o: Obj) -> Self {
        Json::Obj(o)
    }
}

impl Json {
    // -- typed accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Obj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["config", "name"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.as_obj()?.get(key)?;
        }
        Some(cur)
    }

    // -- parsing --------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- writing --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(obj) => {
                out.push('{');
                for (i, key) in obj.keys().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    obj.get(key).unwrap().write(out, indent, depth + 1);
                }
                if !obj.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no inf/nan; mirror python-side convention (strings).
        let tag = if x.is_nan() {
            "nan"
        } else if x > 0.0 {
            "inf"
        } else {
            "-inf"
        };
        let _ = write!(out, "\"{tag}\"");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = Obj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Note: surrogate pairs unsupported (unused here).
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "1e3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["c"]), Some(&Json::Null));
        let arr = j.at(&["a"]).unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].at(&["b"]).unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn key_order_preserved() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn writer_escapes() {
        let mut obj = Obj::new();
        obj.insert("k", "a\"b\\c\nd");
        let s = Json::Obj(obj).to_string();
        assert_eq!(Json::parse(&s).unwrap().at(&["k"]).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let s = r#"{
 "config": {"name": "test", "vocab_size": 256},
 "total_params": 43168,
 "tensors": [{"name": "embed", "shape": [256, 32], "offset": 0, "size": 8192, "stacked": false}],
 "programs": {"train_step": "train_step.hlo.txt"}
}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.at(&["total_params"]).unwrap().as_usize(), Some(43168));
        assert_eq!(
            j.at(&["tensors"]).unwrap().as_arr().unwrap()[0]
                .at(&["stacked"])
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn nonfinite_written_as_string() {
        let mut s = String::new();
        write_num(&mut s, f64::INFINITY);
        assert_eq!(s, "\"inf\"");
    }
}
