//! `SocketComm` — the TCP socket backend of the [`Collective`] trait.
//!
//! One handle per OS process, speaking the framed protocol of
//! [`super::frame`] (normative spec: `docs/WIRE_PROTOCOL.md`) to the
//! [`super::rendezvous`] hub, which performs the rank-0..n fold. From
//! the caller's perspective this is a drop-in for `ThreadComm`'s
//! fallible surface: same trait, same `CommError` taxonomy, same
//! degraded-membership semantics, and — the property the cross-backend
//! suite asserts — bitwise-identical reduction results at matched rank
//! count, because f32 payloads travel as raw IEEE-754 bits and the hub
//! folds in the same ascending-live-rank order with the same kernels.
//!
//! # Sequencing and retries (WIRE_PROTOCOL.md §4.2–§4.3)
//!
//! Collectives are lockstep: every rank issues the same op with the
//! same sequence number. The client advances its sequence counter on
//! success and on deterministic failure (`PeerFailed`), but **not** on
//! `Timeout` — a `RetryPolicy` retry re-contributes the same sequence
//! number, and the hub deduplicates (replaying the cached result if the
//! op completed while the client was giving up). Stale frames for an
//! older sequence number are dropped on read.
//!
//! # Pipelined nonblocking ops (WIRE_PROTOCOL.md §4.2)
//!
//! The `start_*` methods issue a Contribute immediately and return a
//! [`CommHandle`]; up to [`PIPELINE_WINDOW`] ops may be in flight, each
//! at its own sequence number, and the hub folds them strictly in
//! sequence order. Draining is cooperative: waiting on any handle also
//! files Results/Errors that arrive for *other* in-flight sequence
//! numbers, and answers hub-side `Timeout` nudges by re-sending the
//! cached Contribute payload at the same seq. A blocking `try_*` op
//! first flushes the pipeline, so mixed use keeps the lockstep
//! invariant. One deliberate divergence from the blocking path: a
//! *client-side* wait timeout abandons the in-flight op (the hub still
//! completes it for the peers) — retrying means issuing a fresh op at a
//! new sequence number, not re-contributing the old one.
//!
//! # Liveness
//!
//! A background thread heartbeats over the shared writer at
//! `heartbeat_interval`, so a worker busy in a long inner-step loop is
//! never mistaken for dead; only a killed or wedged process goes
//! silent and gets evicted by the hub (timeout-then-evict).
//!
//! # Reconnect with replay (WIRE_PROTOCOL.md §6)
//!
//! A dropped connection is **not** fatal: any IO failure on the hub
//! link routes through [`SocketComm::drop_link`]-style recovery — the
//! client redials with bounded exponential backoff
//! ([`ConnectOpts::retry`]), re-Hellos with `{rank, generation, seq}`,
//! swaps the stream under the shared writer (the heartbeat thread
//! resumes automatically), discards any half-assembled frame, and
//! re-sends every unresolved contribution at its original sequence
//! number. The hub dedupes same-seq contributions and replays cached
//! results (§4.3), so recovery is value-neutral: a netdrop-faulted run
//! ends bitwise identical to a clean one. Only an explicit rejection
//! (eviction, shutdown, protocol error) or an exhausted backoff budget
//! surfaces as an error.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::collectives::frame::{
    write_frame, ErrorCode, Frame, FrameBuffer, FrameKind, OpCode, PayloadReader, PayloadWriter,
    RANK_UNASSIGNED,
};
use crate::collectives::{
    group, Collective, CommError, CommHandle, CommResult, HandleState, RetryPolicy,
    PIPELINE_WINDOW,
};

/// Client connection knobs.
#[derive(Debug, Clone, Copy)]
pub struct ConnectOpts {
    /// Window for TCP connect + the Hello/Welcome handshake (also the
    /// retry window while the hub is still binding).
    pub connect_timeout: Duration,
    /// Liveness beacon period (must undercut the hub's
    /// `heartbeat_timeout` by a healthy margin).
    pub heartbeat_interval: Duration,
    /// Reconnect policy after a dropped link (§6.1): `max_attempts`
    /// redials with exponential backoff, each re-Hello given `timeout`
    /// to complete. The budget must stay well under the hub's
    /// `heartbeat_timeout` so a transient drop recovers before the
    /// dead-peer detector evicts the rank.
    pub retry: RetryPolicy,
}

impl Default for ConnectOpts {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(10),
            heartbeat_interval: Duration::from_millis(100),
            retry: RetryPolicy {
                max_attempts: 5,
                base_backoff: Duration::from_millis(20),
                timeout: Duration::from_secs(2),
            },
        }
    }
}

/// Bytes/frames this handle moved for collective ops (heartbeats
/// excluded — they belong to liveness, not payload accounting). The
/// int8-payload wire-ratio gate measures real `tx_bytes` deltas here.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    pub tx_bytes: u64,
    pub rx_bytes: u64,
    pub tx_frames: u64,
    pub rx_frames: u64,
    /// Successful redial + re-Hello recoveries (§6.1).
    pub reconnects: u64,
}

struct OpOutcome {
    data: Vec<f32>,
}

/// How a pipelined op's result is applied to its buffer at resolution.
#[derive(Clone, Copy, PartialEq, Eq)]
enum PipeKind {
    /// Result replaces the whole buffer (empty = sole survivor, keep).
    AllReduceMean,
    /// Result replaces this rank's shard region (empty = keep).
    ReduceScatter,
    /// Result carries the concatenation; every shard region is copied.
    AllGather,
}

/// One nonblocking op in flight on the wire (WIRE_PROTOCOL.md §4.2).
struct InflightOp {
    seq: u64,
    op: OpCode,
    kind: PipeKind,
    /// Encoded Contribute payload, kept so a hub-side `Timeout` error
    /// can be answered by re-sending the **same** sequence number (the
    /// hub recreates the dropped op — §4.3 replay with a window).
    payload: Vec<u8>,
    /// Caller's buffer, owned while in flight; the hub's result is
    /// applied here and the buffer returns through `wait_handle`.
    buf: Vec<f32>,
    shards: Vec<(usize, usize)>,
    timeout: Duration,
    /// Filled when the hub's Result/Error frame for this seq lands —
    /// possibly while draining on behalf of a *different* handle.
    result: Option<CommResult<()>>,
}

#[derive(Default)]
struct Pipeline {
    ops: VecDeque<InflightOp>,
}

impl Pipeline {
    fn unresolved(&self) -> usize {
        self.ops.iter().filter(|o| o.result.is_none()).count()
    }
}

/// Socket-backed [`Collective`] handle; see the module docs.
pub struct SocketComm {
    rank: usize,
    /// Group size; grows when a Result live-mask or re-Welcome reveals
    /// a mid-run joiner (§6.3) — membership can now expand, not only
    /// degrade.
    world: Cell<usize>,
    /// Hub address, kept for redials (§6.1).
    addr: String,
    opts: ConnectOpts,
    stream: RefCell<TcpStream>,
    writer: Arc<Mutex<TcpStream>>,
    seq: Cell<u64>,
    generation: Cell<u64>,
    live_mask: Cell<u64>,
    closed: Cell<bool>,
    stats: Cell<WireStats>,
    /// Nonzero iff this handle was admitted mid-run: the seq of the
    /// admission barrier the hub mapped the late Hello onto (§6.3).
    joined_at_seq: u64,
    fb: RefCell<FrameBuffer>,
    qcodes: RefCell<Vec<i8>>,
    qscales: RefCell<Vec<f32>>,
    pipeline: RefCell<Pipeline>,
    hb_stop: Arc<AtomicBool>,
    hb: Option<JoinHandle<()>>,
}

impl SocketComm {
    /// Connect to a rendezvous hub and complete the Hello/Welcome rank
    /// assignment. Retries refused connections until `connect_timeout`
    /// elapses, so workers may race the hub's bind.
    pub fn connect(addr: &str, opts: ConnectOpts) -> io::Result<SocketComm> {
        let deadline = Instant::now() + opts.connect_timeout;
        let stream = loop {
            match try_connect(addr, Duration::from_millis(500)) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        let _ = stream.set_nodelay(true);
        {
            let mut w = &stream;
            write_frame(&mut w, &Frame::new(FrameKind::Hello, RANK_UNASSIGNED, 0, Vec::new()))?;
        }
        let welcome = read_one_frame(&stream, deadline)?;
        let (rank, world, start_seq) = match welcome.kind {
            FrameKind::Welcome => {
                let mut r = PayloadReader::new(&welcome.payload);
                let rank = r.u32()? as usize;
                let world = r.u32()? as usize;
                // start_seq (§6.3) is nonzero only for a mid-run
                // joiner; absent on pre-v2 hubs.
                let start_seq = if r.remaining() >= 8 { r.u64()? } else { 0 };
                (rank, world, start_seq)
            }
            FrameKind::Error => {
                let mut r = PayloadReader::new(&welcome.payload);
                let _seq = r.u64()?;
                let _code = r.u8()?;
                let _rank = r.u32()?;
                let msg = r.text().unwrap_or_default();
                return Err(io::Error::new(io::ErrorKind::ConnectionRefused, msg));
            }
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("expected Welcome, got {other:?}"),
                ))
            }
        };

        let writer = Arc::new(Mutex::new(stream.try_clone()?));
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb = {
            let writer = Arc::clone(&writer);
            let stop = Arc::clone(&hb_stop);
            let interval = opts.heartbeat_interval;
            let rank32 = rank as u32;
            std::thread::Builder::new()
                .name(format!("edit-hb-r{rank}"))
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        std::thread::sleep(interval);
                        let Ok(mut w) = writer.lock() else { break };
                        let frame = Frame::new(FrameKind::Heartbeat, rank32, 0, Vec::new());
                        // A write failure is NOT fatal: the link may be
                        // mid-reconnect (§6.1). Keep beating — the next
                        // tick lands on the swapped-in stream.
                        let _ = write_frame(&mut *w, &frame);
                    }
                })?
        };

        let mask = if world >= 64 { u64::MAX } else { (1u64 << world) - 1 };
        Ok(SocketComm {
            rank,
            world: Cell::new(world),
            addr: addr.to_string(),
            opts,
            stream: RefCell::new(stream),
            writer,
            seq: Cell::new(start_seq),
            generation: Cell::new(welcome.generation),
            live_mask: Cell::new(mask),
            closed: Cell::new(false),
            stats: Cell::new(WireStats::default()),
            joined_at_seq: start_seq,
            fb: RefCell::new(FrameBuffer::new()),
            qcodes: RefCell::new(Vec::new()),
            qscales: RefCell::new(Vec::new()),
            pipeline: RefCell::new(Pipeline::default()),
            hb_stop,
            hb: None,
        }
        .with_heartbeat(hb))
    }

    fn with_heartbeat(mut self, hb: JoinHandle<()>) -> Self {
        self.hb = Some(hb);
        self
    }

    /// Membership generation from the last hub frame seen.
    pub fn generation(&self) -> u64 {
        self.generation.get()
    }

    /// Live-rank bitmask from the last completed collective.
    pub fn live_mask(&self) -> u64 {
        self.live_mask.get()
    }

    /// Live rank count per the last completed collective.
    pub fn live_ranks(&self) -> usize {
        self.live_mask.get().count_ones() as usize
    }

    /// Bytes/frames moved for collective ops so far.
    pub fn wire_stats(&self) -> WireStats {
        self.stats.get()
    }

    /// Graceful leave: sends Goodbye, stops the heartbeat. Further ops
    /// return [`CommError::Shutdown`].
    pub fn close(&mut self) {
        if !self.closed.get() {
            if let Ok(mut w) = self.writer.lock() {
                let frame =
                    Frame::new(FrameKind::Goodbye, self.rank as u32, self.generation.get(), Vec::new());
                let _ = write_frame(&mut *w, &frame);
            }
            self.closed.set(true);
        }
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.hb.take() {
            let _ = h.join();
        }
    }

    /// Die abruptly: sever the TCP stream with **no** Goodbye and stop
    /// heartbeating — from the hub's side this is indistinguishable
    /// from a SIGKILLed worker process (reader EOF → reconnect grace →
    /// eviction once the grace window lapses with no re-Hello, §6.2).
    /// Exists so in-process tests can exercise the crash path; a
    /// graceful exit is [`Self::close`].
    pub fn kill(&mut self) {
        self.closed.set(true);
        self.hb_stop.store(true, Ordering::SeqCst);
        let _ = self.stream.borrow().shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.hb.take() {
            let _ = h.join();
        }
    }

    fn begin(&self, op: OpCode) -> PayloadWriter {
        let mut p = PayloadWriter::default();
        p.u8(op as u8).u64(self.seq.get());
        p
    }

    fn bump_stats(&self, tx: usize, rx: usize) {
        let mut s = self.stats.get();
        if tx > 0 {
            s.tx_bytes += tx as u64;
            s.tx_frames += 1;
        }
        if rx > 0 {
            s.rx_bytes += rx as u64;
            s.rx_frames += 1;
        }
        self.stats.set(s);
    }

    fn terminal(&self) -> CommError {
        self.closed.set(true);
        CommError::Shutdown
    }

    /// File a Result frame's live mask, growing `world` if the mask
    /// reveals ranks admitted after our Welcome (§6.3).
    fn note_mask(&self, mask: u64) {
        self.live_mask.set(mask);
        let top = (64 - mask.leading_zeros()) as usize;
        if top > self.world.get() {
            self.world.set(top);
        }
    }

    /// Raw single-frame write over the shared writer (no recovery —
    /// [`Self::recover`] builds on this and must not recurse).
    fn send_frame(&self, frame: &Frame) -> io::Result<()> {
        let Ok(mut w) = self.writer.lock() else {
            return Err(io::Error::other("writer lock poisoned"));
        };
        write_frame(&mut *w, frame)
    }

    /// Re-establish a dropped hub link (§6.1): redial with bounded
    /// exponential backoff ([`ConnectOpts::retry`]), re-Hello with
    /// `{rank, generation, seq}`, swap the stream under the shared
    /// writer (the heartbeat thread resumes on its next tick), discard
    /// any half-assembled frame bytes, and re-send every unresolved
    /// pipelined contribution in seq order. The hub dedupes same-seq
    /// contributions and replays cached results, so recovery never
    /// changes a fold (§4.3). Terminal if the hub rejects us (evicted /
    /// shutdown); `Timeout` if the backoff budget runs dry.
    ///
    /// Callers must not hold a `fb` or `stream` borrow across this
    /// call.
    fn recover(&self) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        let rp = self.opts.retry;
        for attempt in 0..rp.max_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(rp.backoff(attempt - 1));
            }
            let Ok(s) = try_connect(&self.addr, Duration::from_millis(500)) else {
                continue;
            };
            let _ = s.set_nodelay(true);
            let mut hello = PayloadWriter::default();
            hello.u32(self.rank as u32).u64(self.generation.get()).u64(self.seq.get());
            let frame = Frame::new(
                FrameKind::Hello,
                self.rank as u32,
                self.generation.get(),
                hello.finish(),
            );
            {
                let mut w = &s;
                if write_frame(&mut w, &frame).is_err() {
                    continue;
                }
            }
            let Ok(reply) = read_one_frame(&s, Instant::now() + rp.timeout) else {
                continue;
            };
            match reply.kind {
                FrameKind::Welcome => {
                    let mut r = PayloadReader::new(&reply.payload);
                    let (Ok(rank), Ok(world)) = (r.u32(), r.u32()) else {
                        return Err(self.terminal());
                    };
                    if rank as usize != self.rank {
                        return Err(self.terminal());
                    }
                    if world as usize > self.world.get() {
                        self.world.set(world as usize);
                    }
                    let Ok(clone) = s.try_clone() else { continue };
                    match self.writer.lock() {
                        Ok(mut w) => *w = clone,
                        Err(_) => return Err(self.terminal()),
                    }
                    *self.stream.borrow_mut() = s;
                    self.fb.borrow_mut().clear();
                    let mut st = self.stats.get();
                    st.reconnects += 1;
                    self.stats.set(st);
                    // Seq replay: every unresolved pipelined op goes
                    // out again at its original seq, in order.
                    let frames: Vec<Frame> = self
                        .pipeline
                        .borrow()
                        .ops
                        .iter()
                        .filter(|o| o.result.is_none())
                        .map(|o| {
                            Frame::new(
                                FrameKind::Contribute,
                                self.rank as u32,
                                self.generation.get(),
                                o.payload.clone(),
                            )
                        })
                        .collect();
                    for f in &frames {
                        if self.send_frame(f).is_err() {
                            break; // next IO failure recovers again
                        }
                        self.bump_stats(f.wire_len(), 0);
                    }
                    return Ok(());
                }
                // Error (evicted, protocol, version) or Shutdown: the
                // hub explicitly refused us — terminal, not retryable.
                FrameKind::Error | FrameKind::Shutdown => return Err(self.terminal()),
                _ => continue,
            }
        }
        Err(CommError::Timeout { op: "reconnect", waited: rp.timeout })
    }

    /// Recovery for a blocking op: reconnect, then re-send its
    /// Contribute at the same seq (idempotent at the hub, §4.3/§6.2).
    fn recover_and_resend(&self, frame: &Frame) -> CommResult<()> {
        self.recover()?;
        if self.send_frame(frame).is_err() {
            return Err(self.terminal());
        }
        self.bump_stats(frame.wire_len(), 0);
        Ok(())
    }

    /// One Contribute → Result round trip; the heart of every op.
    fn op_round(&self, op: OpCode, payload: Vec<u8>, timeout: Duration) -> CommResult<OpOutcome> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        // Blocking ops run strictly after every pipelined op: the poll
        // loop below matches only its own seq and would drop pipelined
        // results as stale.
        self.flush_pipeline(timeout)?;
        let seq = self.seq.get();
        let frame = Frame::new(FrameKind::Contribute, self.rank as u32, self.generation.get(), payload);
        if self.send_frame(&frame).is_err() {
            // Dropped link: reconnect and re-send at the same seq.
            self.recover_and_resend(&frame)?;
        } else {
            self.bump_stats(frame.wire_len(), 0);
        }

        let deadline = Instant::now() + timeout;
        loop {
            let polled = self.fb.borrow_mut().poll();
            match polled {
                Ok(Some((_v, reply))) => {
                    self.bump_stats(0, reply.wire_len());
                    self.generation.set(reply.generation);
                    match reply.kind {
                        FrameKind::Result => {
                            let parsed = (|| -> io::Result<(u64, u64, Vec<f32>)> {
                                let mut r = PayloadReader::new(&reply.payload);
                                Ok((r.u64()?, r.u64()?, r.f32s()?))
                            })();
                            let Ok((rseq, mask, data)) = parsed else {
                                return Err(self.terminal());
                            };
                            if rseq != seq {
                                continue; // stale result from a prior attempt
                            }
                            self.note_mask(mask);
                            self.seq.set(seq + 1);
                            return Ok(OpOutcome { data });
                        }
                        FrameKind::Error => {
                            let parsed = (|| -> io::Result<(u64, u8, u32)> {
                                let mut r = PayloadReader::new(&reply.payload);
                                Ok((r.u64()?, r.u8()?, r.u32()?))
                            })();
                            let Ok((eseq, code, erank)) = parsed else {
                                return Err(self.terminal());
                            };
                            match ErrorCode::from_u8(code) {
                                Some(ErrorCode::Timeout) if eseq == seq => {
                                    return Err(CommError::Timeout { op: op.name(), waited: timeout });
                                }
                                Some(ErrorCode::PeerFailed) if eseq == seq => {
                                    if erank as usize == self.rank {
                                        // The hub evicted *us*; terminal.
                                        return Err(self.terminal());
                                    }
                                    self.seq.set(seq + 1);
                                    return Err(CommError::PeerFailed { rank: erank as usize });
                                }
                                Some(ErrorCode::Timeout) | Some(ErrorCode::PeerFailed) => continue,
                                _ => return Err(self.terminal()),
                            }
                        }
                        FrameKind::Shutdown => return Err(self.terminal()),
                        _ => continue,
                    }
                }
                Ok(None) => {}
                Err(_) => return Err(self.terminal()),
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(CommError::Timeout { op: op.name(), waited: timeout });
            }
            let poll = (deadline - now).min(Duration::from_millis(50));
            let filled = {
                let s = self.stream.borrow();
                let _ = s.set_read_timeout(Some(poll.max(Duration::from_millis(1))));
                self.fb.borrow_mut().fill_from(&mut (&*s))
            };
            match filled {
                // EOF or a hard IO error: reconnect and re-contribute
                // at the same seq — the hub replays a cached Result if
                // the op completed while we were away (§6.2).
                Ok(0) => self.recover_and_resend(&frame)?,
                Ok(_) => {}
                Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
                Err(_) => self.recover_and_resend(&frame)?,
            }
        }
    }

    /// Copy a result region, failing terminally on a length mismatch
    /// (protocol corruption, not a membership event).
    fn expect_len(&self, data: &[f32], want: usize) -> CommResult<()> {
        if data.len() == want {
            Ok(())
        } else {
            Err(self.terminal())
        }
    }

    // --- pipelined nonblocking surface (WIRE_PROTOCOL.md §4.2) ------------

    /// Send one Contribute frame carrying `payload` (first send and
    /// same-seq re-sends share this path). A write failure routes
    /// through reconnect-with-replay before giving up (§6.1).
    fn send_contribute(&self, payload: &[u8]) -> CommResult<()> {
        let frame = Frame::new(
            FrameKind::Contribute,
            self.rank as u32,
            self.generation.get(),
            payload.to_vec(),
        );
        if self.send_frame(&frame).is_err() {
            self.recover()?;
            if self.send_frame(&frame).is_err() {
                return Err(self.terminal());
            }
        }
        self.bump_stats(frame.wire_len(), 0);
        Ok(())
    }

    /// Apply a Result frame's data to an in-flight op's buffer. Empty
    /// data = sole survivor: the buffer already holds the answer.
    fn apply_pipeline_result(&self, entry: &mut InflightOp, data: &[f32]) -> CommResult<()> {
        if data.is_empty() {
            return Ok(());
        }
        match entry.kind {
            PipeKind::AllReduceMean => {
                self.expect_len(data, entry.buf.len())?;
                entry.buf.copy_from_slice(data);
            }
            PipeKind::ReduceScatter => {
                let (off, len) = entry.shards[self.rank];
                self.expect_len(data, len)?;
                entry.buf[off..off + len].copy_from_slice(data);
            }
            PipeKind::AllGather => {
                for &(o, l) in &entry.shards {
                    if o + l > data.len() {
                        return Err(self.terminal());
                    }
                    entry.buf[o..o + l].copy_from_slice(&data[o..o + l]);
                }
            }
        }
        Ok(())
    }

    /// Drain hub frames against the pipeline until `done` holds or
    /// `timeout` passes. Results/errors land on whichever in-flight op
    /// their seq names (not just the one being waited on); hub-side
    /// `Timeout` errors for an unresolved op re-send its contribution at
    /// the same seq.
    fn pump_until(
        &self,
        opname: &'static str,
        timeout: Duration,
        done: impl Fn(&Pipeline) -> bool,
    ) -> CommResult<()> {
        let deadline = Instant::now() + timeout;
        loop {
            if done(&self.pipeline.borrow()) {
                return Ok(());
            }
            if self.closed.get() {
                return Err(CommError::Shutdown);
            }
            let polled = self.fb.borrow_mut().poll();
            match polled {
                Ok(Some((_v, reply))) => {
                    self.bump_stats(0, reply.wire_len());
                    self.generation.set(reply.generation);
                    match reply.kind {
                        FrameKind::Result => {
                            let parsed = (|| -> io::Result<(u64, u64, Vec<f32>)> {
                                let mut r = PayloadReader::new(&reply.payload);
                                Ok((r.u64()?, r.u64()?, r.f32s()?))
                            })();
                            let Ok((rseq, mask, data)) = parsed else {
                                return Err(self.terminal());
                            };
                            let mut pl = self.pipeline.borrow_mut();
                            if let Some(entry) =
                                pl.ops.iter_mut().find(|o| o.seq == rseq && o.result.is_none())
                            {
                                self.note_mask(mask);
                                let applied = self.apply_pipeline_result(entry, &data);
                                entry.result = Some(applied);
                            }
                            // Unknown seq: a replay for an op some prior
                            // attempt already resolved — drop it.
                        }
                        FrameKind::Error => {
                            let parsed = (|| -> io::Result<(u64, u8, u32)> {
                                let mut r = PayloadReader::new(&reply.payload);
                                Ok((r.u64()?, r.u8()?, r.u32()?))
                            })();
                            let Ok((eseq, code, erank)) = parsed else {
                                return Err(self.terminal());
                            };
                            match ErrorCode::from_u8(code) {
                                Some(ErrorCode::Timeout) => {
                                    let payload = self
                                        .pipeline
                                        .borrow()
                                        .ops
                                        .iter()
                                        .find(|o| o.seq == eseq && o.result.is_none())
                                        .map(|o| o.payload.clone());
                                    if let Some(p) = payload {
                                        self.send_contribute(&p)?;
                                    }
                                }
                                Some(ErrorCode::PeerFailed) => {
                                    if erank as usize == self.rank {
                                        // The hub evicted *us*; terminal.
                                        return Err(self.terminal());
                                    }
                                    let mut pl = self.pipeline.borrow_mut();
                                    if let Some(entry) = pl
                                        .ops
                                        .iter_mut()
                                        .find(|o| o.seq == eseq && o.result.is_none())
                                    {
                                        entry.result = Some(Err(CommError::PeerFailed {
                                            rank: erank as usize,
                                        }));
                                    }
                                }
                                _ => return Err(self.terminal()),
                            }
                        }
                        FrameKind::Shutdown => return Err(self.terminal()),
                        _ => {}
                    }
                    continue;
                }
                Ok(None) => {}
                Err(_) => return Err(self.terminal()),
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(CommError::Timeout { op: opname, waited: timeout });
            }
            let poll = (deadline - now).min(Duration::from_millis(50));
            let filled = {
                let s = self.stream.borrow();
                let _ = s.set_read_timeout(Some(poll.max(Duration::from_millis(1))));
                self.fb.borrow_mut().fill_from(&mut (&*s))
            };
            match filled {
                // EOF / hard IO error: reconnect; `recover` re-sends
                // every unresolved pipelined contribution itself.
                Ok(0) => self.recover()?,
                Ok(_) => {}
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
                Err(_) => self.recover()?,
            }
        }
    }

    /// Resolve every in-flight pipelined op (results stay stashed on
    /// their entries for later `wait_handle` calls).
    fn flush_pipeline(&self, timeout: Duration) -> CommResult<()> {
        if self.pipeline.borrow().unresolved() == 0 {
            return Ok(());
        }
        self.pump_until("pipeline.flush", timeout, |pl| pl.unresolved() == 0)
    }

    /// Issue one pipelined op: free a window slot if needed, encode the
    /// Contribute at the current seq, send, advance the seq. `encode`
    /// writes the op-specific payload after the `(op, seq)` header.
    fn start_pipelined(
        &self,
        op: OpCode,
        kind: PipeKind,
        buf: Vec<f32>,
        shards: Vec<(usize, usize)>,
        timeout: Duration,
        encode: impl FnOnce(&mut PayloadWriter, &[f32], &[(usize, usize)]),
    ) -> CommHandle {
        if self.closed.get() {
            return CommHandle::ready(Err(CommError::Shutdown));
        }
        if self.world.get() == 1 {
            // Degenerate group: the op is a no-op on the wire (weighted
            // is special-cased by its caller before reaching here).
            return CommHandle::ready(Ok(buf));
        }
        // Backpressure: at most PIPELINE_WINDOW unresolved ops.
        if let Err(e) = self.pump_until(op.name(), timeout, |pl| pl.unresolved() < PIPELINE_WINDOW)
        {
            return CommHandle::ready(Err(e));
        }
        // Garbage-collect long-resolved entries whose handles were
        // dropped without a wait (the op itself completed; only the
        // result pickup was abandoned).
        {
            let mut pl = self.pipeline.borrow_mut();
            let cur = self.seq.get();
            pl.ops.retain(|o| o.result.is_none() || o.seq + 64 > cur);
        }
        let mut p = self.begin(op);
        encode(&mut p, &buf, &shards);
        let payload = p.finish();
        if let Err(e) = self.send_contribute(&payload) {
            return CommHandle::ready(Err(e));
        }
        let seq = self.seq.get();
        self.seq.set(seq + 1);
        self.pipeline.borrow_mut().ops.push_back(InflightOp {
            seq,
            op,
            kind,
            payload,
            buf,
            shards,
            timeout,
            result: None,
        });
        CommHandle::socket(seq)
    }

    /// Complete the pipelined op issued at `seq` and hand its buffer
    /// back. A client-side timeout abandons the op (the hub still
    /// completes it for the peers); retrying means issuing a fresh op.
    fn wait_seq(&self, seq: u64) -> CommResult<Vec<f32>> {
        let (opname, timeout) = {
            let pl = self.pipeline.borrow();
            match pl.ops.iter().find(|o| o.seq == seq) {
                Some(o) => (o.op.name(), o.timeout),
                // Unknown handle: pruned after a drop, or foreign.
                None => return Err(CommError::Shutdown),
            }
        };
        let pumped = self.pump_until(opname, timeout, |pl| {
            pl.ops.iter().find(|o| o.seq == seq).is_none_or(|o| o.result.is_some())
        });
        let mut pl = self.pipeline.borrow_mut();
        let Some(idx) = pl.ops.iter().position(|o| o.seq == seq) else {
            return Err(pumped.err().unwrap_or(CommError::Shutdown));
        };
        let entry = pl.ops.remove(idx).expect("indexed inflight op");
        match entry.result {
            Some(Ok(())) => Ok(entry.buf),
            Some(Err(e)) => Err(e),
            None => Err(pumped.err().unwrap_or(CommError::Shutdown)),
        }
    }
}

impl Drop for SocketComm {
    fn drop(&mut self) {
        self.close();
    }
}

fn try_connect(addr: &str, per_addr: Duration) -> io::Result<TcpStream> {
    let mut last = io::Error::new(io::ErrorKind::AddrNotAvailable, format!("no addresses for {addr}"));
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, per_addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// Blocking single-frame read with a deadline (handshake path).
fn read_one_frame(stream: &TcpStream, deadline: Instant) -> io::Result<Frame> {
    let mut fb = FrameBuffer::new();
    let mut src = stream;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        if let Some((_v, f)) = fb.poll()? {
            return Ok(f);
        }
        if Instant::now() >= deadline {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "handshake read timed out"));
        }
        match fb.fill_from(&mut src) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "hub closed")),
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
            Err(e) => return Err(e),
        }
    }
}

impl Collective for SocketComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.world.get()
    }

    fn drop_link(&self) {
        // Sever the TCP link without marking the comm closed: the next
        // op's IO failure routes through `recover` (§6.1). This is the
        // `FaultKind::NetDrop` injection point.
        let _ = self.stream.borrow().shutdown(std::net::Shutdown::Both);
    }

    fn late_joiner(&self) -> bool {
        self.joined_at_seq > 0
    }

    fn try_barrier(&self, timeout: Duration) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let payload = self.begin(OpCode::Barrier).finish();
        self.op_round(OpCode::Barrier, payload, timeout).map(|_| ())
    }

    fn try_all_reduce_mean(&self, buf: &mut [f32], timeout: Duration) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let mut p = self.begin(OpCode::AllReduceMean);
        p.f32s(buf);
        let out = self.op_round(OpCode::AllReduceMean, p.finish(), timeout)?;
        if out.data.is_empty() {
            return Ok(()); // sole survivor: own contribution is the mean
        }
        self.expect_len(&out.data, buf.len())?;
        buf.copy_from_slice(&out.data);
        Ok(())
    }

    fn try_all_gather(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let (off, len) = shards[self.rank];
        let mut p = self.begin(OpCode::AllGather);
        p.shards(shards).f32s(&full[off..off + len]);
        let out = self.op_round(OpCode::AllGather, p.finish(), timeout)?;
        if out.data.is_empty() {
            return Ok(());
        }
        for &(o, l) in shards {
            if o + l > out.data.len() {
                return Err(self.terminal());
            }
            full[o..o + l].copy_from_slice(&out.data[o..o + l]);
        }
        Ok(())
    }

    fn try_reduce_scatter_mean(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.rs_f32(OpCode::ReduceScatterMean, full, shards, timeout)
    }

    fn try_reduce_scatter_sum(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.rs_f32(OpCode::ReduceScatterSum, full, shards, timeout)
    }

    fn try_reduce_scatter_weighted(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        let (off, len) = shards[self.rank];
        if self.world.get() == 1 {
            // Degenerate group: the reference's zero-init single fold.
            let w = weights[0];
            for x in full[off..off + len].iter_mut() {
                let mut acc = 0.0f32;
                if w != 0.0 {
                    acc += w * *x;
                }
                *x = acc;
            }
            return Ok(());
        }
        let mut p = self.begin(OpCode::ReduceScatterWeighted);
        p.shards(shards).f32s(weights).f32s(full);
        let out = self.op_round(OpCode::ReduceScatterWeighted, p.finish(), timeout)?;
        self.expect_len(&out.data, len)?;
        full[off..off + len].copy_from_slice(&out.data);
        Ok(())
    }

    fn try_reduce_scatter_mean_q8(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let (off, len) = shards[self.rank];
        let mut p = self.begin(OpCode::ReduceScatterMeanQ8);
        {
            let mut codes = self.qcodes.borrow_mut();
            let mut scales = self.qscales.borrow_mut();
            group::quantize_int8_into(full, &mut codes, &mut scales);
            p.shards(shards).u32(full.len() as u32).i8s(&codes).f32s(&scales);
        }
        let out = self.op_round(OpCode::ReduceScatterMeanQ8, p.finish(), timeout)?;
        if out.data.is_empty() {
            return Ok(());
        }
        self.expect_len(&out.data, len)?;
        full[off..off + len].copy_from_slice(&out.data);
        Ok(())
    }

    fn try_broadcast(&self, buf: &mut [f32], root: usize, timeout: Duration) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let mut p = self.begin(OpCode::Broadcast);
        p.u32(root as u32);
        if self.rank == root {
            p.u8(1).f32s(buf);
        } else {
            p.u8(0);
        }
        let out = self.op_round(OpCode::Broadcast, p.finish(), timeout)?;
        if self.rank != root && !out.data.is_empty() {
            self.expect_len(&out.data, buf.len())?;
            buf.copy_from_slice(&out.data);
        }
        Ok(())
    }

    fn start_all_reduce_mean(&self, buf: Vec<f32>, timeout: Duration) -> CommHandle {
        self.start_pipelined(
            OpCode::AllReduceMean,
            PipeKind::AllReduceMean,
            buf,
            Vec::new(),
            timeout,
            |p, full, _| {
                p.f32s(full);
            },
        )
    }

    fn start_reduce_scatter_mean(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        self.start_pipelined(
            OpCode::ReduceScatterMean,
            PipeKind::ReduceScatter,
            full,
            shards.to_vec(),
            timeout,
            |p, full, shards| {
                p.shards(shards).f32s(full);
            },
        )
    }

    fn start_reduce_scatter_mean_q8(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        self.start_pipelined(
            OpCode::ReduceScatterMeanQ8,
            PipeKind::ReduceScatter,
            full,
            shards.to_vec(),
            timeout,
            |p, full, shards| {
                let mut codes = self.qcodes.borrow_mut();
                let mut scales = self.qscales.borrow_mut();
                group::quantize_int8_into(full, &mut codes, &mut scales);
                p.shards(shards).u32(full.len() as u32).i8s(&codes).f32s(&scales);
            },
        )
    }

    fn start_reduce_scatter_weighted(
        &self,
        mut full: Vec<f32>,
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommHandle {
        if self.closed.get() {
            return CommHandle::ready(Err(CommError::Shutdown));
        }
        if self.world.get() == 1 {
            // Degenerate group: the reference's zero-init single fold —
            // a real computation even alone, unlike the other ops.
            let (off, len) = shards[self.rank];
            let w = weights[0];
            for x in full[off..off + len].iter_mut() {
                let mut acc = 0.0f32;
                if w != 0.0 {
                    acc += w * *x;
                }
                *x = acc;
            }
            return CommHandle::ready(Ok(full));
        }
        let weights = weights.to_vec();
        self.start_pipelined(
            OpCode::ReduceScatterWeighted,
            PipeKind::ReduceScatter,
            full,
            shards.to_vec(),
            timeout,
            move |p, full, shards| {
                p.shards(shards).f32s(&weights).f32s(full);
            },
        )
    }

    fn start_all_gather(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        self.start_pipelined(
            OpCode::AllGather,
            PipeKind::AllGather,
            full,
            shards.to_vec(),
            timeout,
            |p, full, shards| {
                let (off, len) = shards[self.rank];
                p.shards(shards).f32s(&full[off..off + len]);
            },
        )
    }

    fn wait_handle(&self, mut handle: CommHandle) -> CommResult<Vec<f32>> {
        match handle.state.take() {
            Some(HandleState::Ready(r)) => r,
            Some(HandleState::Socket(seq)) => self.wait_seq(seq),
            Some(HandleState::Thread(_)) => {
                panic!("thread CommHandle waited on a socket backend")
            }
            None => Err(CommError::Shutdown),
        }
    }
}

impl SocketComm {
    fn rs_f32(
        &self,
        op: OpCode,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        if self.closed.get() {
            return Err(CommError::Shutdown);
        }
        if self.world.get() == 1 {
            return Ok(());
        }
        let (off, len) = shards[self.rank];
        let mut p = self.begin(op);
        p.shards(shards).f32s(full);
        let out = self.op_round(op, p.finish(), timeout)?;
        if out.data.is_empty() {
            return Ok(()); // sole survivor: region untouched
        }
        self.expect_len(&out.data, len)?;
        full[off..off + len].copy_from_slice(&out.data);
        Ok(())
    }
}
