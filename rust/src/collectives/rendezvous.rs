//! Rendezvous + membership service for the socket transport.
//!
//! The hub is the socket counterpart of [`super::thread`]'s condvar
//! gate: workers connect, a `Hello`/`Welcome` exchange assigns ranks in
//! arrival order (WIRE_PROTOCOL.md §4.1), and every collective is a
//! `Contribute` → `Result` round trip through the hub, which performs
//! the rank-0..n fold itself. Hub-side reduction is what makes the
//! fold-order contract trivial to uphold over a network: contributions
//! are folded over the **live ranks in ascending rank order** with a
//! zero-initialized accumulator, exactly the degraded-group semantics of
//! `ThreadComm`'s fallible surface, so socket and in-process backends
//! stay bitwise interchangeable.
//!
//! # Membership, generations, and the failure taxonomy
//!
//! Liveness is generation-counted: every eviction, graceful leave, or
//! mid-run admission bumps the membership epoch, and every hub frame
//! carries the current generation plus a live-rank bitmask (world ≤
//! 64). Dead peers are detected two ways, both mapping onto the
//! in-process `CommError` taxonomy (timeout-then-evict, PR 5's policy):
//!
//!  * **connection loss** — a reader hitting EOF/reset marks the rank
//!    *disconnected* and starts a reconnect grace window of
//!    `heartbeat_timeout` (§6.2). A rank that redials and re-Hellos in
//!    time reattaches with no membership event at all; one that does
//!    not is evicted, and a pending op either completes over the
//!    survivors or resolves `PeerFailed` if the dead rank was
//!    structurally required (broadcast root, all-gather shard owner).
//!  * **silence** — when a pending op exceeds the op window, live
//!    non-contributors whose heartbeat is stale get evicted; everyone
//!    else receives a retryable `Timeout` error frame and re-contributes
//!    (the wire mirror of `RetryPolicy`).
//!
//! # Reconnect, replay, and late join (WIRE_PROTOCOL.md §6)
//!
//! The listener stays open after the initial group forms. A dial that
//! re-Hellos with `{rank, generation, last_seq}` is a **reconnect**:
//! the hub swaps the rank's writer, re-Welcomes it, and relies on §4.3
//! same-seq idempotency to absorb whatever the client re-sends. A dial
//! that Hellos with an empty payload is a **late join**: it waits in
//! the lobby until the next *new* `Barrier` op opens, at which point it
//! is admitted as the next rank, participates in that very barrier
//! (its `Welcome` carries the barrier's seq as `start_seq`), and the
//! generation bumps. Ops opened before a rank joined neither wait for
//! nor answer it — completion is filtered by each rank's join seq.
//!
//! # Pipelined ops and duplicate contributions
//!
//! The hub accepts a bounded **window** of in-flight ops (§4.2): a
//! pipelined client contributes seq k+1 (and beyond) before seq k has
//! resolved. Contributions are filed by sequence number; ops complete
//! strictly in sequence order (only the head of the window can fold),
//! and only the head is on the op-timeout clock. A client whose local
//! timeout fires just before the result lands will retry the same
//! sequence number: the hub caches the last resolved ops' per-rank
//! response frames and replays them on a duplicate `Contribute`, so
//! client-side retries stay idempotent with multiple ops in flight
//! (§4.3). Reconnect replay (§6.2) is the same machinery: a rejoining
//! client re-sends its unresolved contributions at their original
//! sequence numbers and the hub files or replays each one.

use std::collections::VecDeque;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::collectives::frame::{
    write_frame, ErrorCode, Frame, FrameBuffer, FrameKind, OpCode, PayloadReader, PayloadWriter,
    PROTOCOL_VERSION, RANK_UNASSIGNED,
};
use crate::tensor::{kernels, QUANT_CHUNK};

/// Hub tuning knobs. Defaults suit loopback tests; real deployments
/// stretch the windows.
#[derive(Debug, Clone, Copy)]
pub struct RendezvousConfig {
    /// Ranks expected to join before collectives begin.
    pub world: usize,
    /// Join window: how long `bind` waits for `world` Hellos.
    pub accept_timeout: Duration,
    /// Quorum window per collective before Timeout frames go out.
    pub op_timeout: Duration,
    /// Heartbeat staleness beyond which a silent, op-blocking rank is
    /// evicted (must exceed the client heartbeat interval). Doubles as
    /// the reconnect grace window: a disconnected rank that has not
    /// re-Helloed within this span is declared dead (§6.2).
    pub heartbeat_timeout: Duration,
}

impl Default for RendezvousConfig {
    fn default() -> Self {
        Self {
            world: 2,
            accept_timeout: Duration::from_secs(30),
            op_timeout: Duration::from_secs(2),
            heartbeat_timeout: Duration::from_secs(3),
        }
    }
}

/// What the service did, returned by [`Rendezvous::wait`].
#[derive(Debug, Clone, Default)]
pub struct RendezvousReport {
    /// Ranks that completed a handshake (initial group + late joiners;
    /// reconnects do not recount).
    pub joined: usize,
    /// Final membership generation (0 = no membership change ever).
    pub generations: u64,
    /// Ranks evicted as dead peers, in eviction order.
    pub evicted: Vec<usize>,
    /// Collectives resolved successfully.
    pub ops_done: u64,
}

/// Handle to a running hub. Dropping it shuts the service down.
pub struct Rendezvous {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<RendezvousReport>>,
}

impl Rendezvous {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve one `world`-rank
    /// group in a background thread.
    pub fn bind(addr: &str, cfg: RendezvousConfig) -> io::Result<Rendezvous> {
        assert!(cfg.world >= 1, "rendezvous world must be at least 1");
        assert!(cfg.world <= 64, "live-mask is a u64: world must be <= 64");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("edit-rendezvous".into())
            .spawn(move || serve(listener, cfg, flag))?;
        Ok(Rendezvous { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the service to tear down: live peers receive `Shutdown`
    /// frames, pending ops resolve with `Shutdown` errors.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the service exits (all ranks done, or shutdown).
    pub fn wait(&mut self) -> RendezvousReport {
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => RendezvousReport::default(),
        }
    }
}

impl Drop for Rendezvous {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Hub internals
// ---------------------------------------------------------------------------

/// One contribution's decoded operands. A plain bag rather than a
/// per-op enum: only the fields the op reads are filled.
#[derive(Default, Clone)]
struct Contrib {
    shards: Vec<(usize, usize)>,
    weights: Vec<f32>,
    root: u32,
    data: Vec<f32>,
    codes: Vec<i8>,
    scales: Vec<f32>,
    total_len: usize,
}

struct Pending {
    seq: u64,
    op: OpCode,
    started: Instant,
    /// Indexed by rank; its length snapshots the member count at the
    /// op's seq (ranks admitted later never appear — see
    /// [`HubState::participants`]).
    contribs: Vec<Option<Contrib>>,
}

/// Cached per-rank responses of a resolved op, replayed on duplicate
/// contributions (client retried after a local timeout, or re-sent its
/// window after a reconnect — §6.2).
struct Completed {
    seq: u64,
    frames: Vec<Option<Frame>>,
}

/// How many ops the hub accepts concurrently (WIRE_PROTOCOL.md §4.2):
/// pipelined clients keep at most [`crate::collectives::PIPELINE_WINDOW`]
/// in flight; the hub window is wider so a retried (recreated) op plus a
/// full client window still fit. The replay cache keeps this many
/// resolved ops too.
const HUB_WINDOW: usize = 8;

struct HubState {
    alive: Vec<bool>,
    done: Vec<bool>,
    /// Whether the rank's TCP link is currently attached. A rank can be
    /// alive but disconnected (inside the reconnect grace window).
    connected: Vec<bool>,
    /// Bumped on every reconnect; readers carry the epoch they were
    /// spawned at so a superseded reader's EOF cannot disturb the rank
    /// that already reattached.
    conn_epoch: Vec<u64>,
    /// Seq of the first op each rank participates in: 0 for founding
    /// members, the admission barrier's seq for late joiners (§6.3).
    /// Nondecreasing in rank order — admission order is seq order.
    joined_at: Vec<u64>,
    /// When the rank's link was last lost (meaningful while
    /// `!connected`): the reconnect grace clock.
    disconnected_at: Vec<Instant>,
    last_seen: Vec<Instant>,
    generation: u64,
    evicted: Vec<usize>,
    /// In-flight ops, ascending by seq. Only the **front** may resolve
    /// (completion is strictly in sequence order) and only the front is
    /// subject to the op-timeout window.
    pending: VecDeque<Pending>,
    /// Replay cache of the last [`HUB_WINDOW`] resolved ops.
    completed: VecDeque<Completed>,
    /// The sequence number the next *new* op must carry. A contribution
    /// below this that matches neither a pending nor a cached op is a
    /// retry of a timed-out op and recreates it; above is a protocol
    /// violation (the client skipped a sequence number).
    next_new_seq: u64,
    ops_done: u64,
    /// Handshakes completed (initial + late joins), for the report.
    joined: usize,
    shutdown: bool,
    /// Fresh-join dials waiting for the next new Barrier to open
    /// (§6.3); their Welcome is deferred until admission.
    lobby: Vec<TcpStream>,
}

struct Peer {
    writer: Mutex<TcpStream>,
}

struct Hub {
    cfg: RendezvousConfig,
    /// One writer per rank; swapped on reconnect, grown on admission.
    peers: Mutex<Vec<Arc<Peer>>>,
    state: Mutex<HubState>,
    /// Reader threads (one per live link), joined at teardown.
    readers: Mutex<Vec<JoinHandle<()>>>,
}

impl HubState {
    fn live_ranks(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&r| self.alive[r]).collect()
    }

    fn live_mask(&self) -> u64 {
        self.alive
            .iter()
            .enumerate()
            .fold(0u64, |m, (r, &a)| if a { m | (1u64 << r) } else { m })
    }

    fn all_finished(&self) -> bool {
        (0..self.alive.len()).all(|r| self.done[r] || !self.alive[r])
    }

    /// Live ranks that belong to op `p`: admitted at or before its seq.
    /// Always a prefix of the rank space (`joined_at` is nondecreasing),
    /// so every returned rank indexes `p.contribs`.
    fn participants(&self, p: &Pending) -> Vec<usize> {
        (0..self.alive.len())
            .filter(|&r| self.alive[r] && self.joined_at[r] <= p.seq)
            .collect()
    }

    /// Member count at sequence number `seq` (alive or dead): the world
    /// size ops at that seq were shaped for.
    fn members_at(&self, seq: u64) -> usize {
        (0..self.alive.len()).filter(|&r| self.joined_at[r] <= seq).count()
    }
}

fn send_to(hub: &Hub, rank: usize, frame: &Frame) {
    // Write failures surface as the reader thread's EOF → reconnect
    // grace; no point double-reporting here.
    let peer = hub.peers.lock().ok().and_then(|ps| ps.get(rank).cloned());
    if let Some(peer) = peer {
        if let Ok(mut w) = peer.writer.lock() {
            let _ = write_frame(&mut *w, frame);
        }
    }
}

/// One-shot reply on a not-yet-registered stream (handshake paths).
fn reply(stream: &TcpStream, frame: &Frame) {
    let mut w = stream;
    let _ = write_frame(&mut w, frame);
}

fn error_frame(generation: u64, seq: u64, code: ErrorCode, rank: u32, msg: &str) -> Frame {
    let mut p = PayloadWriter::default();
    p.u64(seq).u8(code as u8).u32(rank).text(msg);
    Frame::new(FrameKind::Error, RANK_UNASSIGNED, generation, p.finish())
}

fn result_frame(generation: u64, seq: u64, live_mask: u64, data: &[f32]) -> Frame {
    let mut p = PayloadWriter::default();
    p.u64(seq).u64(live_mask).f32s(data);
    Frame::new(FrameKind::Result, RANK_UNASSIGNED, generation, p.finish())
}

/// Welcome payload (§3.2/§6.3): `{rank, world, start_seq}`.
fn welcome_frame(generation: u64, rank: usize, world: usize, start_seq: u64) -> Frame {
    let mut p = PayloadWriter::default();
    p.u32(rank as u32).u32(world as u32).u64(start_seq);
    Frame::new(FrameKind::Welcome, rank as u32, generation, p.finish())
}

/// Decode a Contribute payload into `(seq, op, operands)`.
fn parse_contribute(payload: &[u8]) -> io::Result<(u64, OpCode, Contrib)> {
    let mut r = PayloadReader::new(payload);
    let op = OpCode::from_u8(r.u8()?)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "unknown op code"))?;
    let seq = r.u64()?;
    let mut c = Contrib::default();
    match op {
        OpCode::Barrier => {}
        OpCode::AllReduceMean => c.data = r.f32s()?,
        OpCode::AllGather | OpCode::ReduceScatterMean | OpCode::ReduceScatterSum => {
            c.shards = r.shards()?;
            c.data = r.f32s()?;
        }
        OpCode::ReduceScatterWeighted => {
            c.shards = r.shards()?;
            c.weights = r.f32s()?;
            c.data = r.f32s()?;
        }
        OpCode::ReduceScatterMeanQ8 => {
            c.shards = r.shards()?;
            c.total_len = r.u32()? as usize;
            c.codes = r.i8s()?;
            c.scales = r.f32s()?;
        }
        OpCode::Broadcast => {
            c.root = r.u32()?;
            if r.u8()? != 0 {
                c.data = r.f32s()?;
            }
        }
    }
    Ok((seq, op, c))
}

fn shard_extent(shards: &[(usize, usize)]) -> usize {
    shards.iter().map(|&(o, l)| o + l).max().unwrap_or(0)
}

/// Structural validation of one contribution (shape only — the hub
/// never judges values). `world` is the member count at the op's seq.
/// Returns a protocol complaint on violation.
fn validate_contrib(
    op: OpCode,
    rank: usize,
    world: usize,
    c: &Contrib,
    meta: Option<&Contrib>,
) -> Result<(), String> {
    if !c.shards.is_empty() && c.shards.len() != world {
        return Err(format!("shard table has {} entries, world is {world}", c.shards.len()));
    }
    match op {
        OpCode::Barrier => {}
        OpCode::AllReduceMean => {
            if let Some(m) = meta {
                if c.data.len() != m.data.len() {
                    return Err("all_reduce operand length mismatch across ranks".into());
                }
            }
        }
        OpCode::AllGather => {
            let (_, len) = c.shards.get(rank).copied().unwrap_or((0, 0));
            if c.data.len() != len {
                return Err(format!("all_gather shard payload is {} elems, own shard is {len}", c.data.len()));
            }
        }
        OpCode::ReduceScatterMean | OpCode::ReduceScatterSum | OpCode::ReduceScatterWeighted => {
            if c.data.len() < shard_extent(&c.shards) {
                return Err("reduce_scatter operand shorter than shard extent".into());
            }
            if op == OpCode::ReduceScatterWeighted && c.weights.len() != world {
                return Err(format!("weight table has {} entries, world is {world}", c.weights.len()));
            }
        }
        OpCode::ReduceScatterMeanQ8 => {
            if c.codes.len() != c.total_len
                || c.scales.len() != c.total_len.div_ceil(QUANT_CHUNK)
                || c.total_len < shard_extent(&c.shards)
            {
                return Err("q8 payload shape inconsistent".into());
            }
        }
        OpCode::Broadcast => {
            if rank as u32 == c.root && c.data.is_empty() {
                return Err("broadcast root sent no payload".into());
            }
        }
    }
    if let Some(m) = meta {
        if c.shards != m.shards {
            return Err("shard tables differ across ranks".into());
        }
        if c.weights.len() != m.weights.len()
            || c.weights.iter().zip(&m.weights).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err("weight tables differ across ranks".into());
        }
        if op == OpCode::Broadcast && c.root != m.root {
            return Err("broadcast roots differ across ranks".into());
        }
    }
    Ok(())
}

/// Evict `rank` (reconnect grace expired, or op-blocking silence):
/// membership epoch bumps, its pending contribution is dropped (a
/// reduction never folds a dead rank, even one that contributed before
/// dying — the same fold-time liveness check as `ThreadComm`), and the
/// pending window is drained front-first, so every op the dead rank had
/// pipelined resolves deterministically for the survivors.
fn evict(hub: &Hub, st: &mut HubState, rank: usize) {
    if !st.alive[rank] {
        return;
    }
    st.alive[rank] = false;
    st.generation += 1;
    st.evicted.push(rank);
    for p in st.pending.iter_mut() {
        if let Some(c) = p.contribs.get_mut(rank) {
            *c = None;
        }
    }
    try_complete(hub, st);
}

/// Graceful leave: membership shrinks without counting as a failure.
fn leave(hub: &Hub, st: &mut HubState, rank: usize) {
    if st.done[rank] {
        return;
    }
    st.done[rank] = true;
    if st.alive[rank] {
        st.alive[rank] = false;
        st.generation += 1;
    }
    for p in st.pending.iter_mut() {
        if let Some(c) = p.contribs.get_mut(rank) {
            *c = None;
        }
    }
    try_complete(hub, st);
}

/// Reader-side link loss at `epoch`: start the reconnect grace clock
/// (`hard = false`, EOF/reset) or evict outright (`hard = true`, a
/// protocol-corrupt stream). A superseded epoch is a no-op — the rank
/// already reattached and a newer reader owns it.
fn link_failed(hub: &Hub, rank: usize, epoch: u64, hard: bool) {
    let mut st = hub.state.lock().unwrap();
    if st.conn_epoch[rank] != epoch {
        return;
    }
    if hard {
        evict(hub, &mut st, rank);
    } else if st.connected[rank] {
        st.connected[rank] = false;
        st.disconnected_at[rank] = Instant::now();
    }
}

/// Cache a resolved op's frames for duplicate replay, evicting the
/// oldest beyond [`HUB_WINDOW`].
fn cache_completed(st: &mut HubState, done: Completed) {
    st.completed.push_back(done);
    while st.completed.len() > HUB_WINDOW {
        st.completed.pop_front();
    }
}

/// Pop the resolved front op and restart the next head's op-timeout
/// clock (a queued op's window counts from when it reaches the head of
/// the line, not from its first contribution).
fn pop_front_pending(st: &mut HubState) -> Pending {
    let p = st.pending.pop_front().expect("pop on empty pending window");
    if let Some(next) = st.pending.front_mut() {
        next.started = Instant::now();
    }
    p
}

/// Resolve as many ops as possible, strictly from the **front** of the
/// pending window (completion order == sequence order, whatever order
/// contributions arrived in): `PeerFailed` when a structurally required
/// rank is dead, the fold + `Result` frames when every live
/// *participant* (rank admitted at or before the op's seq) has
/// contributed, otherwise stop — later ops wait behind the head.
fn try_complete(hub: &Hub, st: &mut HubState) {
    loop {
        let Some(p) = st.pending.front() else { return };
        let Some(meta) = p.contribs.iter().flatten().next() else {
            // Every contributor died; survivors will recreate the op.
            pop_front_pending(st);
            continue;
        };

        // Structural impossibility first — mirrors the order of
        // `ThreadComm`'s checks (dead owners fail even for a sole survivor).
        let victim = match p.op {
            OpCode::AllGather => meta
                .shards
                .iter()
                .enumerate()
                .find(|&(r, &(_, len))| len > 0 && !st.alive.get(r).copied().unwrap_or(false))
                .map(|(r, _)| r),
            OpCode::Broadcast => {
                let root = meta.root as usize;
                (!st.alive.get(root).copied().unwrap_or(false)).then_some(root)
            }
            _ => None,
        };
        if let Some(victim) = victim {
            let seq = p.seq;
            let op = p.op;
            let party = st.participants(p);
            let frame =
                error_frame(st.generation, seq, ErrorCode::PeerFailed, victim as u32, op.name());
            let mut frames: Vec<Option<Frame>> = vec![None; st.alive.len()];
            for r in party {
                send_to(hub, r, &frame);
                frames[r] = Some(frame.clone());
            }
            cache_completed(st, Completed { seq, frames });
            pop_front_pending(st);
            continue;
        }

        let party = st.participants(p);
        if party.iter().any(|&r| p.contribs[r].is_none()) {
            return;
        }
        let p = pop_front_pending(st);
        let results = fold(&p, &party);
        let mask = st.live_mask();
        let mut frames: Vec<Option<Frame>> = vec![None; st.alive.len()];
        for (&r, data) in party.iter().zip(&results) {
            let frame = result_frame(st.generation, p.seq, mask, data);
            send_to(hub, r, &frame);
            frames[r] = Some(frame);
        }
        cache_completed(st, Completed { seq: p.seq, frames });
        st.ops_done += 1;
    }
}

/// The hub-side fold: zero-seeded, ascending live rank order — the
/// fold-order contract of WIRE_PROTOCOL.md §5. Returns one result
/// vector per live participant (empty = "leave your buffer untouched",
/// the sole-survivor answer for every op except the weighted fold,
/// which is a real computation even alone).
fn fold(p: &Pending, live: &[usize]) -> Vec<Vec<f32>> {
    let contrib = |r: usize| p.contribs[r].as_ref().unwrap();
    let meta = contrib(live[0]);
    if live.len() <= 1 && p.op != OpCode::ReduceScatterWeighted {
        return vec![Vec::new(); live.len()];
    }
    let inv = 1.0 / live.len() as f32;
    match p.op {
        OpCode::Barrier => vec![Vec::new(); live.len()],
        OpCode::AllReduceMean => {
            let mut out = vec![0.0f32; meta.data.len()];
            for &r in live {
                kernels::add(&mut out, &contrib(r).data);
            }
            kernels::scale(&mut out, inv);
            vec![out; live.len()]
        }
        OpCode::AllGather => {
            let mut out = vec![0.0f32; shard_extent(&meta.shards)];
            for (owner, &(off, len)) in meta.shards.iter().enumerate() {
                if len > 0 {
                    out[off..off + len].copy_from_slice(&contrib(owner).data);
                }
            }
            vec![out; live.len()]
        }
        OpCode::ReduceScatterMean | OpCode::ReduceScatterSum => live
            .iter()
            .map(|&dst| {
                let (off, len) = meta.shards[dst];
                let mut out = vec![0.0f32; len];
                for &r in live {
                    kernels::add(&mut out, &contrib(r).data[off..off + len]);
                }
                if p.op == OpCode::ReduceScatterMean {
                    kernels::scale(&mut out, inv);
                }
                out
            })
            .collect(),
        OpCode::ReduceScatterWeighted => live
            .iter()
            .map(|&dst| {
                let (off, len) = meta.shards[dst];
                let mut out = vec![0.0f32; len];
                for &r in live {
                    let w = meta.weights[r];
                    if w != 0.0 {
                        kernels::axpy(&mut out, w, &contrib(r).data[off..off + len]);
                    }
                }
                out
            })
            .collect(),
        OpCode::ReduceScatterMeanQ8 => live
            .iter()
            .map(|&dst| {
                let (off, len) = meta.shards[dst];
                let mut out = vec![0.0f32; len];
                for &r in live {
                    let c = contrib(r);
                    for (j, o) in out.iter_mut().enumerate() {
                        let i = off + j;
                        *o += c.codes[i] as f32 * c.scales[i / QUANT_CHUNK];
                    }
                }
                kernels::scale(&mut out, inv);
                out
            })
            .collect(),
        OpCode::Broadcast => {
            let root = meta.root as usize;
            let data = contrib(root).data.clone();
            live.iter()
                .map(|&r| if r == root { Vec::new() } else { data.clone() })
                .collect()
        }
    }
}

/// Admit every lobby entry onto the newly opened barrier at
/// `barrier_seq` (§6.3): each joiner becomes the next rank, bumps the
/// generation, joins the barrier's contribution table, and receives a
/// Welcome whose `start_seq` is the barrier's seq — its first
/// contribution lands on the very op that admitted it.
fn admit_lobby(hub: &Arc<Hub>, st: &mut HubState, barrier_seq: u64) {
    for stream in std::mem::take(&mut st.lobby) {
        let rank = st.alive.len();
        let Ok(wclone) = stream.try_clone() else { continue };
        st.alive.push(true);
        st.done.push(false);
        st.connected.push(true);
        st.conn_epoch.push(0);
        st.joined_at.push(barrier_seq);
        st.disconnected_at.push(Instant::now());
        st.last_seen.push(Instant::now());
        st.generation += 1;
        st.joined += 1;
        if let Some(p) = st.pending.iter_mut().find(|p| p.seq == barrier_seq) {
            p.contribs.push(None);
        }
        if let Ok(mut peers) = hub.peers.lock() {
            peers.push(Arc::new(Peer { writer: Mutex::new(wclone) }));
        }
        send_to(hub, rank, &welcome_frame(st.generation, rank, st.alive.len(), barrier_seq));
        spawn_reader(hub, rank, stream, 0);
    }
}

fn on_contribute(hub: &Arc<Hub>, rank: usize, payload: &[u8]) {
    let parsed = parse_contribute(payload);
    let mut st = hub.state.lock().unwrap();
    st.last_seen[rank] = Instant::now();
    let generation = st.generation;
    if st.shutdown {
        send_to(hub, rank, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, generation, Vec::new()));
        return;
    }
    if !st.alive[rank] {
        // An evicted-but-connected rank learns its fate from the answer.
        let seq = parsed.map(|(s, _, _)| s).unwrap_or(0);
        send_to(hub, rank, &error_frame(generation, seq, ErrorCode::PeerFailed, rank as u32, "evicted"));
        return;
    }
    let (seq, op, contrib) = match parsed {
        Ok(v) => v,
        Err(e) => {
            send_to(hub, rank, &error_frame(generation, 0, ErrorCode::Protocol, rank as u32, &e.to_string()));
            return;
        }
    };
    // Duplicate of a resolved op (client retried after a local timeout,
    // or replayed its window after a reconnect): replay the cached
    // response.
    if let Some(c) = st.completed.iter().find(|c| c.seq == seq) {
        if let Some(frame) = c.frames.get(rank).and_then(|f| f.clone()) {
            send_to(hub, rank, &frame);
        }
        return;
    }
    if let Some(idx) = st.pending.iter().position(|p| p.seq == seq) {
        // Joins an op already opened by a peer.
        let p = &st.pending[idx];
        if op != p.op {
            let msg = format!(
                "out-of-step contribution: got {}#{seq}, pending {}#{}",
                op.name(),
                p.op.name(),
                p.seq
            );
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        let world = p.contribs.len();
        if rank >= world {
            // The op predates this rank's admission; it has no seat.
            let msg = format!("contribution to {}#{seq}, opened before rank {rank} joined", op.name());
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        let meta = p.contribs.iter().flatten().next().cloned();
        if let Err(msg) = validate_contrib(op, rank, world, &contrib, meta.as_ref()) {
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        st.pending[idx].contribs[rank] = Some(contrib);
    } else if seq == st.next_new_seq || seq < st.next_new_seq {
        // `seq == next_new_seq`: opens the next op in the pipeline.
        // `seq < next_new_seq` (matching nothing above): a retry of an
        // op the hub timed out and dropped — recreate it so same-seq
        // retries stay idempotent with multiple ops in flight; it is
        // inserted in sequence order, since completion is front-first.
        if st.pending.len() >= HUB_WINDOW {
            let msg = format!("pipeline window exceeded ({HUB_WINDOW} ops in flight)");
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        let world = st.members_at(seq);
        if rank >= world {
            let msg = format!("contribution to {}#{seq}, opened before rank {rank} joined", op.name());
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        if let Err(msg) = validate_contrib(op, rank, world, &contrib, None) {
            send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
            return;
        }
        let mut contribs: Vec<Option<Contrib>> = vec![None; world];
        contribs[rank] = Some(contrib);
        let entry = Pending { seq, op, started: Instant::now(), contribs };
        let at = st.pending.iter().position(|p| p.seq > seq).unwrap_or(st.pending.len());
        st.pending.insert(at, entry);
        let fresh = seq == st.next_new_seq;
        if fresh {
            st.next_new_seq = seq + 1;
        }
        // A *new* barrier is the admission point for lobby joiners
        // (§6.3) — a membership change can only land on a round
        // boundary, which the trainer marks with a barrier.
        if fresh && op == OpCode::Barrier && !st.lobby.is_empty() {
            admit_lobby(hub, &mut st, seq);
        }
    } else {
        // A gap: the client skipped a sequence number.
        let msg = format!(
            "out-of-window contribution: got {}#{seq}, next new seq is {}",
            op.name(),
            st.next_new_seq
        );
        send_to(hub, rank, &error_frame(generation, seq, ErrorCode::Protocol, rank as u32, &msg));
        return;
    }
    try_complete(hub, &mut st);
}

/// Per-connection reader: drains frames, updates liveness, feeds
/// contributions to the hub. EOF or a stream reset starts the reconnect
/// grace clock (§6.2); only a protocol-corrupt stream evicts outright.
fn reader_loop(hub: &Arc<Hub>, rank: usize, stream: &TcpStream, epoch: u64) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
    let mut fb = FrameBuffer::new();
    let mut src = stream;
    loop {
        match fb.poll() {
            Ok(Some((_v, frame))) => {
                match frame.kind {
                    FrameKind::Heartbeat => {
                        hub.state.lock().unwrap().last_seen[rank] = Instant::now();
                    }
                    FrameKind::Contribute => on_contribute(hub, rank, &frame.payload),
                    FrameKind::Goodbye => {
                        leave(hub, &mut hub.state.lock().unwrap(), rank);
                        return;
                    }
                    _ => {
                        let st = hub.state.lock().unwrap();
                        let f = error_frame(
                            st.generation,
                            0,
                            ErrorCode::Protocol,
                            rank as u32,
                            "unexpected frame kind",
                        );
                        drop(st);
                        send_to(hub, rank, &f);
                    }
                }
                continue;
            }
            Ok(None) => {}
            Err(_) => {
                link_failed(hub, rank, epoch, true);
                return;
            }
        }
        match fb.fill_from(&mut src) {
            Ok(0) => {
                let gone = hub.state.lock().unwrap().done[rank];
                if !gone {
                    link_failed(hub, rank, epoch, false);
                }
                return;
            }
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                let st = hub.state.lock().unwrap();
                if st.shutdown || st.all_finished() {
                    return;
                }
            }
            Err(_) => {
                link_failed(hub, rank, epoch, false);
                return;
            }
        }
    }
}

fn spawn_reader(hub: &Arc<Hub>, rank: usize, stream: TcpStream, epoch: u64) {
    let hub2 = Arc::clone(hub);
    if let Ok(h) = std::thread::Builder::new()
        .name(format!("edit-hub-r{rank}"))
        .spawn(move || reader_loop(&hub2, rank, &stream, epoch))
    {
        hub.readers.lock().unwrap().push(h);
    }
}

/// Read exactly one frame within `deadline` (handshake only — after the
/// Welcome, reads go through `FrameBuffer` polling).
fn read_handshake_frame(stream: &TcpStream, deadline: Instant) -> io::Result<(u32, Frame)> {
    let mut fb = FrameBuffer::new();
    let mut src = stream;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        if let Some(v) = fb.poll()? {
            return Ok(v);
        }
        if Instant::now() >= deadline {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "handshake timed out"));
        }
        match fb.fill_from(&mut src) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed")),
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
            Err(e) => return Err(e),
        }
    }
}

/// Phase-2 handshake (§6): a dial after the initial group formed is
/// either a reconnect (non-empty Hello payload: `{rank, generation,
/// last_seq}`) or a fresh late join (empty payload → lobby).
fn handshake_phase2(hub: &Arc<Hub>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let deadline = Instant::now() + Duration::from_secs(5);
    let Ok((version, hello)) = read_handshake_frame(&stream, deadline) else { return };
    if version != PROTOCOL_VERSION {
        reply(
            &stream,
            &error_frame(
                0,
                0,
                ErrorCode::VersionMismatch,
                RANK_UNASSIGNED,
                &format!("hub speaks v{PROTOCOL_VERSION}, client spoke v{version}"),
            ),
        );
        return;
    }
    if hello.kind != FrameKind::Hello {
        reply(&stream, &error_frame(0, 0, ErrorCode::Protocol, RANK_UNASSIGNED, "expected Hello"));
        return;
    }
    if hello.payload.is_empty() {
        // Fresh late join (§6.3): wait in the lobby for the next new
        // barrier; the Welcome is deferred to admission.
        let mut st = hub.state.lock().unwrap();
        if st.shutdown {
            let g = st.generation;
            drop(st);
            reply(&stream, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, g, Vec::new()));
            return;
        }
        if st.alive.len() + st.lobby.len() >= 64 {
            let g = st.generation;
            drop(st);
            reply(
                &stream,
                &error_frame(g, 0, ErrorCode::Protocol, RANK_UNASSIGNED, "membership full (64 ranks)"),
            );
            return;
        }
        st.lobby.push(stream);
        return;
    }
    // Reconnect (§6.2).
    let parsed = (|| -> io::Result<(usize, u64, u64)> {
        let mut r = PayloadReader::new(&hello.payload);
        Ok((r.u32()? as usize, r.u64()?, r.u64()?))
    })();
    let Ok((rank, _generation, _last_seq)) = parsed else {
        reply(&stream, &error_frame(0, 0, ErrorCode::Protocol, RANK_UNASSIGNED, "malformed reconnect Hello"));
        return;
    };
    let mut st = hub.state.lock().unwrap();
    if st.shutdown {
        let g = st.generation;
        drop(st);
        reply(&stream, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, g, Vec::new()));
        return;
    }
    if rank >= st.alive.len() {
        let g = st.generation;
        drop(st);
        reply(&stream, &error_frame(g, 0, ErrorCode::Protocol, rank as u32, "reconnect for unknown rank"));
        return;
    }
    if !st.alive[rank] || st.done[rank] {
        // The grace window expired (or the rank already left): the
        // explicit rejection the client treats as terminal.
        let g = st.generation;
        drop(st);
        reply(&stream, &error_frame(g, 0, ErrorCode::PeerFailed, rank as u32, "evicted"));
        return;
    }
    let Ok(wclone) = stream.try_clone() else { return };
    st.conn_epoch[rank] += 1;
    let epoch = st.conn_epoch[rank];
    st.connected[rank] = true;
    st.last_seen[rank] = Instant::now();
    st.disconnected_at[rank] = Instant::now();
    let g = st.generation;
    let world = st.alive.len();
    let start_seq = st.joined_at[rank];
    if let Ok(mut peers) = hub.peers.lock() {
        peers[rank] = Arc::new(Peer { writer: Mutex::new(wclone) });
    }
    drop(st);
    reply(&stream, &welcome_frame(g, rank, world, start_seq));
    spawn_reader(hub, rank, stream, epoch);
}

fn serve(listener: TcpListener, cfg: RendezvousConfig, stop: Arc<AtomicBool>) -> RendezvousReport {
    // Phase 1: collect `world` handshakes (WIRE_PROTOCOL.md §4.1).
    let _ = listener.set_nonblocking(true);
    let join_deadline = Instant::now() + cfg.accept_timeout;
    let mut streams: Vec<TcpStream> = Vec::with_capacity(cfg.world);
    while streams.len() < cfg.world {
        if stop.load(Ordering::SeqCst) || Instant::now() >= join_deadline {
            for s in &streams {
                reply(s, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, 0, Vec::new()));
            }
            return RendezvousReport { joined: streams.len(), ..Default::default() };
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                let deadline = Instant::now() + Duration::from_secs(5);
                match read_handshake_frame(&stream, deadline) {
                    Ok((version, hello)) => {
                        if version != PROTOCOL_VERSION {
                            reply(
                                &stream,
                                &error_frame(
                                    0,
                                    0,
                                    ErrorCode::VersionMismatch,
                                    RANK_UNASSIGNED,
                                    &format!("hub speaks v{PROTOCOL_VERSION}, client spoke v{version}"),
                                ),
                            );
                            continue;
                        }
                        if hello.kind != FrameKind::Hello {
                            reply(&stream, &error_frame(0, 0, ErrorCode::Protocol, RANK_UNASSIGNED, "expected Hello"));
                            continue;
                        }
                        let rank = streams.len();
                        let mut w = &stream;
                        if write_frame(&mut w, &welcome_frame(0, rank, cfg.world, 0)).is_ok() {
                            streams.push(stream);
                        }
                    }
                    Err(_) => continue,
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }

    // Phase 2: serve collectives until every rank leaves or dies.
    let now = Instant::now();
    let hub = Arc::new(Hub {
        cfg,
        peers: Mutex::new(
            streams
                .iter()
                .map(|s| Arc::new(Peer { writer: Mutex::new(s.try_clone().expect("tcp clone")) }))
                .collect(),
        ),
        state: Mutex::new(HubState {
            alive: vec![true; cfg.world],
            done: vec![false; cfg.world],
            connected: vec![true; cfg.world],
            conn_epoch: vec![0; cfg.world],
            joined_at: vec![0; cfg.world],
            disconnected_at: vec![now; cfg.world],
            last_seen: vec![now; cfg.world],
            generation: 0,
            evicted: Vec::new(),
            pending: VecDeque::new(),
            completed: VecDeque::new(),
            next_new_seq: 0,
            ops_done: 0,
            joined: cfg.world,
            shutdown: false,
            lobby: Vec::new(),
        }),
        readers: Mutex::new(Vec::new()),
    });

    for (rank, stream) in streams.into_iter().enumerate() {
        spawn_reader(&hub, rank, stream, 0);
    }

    // Monitor loop: phase-2 dials (reconnect / late join), reconnect
    // grace, op-window timeouts, heartbeat-stale evictions.
    loop {
        std::thread::sleep(Duration::from_millis(10));
        // The listener stays open (§6): reconnects re-Hello with their
        // rank; fresh Hellos wait in the lobby. Handshakes run in their
        // own threads so a slow dialer cannot stall the monitor.
        while let Ok((stream, _peer)) = listener.accept() {
            let hub2 = Arc::clone(&hub);
            let _ = std::thread::Builder::new()
                .name("edit-hub-hs".into())
                .spawn(move || handshake_phase2(&hub2, stream));
        }
        let mut st = hub.state.lock().unwrap();
        if stop.load(Ordering::SeqCst) {
            st.shutdown = true;
            let generation = st.generation;
            for p in std::mem::take(&mut st.pending) {
                for (r, c) in p.contribs.iter().enumerate() {
                    if c.is_some() && st.alive[r] {
                        send_to(&hub, r, &error_frame(generation, p.seq, ErrorCode::Shutdown, r as u32, "hub shutdown"));
                    }
                }
            }
            for r in st.live_ranks() {
                send_to(&hub, r, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, generation, Vec::new()));
            }
            break;
        }
        if st.all_finished() {
            st.shutdown = true;
            break;
        }
        // Reconnect grace (§6.2): a disconnected rank that has not
        // re-Helloed within `heartbeat_timeout` is dead.
        let lapsed: Vec<usize> = (0..st.alive.len())
            .filter(|&r| {
                st.alive[r]
                    && !st.done[r]
                    && !st.connected[r]
                    && st.disconnected_at[r].elapsed() >= hub.cfg.heartbeat_timeout
            })
            .collect();
        for r in lapsed {
            evict(&hub, &mut st, r);
        }
        // Only the head of the pending window is on the op-timeout
        // clock — queued ops start their window when they reach the
        // head (see `pop_front_pending`).
        let timed_out = st
            .pending
            .front()
            .is_some_and(|p| p.started.elapsed() >= hub.cfg.op_timeout);
        if timed_out {
            // Evict op-blocking ranks that also stopped heartbeating
            // (a killed -STOP process, a hard hang) — timeout-then-evict.
            let stale: Vec<usize> = {
                let p = st.pending.front().unwrap();
                st.participants(p)
                    .into_iter()
                    .filter(|&r| {
                        p.contribs[r].is_none()
                            && st.last_seen[r].elapsed() >= hub.cfg.heartbeat_timeout
                    })
                    .collect()
            };
            for r in stale {
                evict(&hub, &mut st, r);
            }
            // Still blocked on live, heartbeating ranks: tell the
            // contributors to retry (maps onto RetryPolicy; a pipelined
            // client re-sends the same seq, which recreates the op).
            if let Some(p) = st.pending.front() {
                if p.started.elapsed() >= hub.cfg.op_timeout {
                    let generation = st.generation;
                    let seq = p.seq;
                    let name = p.op.name();
                    let contributed: Vec<usize> = st
                        .participants(p)
                        .into_iter()
                        .filter(|&r| p.contribs[r].is_some())
                        .collect();
                    for r in contributed {
                        send_to(
                            &hub,
                            r,
                            &error_frame(generation, seq, ErrorCode::Timeout, RANK_UNASSIGNED, name),
                        );
                    }
                    pop_front_pending(&mut st);
                }
            }
        }
    }
    {
        let mut st = hub.state.lock().unwrap();
        st.shutdown = true;
        let g = st.generation;
        for s in std::mem::take(&mut st.lobby) {
            reply(&s, &Frame::new(FrameKind::Shutdown, RANK_UNASSIGNED, g, Vec::new()));
        }
    }

    // Readers may still be registering (a handshake racing shutdown):
    // drain until the registry stays empty.
    loop {
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *hub.readers.lock().unwrap());
        if handles.is_empty() {
            break;
        }
        for h in handles {
            let _ = h.join();
        }
    }
    let st = hub.state.lock().unwrap();
    RendezvousReport {
        joined: st.joined,
        generations: st.generation,
        evicted: st.evicted.clone(),
        ops_done: st.ops_done,
    }
}
