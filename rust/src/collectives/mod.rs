//! Collective-communication substrate (DESIGN.md §2.2).
//!
//! Three pieces:
//!  * [`group`] — deterministic sequential reference semantics (the
//!    numerics the trainer actually executes);
//!  * [`thread`] — rendezvous-based threaded communicator with
//!    bitwise-identical reduction order;
//!  * [`cost`] — the α-β timing model shared with the cluster simulator,
//!    so every collective the trainer performs also advances the
//!    simulated clock by the time the same op would take on the paper's
//!    A100 mesh.

pub mod cost;
pub mod group;
pub mod thread;

pub use cost::{CollOp, CommStats, CostModel, Topology};
pub use thread::ThreadComm;
