//! Collective-communication substrate (DESIGN.md §2.2).
//!
//! Three pieces:
//!  * [`group`] — deterministic sequential reference semantics (the
//!    numerics the trainer actually executes);
//!  * [`thread`] — rendezvous-based threaded communicator with
//!    bitwise-identical reduction order;
//!  * [`cost`] — the α-β timing model shared with the cluster simulator,
//!    so every collective the trainer performs also advances the
//!    simulated clock by the time the same op would take on the paper's
//!    A100 mesh.
//!
//! # Reduce-scatter / all-gather semantics and the fold-order contract
//!
//! The sharded outer synchronization path (ZeRO-1-style: each rank owns
//! a contiguous, range-aligned shard of the flat parameter space — see
//! `tensor::TableShards`) decomposes what the unsharded path expresses
//! as per-module all-reduces into a **reduce-scatter** of the member
//! pseudo-gradients into the owned shard followed by an **all-gather**
//! of the updated anchor shards:
//!
//!  * `reduce_scatter_{sum,mean,weighted}` — rank r's shard region ends
//!    with the rank-0..n fold of every rank's contribution over that
//!    region (`weighted` folds `Σ_j w_j·x_j`, skipping zero weights:
//!    the EDiT softmax-weighted combine as a collective). The fold
//!    order is **always ascending rank**, whatever the executing
//!    topology — this is the contract that makes the threaded
//!    implementations, the sequential references and the trainer's
//!    shard-local fused kernels bitwise interchangeable.
//!  * `all_gather` — each rank contributes its owned shard; afterwards
//!    every rank holds the concatenation.
//!
//! Pricing: the ring α-β formulas decompose exactly — `time(RS) +
//! time(AG) == time(AllReduce)` **bitwise** (scaling by two commutes
//! with IEEE rounding; asserted in `cost`), so replacing a module's
//! all-reduce by RS+AG changes neither the simulated clock nor any
//! comparison against the unsharded plan.

pub mod cost;
pub mod group;
pub mod thread;

pub use cost::{CollOp, CommStats, CostModel, Topology};
pub use thread::ThreadComm;
