//! Collective-communication substrate (DESIGN.md §2.2).
//!
//! Three pieces:
//!  * [`group`] — deterministic sequential reference semantics (the
//!    numerics the trainer actually executes);
//!  * [`thread`] — rendezvous-based threaded communicator with
//!    bitwise-identical reduction order;
//!  * [`cost`] — the α-β timing model shared with the cluster simulator,
//!    so every collective the trainer performs also advances the
//!    simulated clock by the time the same op would take on the paper's
//!    A100 mesh.
//!
//! # Reduce-scatter / all-gather semantics and the fold-order contract
//!
//! The sharded outer synchronization path (ZeRO-1-style: each rank owns
//! a contiguous, range-aligned shard of the flat parameter space — see
//! `tensor::TableShards`) decomposes what the unsharded path expresses
//! as per-module all-reduces into a **reduce-scatter** of the member
//! pseudo-gradients into the owned shard followed by an **all-gather**
//! of the updated anchor shards:
//!
//!  * `reduce_scatter_{sum,mean,weighted}` — rank r's shard region ends
//!    with the rank-0..n fold of every rank's contribution over that
//!    region (`weighted` folds `Σ_j w_j·x_j`, skipping zero weights:
//!    the EDiT softmax-weighted combine as a collective). The fold
//!    order is **always ascending rank**, whatever the executing
//!    topology — this is the contract that makes the threaded
//!    implementations, the sequential references and the trainer's
//!    shard-local fused kernels bitwise interchangeable.
//!  * `all_gather` — each rank contributes its owned shard; afterwards
//!    every rank holds the concatenation.
//!  * `reduce_scatter_mean_q8` — the compressed payload lane
//!    (`payload=int8`): contributions are staged as int8 codes +
//!    per-chunk f32 scales (the actual wire bytes, ~3.8× fewer than
//!    f32), dequantized on receipt and folded in the same ascending
//!    rank order. Sequential reference and threaded implementation are
//!    bitwise interchangeable; the quantization error stays with the
//!    sender, where the trainer's error-feedback residuals absorb it.
//!
//! Pricing: the ring α-β formulas decompose exactly — `time(RS) +
//! time(AG) == time(AllReduce)` **bitwise** (scaling by two commutes
//! with IEEE rounding; asserted in `cost`), so replacing a module's
//! all-reduce by RS+AG changes neither the simulated clock nor any
//! comparison against the unsharded plan.

//!
//! # Fault tolerance: the fallible surface
//!
//! The historical collective API is infallible — every rank always
//! shows up. The elastic runtime needs the opposite assumption:
//! [`Collective`] is the **fallible** trait (every op takes a timeout
//! and returns [`CommError`]), [`RetryPolicy`] is the bounded
//! retry/backoff loop callers wrap it in, and [`ThreadComm`] implements
//! the trait with a condvar rendezvous gate that counts only live ranks
//! (`mark_failed` / `shutdown`). Semantics per error:
//!
//!  * [`CommError::Timeout`] — a peer did not arrive in time. Possibly
//!    transient (a hang, a slow rank): **retryable**, and the only
//!    variant [`RetryPolicy::run`] retries.
//!  * [`CommError::PeerFailed`] — the op is impossible without the dead
//!    rank (a broadcast root, an all-gather shard owner). Deterministic:
//!    retrying cannot help; callers degrade membership instead (the
//!    trainer's timeout-then-evict barrier in `engine/sync.rs` is the
//!    simulated-clock mirror of exactly this policy).
//!  * [`CommError::Shutdown`] — the communicator is being torn down.
//!    Terminal.
//!
//! Reductions over a degraded group fold the **live ranks in ascending
//! rank order** (means divide by the live count) — the same membership
//! semantics the trainer's sync paths apply when a replica crashes.

//!
//! # Backends
//!
//! Two implementations of [`Collective`] exist, bitwise
//! interchangeable at matched rank count (asserted by the
//! cross-backend suite in `tests/socket_backend.rs`):
//!
//!  * [`ThreadComm`] — in-process, one handle per OS thread; the
//!    default and the test substrate.
//!  * [`SocketComm`] — one handle per OS **process**, speaking the
//!    framed TCP protocol of [`frame`] (spec: `docs/WIRE_PROTOCOL.md`)
//!    to the [`rendezvous`] hub, which assigns ranks, counts
//!    membership generations, and performs the ascending-live-rank
//!    fold itself. Launched via `edit-train rendezvous --bind` +
//!    `edit-train worker --join` (see [`driver`]).

use std::time::Duration;

pub mod cost;
pub mod driver;
pub mod frame;
pub mod group;
pub mod rendezvous;
pub mod socket;
pub mod thread;

pub use cost::{CollOp, CommStats, CostModel, Topology};
pub use rendezvous::{Rendezvous, RendezvousConfig, RendezvousReport};
pub use socket::{ConnectOpts, SocketComm, WireStats};
pub use thread::ThreadComm;

/// Which transport executes the fallible collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CommBackend {
    /// In-process rendezvous over OS threads ([`ThreadComm`]).
    #[default]
    Thread,
    /// Framed TCP to a rendezvous hub ([`SocketComm`]); requires the
    /// multi-process launcher (`edit-train worker --join <addr>`).
    Socket,
}

impl CommBackend {
    /// Parse a config/CLI value (`thread` | `socket`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "thread" => Some(CommBackend::Thread),
            "socket" => Some(CommBackend::Socket),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CommBackend::Thread => "thread",
            CommBackend::Socket => "socket",
        }
    }
}

/// Why a fallible collective did not complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// A rank required by the op is marked failed (broadcast root,
    /// all-gather shard owner). Deterministic — do not retry.
    PeerFailed { rank: usize },
    /// The rendezvous did not complete within the timeout. Possibly
    /// transient — the retryable variant.
    Timeout { op: &'static str, waited: Duration },
    /// The communicator is shutting down. Terminal.
    Shutdown,
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerFailed { rank } => write!(f, "collective peer rank {rank} failed"),
            CommError::Timeout { op, waited } => {
                write!(f, "collective '{op}' timed out after {waited:?}")
            }
            CommError::Shutdown => write!(f, "communicator shut down"),
        }
    }
}

impl std::error::Error for CommError {}

pub type CommResult<T> = Result<T, CommError>;

/// Upper bound on nonblocking ops a caller may hold in flight per
/// communicator before `start_*` blocks (backpressure, not an error).
/// Sized for the layer-wise pipeline's steady state: one reduce-scatter
/// being folded, one all-gather draining, one of each being issued.
pub const PIPELINE_WINDOW: usize = 4;

/// Where a [`CommHandle`]'s result will come from. Internal: callers
/// only ever move the opaque handle back into [`Collective::wait_handle`].
pub(crate) enum HandleState {
    /// Op already ran to completion at issue time (the default
    /// blocking fallback any backend gets for free).
    Ready(CommResult<Vec<f32>>),
    /// Op is executing on a [`ThreadComm`] comm worker; the result
    /// arrives on this per-op reply channel.
    Thread(std::sync::mpsc::Receiver<CommResult<Vec<f32>>>),
    /// Op is in flight on a [`SocketComm`] pipeline under this wire
    /// sequence number; completion requires draining frames through the
    /// owning communicator (`wait_handle` is overridden there).
    Socket(u64),
}

/// An in-flight nonblocking collective: issued by a `start_*` op,
/// completed by [`Collective::wait_handle`] (or the
/// [`CommHandle::wait`] sugar) on the **same** communicator that issued
/// it. The contribution buffer travels by value — ownership moves into
/// the handle at issue and comes back out of `wait`, so no aliasing is
/// possible while the op is in flight.
///
/// Dropping a handle without waiting is safe: the op still completes on
/// the backend (membership, sequence numbers and fold state stay
/// consistent — pinned by `tests/nonblocking.rs`), only the result is
/// discarded.
pub struct CommHandle {
    pub(crate) state: Option<HandleState>,
}

impl CommHandle {
    pub(crate) fn ready(result: CommResult<Vec<f32>>) -> Self {
        CommHandle { state: Some(HandleState::Ready(result)) }
    }

    pub(crate) fn thread(rx: std::sync::mpsc::Receiver<CommResult<Vec<f32>>>) -> Self {
        CommHandle { state: Some(HandleState::Thread(rx)) }
    }

    pub(crate) fn socket(seq: u64) -> Self {
        CommHandle { state: Some(HandleState::Socket(seq)) }
    }

    /// Complete the op and take back the buffer:
    /// `handle.wait(&comm)` ≡ `comm.wait_handle(handle)`.
    pub fn wait<C: Collective + ?Sized>(self, comm: &C) -> CommResult<Vec<f32>> {
        comm.wait_handle(self)
    }
}

/// Bounded retry/backoff policy for the fallible surface: up to
/// `max_attempts` tries, exponential backoff between them, each attempt
/// given `timeout` to rendezvous. Only [`CommError::Timeout`] is
/// retried — `PeerFailed` is deterministic and `Shutdown` is terminal,
/// so both surface immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    pub base_backoff: Duration,
    pub timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_backoff: Duration::from_millis(10),
            timeout: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): `base · 2^attempt`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        self.base_backoff * (1u32 << attempt.min(16))
    }

    /// Drive `op` (called with the per-attempt timeout) until it
    /// succeeds, fails deterministically, or the attempt budget is
    /// spent. The final timeout error is returned as-is.
    pub fn run<T>(&self, mut op: impl FnMut(Duration) -> CommResult<T>) -> CommResult<T> {
        let mut attempt = 0u32;
        loop {
            match op(self.timeout) {
                Err(CommError::Timeout { op: name, waited }) => {
                    attempt += 1;
                    if attempt >= self.max_attempts.max(1) {
                        return Err(CommError::Timeout { op: name, waited });
                    }
                    std::thread::sleep(self.backoff(attempt - 1));
                }
                other => return other,
            }
        }
    }
}

/// The fallible collective surface: every op takes a rendezvous timeout
/// and reports failure instead of blocking forever on a dead peer.
///
/// # Contract
///
/// **Determinism.** Reductions fold contributions over the **live ranks
/// in ascending rank order** from a zero-initialized accumulator; means
/// divide by the live count, after the fold. Two backends given the
/// same inputs at the same live membership must produce bitwise
/// identical f32 results — this is what makes [`ThreadComm`] (threads)
/// and [`SocketComm`] (processes) interchangeable, and it is asserted,
/// not assumed (`tests/socket_backend.rs`).
///
/// **Membership degrade.** A dead rank shrinks the group instead of
/// wedging it: reductions skip its contribution and means divide by the
/// live count. Only *structurally required* ranks fail an op — a dead
/// broadcast root or a dead all-gather shard owner (with a non-empty
/// shard) yields [`CommError::PeerFailed`], because no fold can
/// reconstruct bytes only that rank held. A sole survivor's collective
/// degenerates to a no-op (its contribution is the reduction).
///
/// **Retry classification.** [`CommError::Timeout`] is possibly
/// transient and the only variant worth retrying; [`RetryPolicy::run`]
/// encodes that loop. `PeerFailed` is deterministic (callers degrade
/// membership — recompute shards over the survivors — rather than
/// retry), and `Shutdown` is terminal.
///
/// # Example
///
/// A 2-rank mean all-reduce, each rank on its own thread:
///
/// ```
/// use edit_train::collectives::{Collective, ThreadComm};
/// use std::time::Duration;
///
/// let comms = ThreadComm::group(2);
/// let t = Duration::from_secs(5);
/// std::thread::scope(|s| {
///     for comm in &comms {
///         s.spawn(move || {
///             let mut buf = vec![(comm.rank() + 1) as f32; 4];
///             comm.try_all_reduce_mean(&mut buf, t).unwrap();
///             assert_eq!(buf, vec![1.5; 4]); // mean of 1.0 and 2.0
///         });
///     }
/// });
/// ```
///
/// Degraded membership — the dead rank is skipped, the mean is over
/// the survivors, and a dead broadcast root fails deterministically:
///
/// ```
/// use edit_train::collectives::{Collective, CommError, ThreadComm};
/// use std::time::Duration;
///
/// let comms = ThreadComm::group(2);
/// comms[0].mark_failed(1);
/// let t = Duration::from_millis(50);
///
/// let mut buf = vec![3.0f32; 4];
/// comms[0].try_all_reduce_mean(&mut buf, t).unwrap();
/// assert_eq!(buf, vec![3.0; 4]); // sole survivor: its own mean
///
/// assert_eq!(
///     comms[0].try_broadcast(&mut buf, 1, t),
///     Err(CommError::PeerFailed { rank: 1 }),
/// );
/// ```
///
/// Wrapping an op in the retry loop:
///
/// ```
/// use edit_train::collectives::{Collective, RetryPolicy, ThreadComm};
///
/// let comms = ThreadComm::group(1);
/// let policy = RetryPolicy::default();
/// let mut buf = vec![1.0f32; 8];
/// policy.run(|t| comms[0].try_all_reduce_mean(&mut buf, t)).unwrap();
/// ```
pub trait Collective {
    /// This handle's rank in `0..size()`.
    fn rank(&self) -> usize;
    /// Configured group size (including dead ranks — membership only
    /// ever degrades from here).
    fn size(&self) -> usize;
    /// Rendezvous with every live rank.
    fn try_barrier(&self, timeout: Duration) -> CommResult<()>;
    /// Mean all-reduce over the live ranks (ascending-rank fold, mean
    /// over the live count).
    fn try_all_reduce_mean(&self, buf: &mut [f32], timeout: Duration) -> CommResult<()>;
    /// All-gather of owned shards; fails with `PeerFailed` if any shard
    /// owner is dead (its shard cannot be reconstructed).
    fn try_all_gather(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()>;
    /// Reduce-scatter (mean) over the live ranks into this rank's shard.
    fn try_reduce_scatter_mean(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()>;
    /// Reduce-scatter (sum) over the live ranks into this rank's shard —
    /// the mean fold without the final live-count scale.
    fn try_reduce_scatter_sum(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()>;
    /// Weighted reduce-scatter: this rank's shard ends with
    /// `Σ_j weights[j]·x_j` over the live ranks (zero-weight ranks
    /// skipped) — the EDiT softmax-weighted combine as a collective.
    fn try_reduce_scatter_weighted(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommResult<()>;
    /// Reduce-scatter (mean) over int8-quantized payloads (the
    /// `payload=int8` wire lane): contributions travel as codes +
    /// per-chunk scales and are dequantized before the fold.
    fn try_reduce_scatter_mean_q8(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()>;
    /// Broadcast from `root`; fails with `PeerFailed` if the root is dead.
    fn try_broadcast(&self, buf: &mut [f32], root: usize, timeout: Duration) -> CommResult<()>;

    /// Chaos hook: sever this handle's transport link once, without
    /// closing the communicator. The socket backend shuts its TCP
    /// stream down and recovers via the reconnect-with-replay path on
    /// the next op (docs/WIRE_PROTOCOL.md §6); in-process backends have
    /// no link to drop, so the default is a no-op. Deterministic fault
    /// plans (`FaultKind::NetDrop` / `Partition`) are injected through
    /// this hook by the collective driver.
    fn drop_link(&self) {}

    /// True when this handle was admitted to a group mid-run (a wire
    /// late join, §6.3) and must adopt the group's round counter and
    /// anchor before training. Only [`SocketComm`] can return true.
    fn late_joiner(&self) -> bool {
        false
    }

    // --- Nonblocking issue/complete surface -----------------------------
    //
    // `start_*` takes the contribution buffer **by value** and returns a
    // [`CommHandle`]; [`Collective::wait_handle`] completes the op and
    // returns the buffer with the fold applied (exactly what the
    // matching `try_*` would have left in place — bitwise). Ops complete
    // in issue order; at most [`PIPELINE_WINDOW`] may be in flight per
    // communicator (`start_*` applies backpressure past that). The
    // default implementations run the blocking op at issue time, so any
    // backend is correct for free; [`ThreadComm`] and [`SocketComm`]
    // override them with genuinely asynchronous execution.

    /// Nonblocking [`Collective::try_all_reduce_mean`].
    fn start_all_reduce_mean(&self, mut buf: Vec<f32>, timeout: Duration) -> CommHandle {
        let r = self.try_all_reduce_mean(&mut buf, timeout).map(|()| buf);
        CommHandle::ready(r)
    }

    /// Nonblocking [`Collective::try_reduce_scatter_mean`].
    fn start_reduce_scatter_mean(
        &self,
        mut full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let r = self.try_reduce_scatter_mean(&mut full, shards, timeout).map(|()| full);
        CommHandle::ready(r)
    }

    /// Nonblocking [`Collective::try_reduce_scatter_mean_q8`].
    fn start_reduce_scatter_mean_q8(
        &self,
        mut full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let r = self.try_reduce_scatter_mean_q8(&mut full, shards, timeout).map(|()| full);
        CommHandle::ready(r)
    }

    /// Nonblocking [`Collective::try_reduce_scatter_weighted`].
    fn start_reduce_scatter_weighted(
        &self,
        mut full: Vec<f32>,
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommHandle {
        let r = self
            .try_reduce_scatter_weighted(&mut full, shards, weights, timeout)
            .map(|()| full);
        CommHandle::ready(r)
    }

    /// Nonblocking [`Collective::try_all_gather`].
    fn start_all_gather(
        &self,
        mut full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let r = self.try_all_gather(&mut full, shards, timeout).map(|()| full);
        CommHandle::ready(r)
    }

    /// Complete a handle issued by this communicator's `start_*` ops and
    /// return the buffer. Handles must be waited on the communicator
    /// that issued them.
    fn wait_handle(&self, mut handle: CommHandle) -> CommResult<Vec<f32>> {
        match handle.state.take() {
            Some(HandleState::Ready(r)) => r,
            Some(HandleState::Thread(rx)) => rx.recv().unwrap_or(Err(CommError::Shutdown)),
            Some(HandleState::Socket(_)) => {
                panic!("socket CommHandle waited on a backend that did not issue it")
            }
            None => Err(CommError::Shutdown),
        }
    }
}

#[cfg(test)]
mod retry_tests {
    use super::*;

    #[test]
    fn retry_policy_retries_only_timeouts() {
        let policy = RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_micros(1),
            timeout: Duration::from_millis(1),
        };
        // Two timeouts, then success: three attempts total.
        let mut calls = 0;
        let got = policy.run(|_t| {
            calls += 1;
            if calls < 3 {
                Err(CommError::Timeout { op: "x", waited: Duration::from_millis(1) })
            } else {
                Ok(calls)
            }
        });
        assert_eq!(got, Ok(3));

        // PeerFailed is deterministic: exactly one attempt.
        let mut calls = 0;
        let got: CommResult<()> = policy.run(|_t| {
            calls += 1;
            Err(CommError::PeerFailed { rank: 1 })
        });
        assert_eq!(got, Err(CommError::PeerFailed { rank: 1 }));
        assert_eq!(calls, 1);

        // Shutdown is terminal: exactly one attempt.
        let mut calls = 0;
        let got: CommResult<()> = policy.run(|_t| {
            calls += 1;
            Err(CommError::Shutdown)
        });
        assert_eq!(got, Err(CommError::Shutdown));
        assert_eq!(calls, 1);

        // The attempt budget is honored.
        let mut calls = 0;
        let got: CommResult<()> = policy.run(|_t| {
            calls += 1;
            Err(CommError::Timeout { op: "y", waited: Duration::from_millis(1) })
        });
        assert!(matches!(got, Err(CommError::Timeout { op: "y", .. })));
        assert_eq!(calls, 3);
    }

    #[test]
    fn backoff_grows_exponentially() {
        let policy = RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(10),
            timeout: Duration::from_secs(1),
        };
        assert_eq!(policy.backoff(0), Duration::from_millis(10));
        assert_eq!(policy.backoff(1), Duration::from_millis(20));
        assert_eq!(policy.backoff(3), Duration::from_millis(80));
    }
}
