//! Backend-generic distributed EDiT sync driver.
//!
//! The trainer's own sync path simulates its cluster in-process (the
//! scratch-arena pipeline priced by the α-β model); *this* module runs
//! the same outer-round shape — inner SGD steps, reduce-scatter of the
//! pseudo-gradients, Nesterov outer update on the owned shard,
//! all-gather of the anchor — over any [`Collective`] backend, with
//! every stochastic draw stateless in `(seed, round, step, rank)`.
//! That makes it the equivalence probe for transports: the same
//! `DriverConfig` must produce a **bitwise identical final anchor**
//! whether the ranks are OS threads sharing a `ThreadComm` or OS
//! processes speaking sockets through the rendezvous hub
//! (`edit-train worker --join` vs `--local`; asserted by
//! `tests/socket_backend.rs` and `scripts/smoke_multiproc.sh`).
//!
//! # Membership degrade
//!
//! A rank that dies mid-run shrinks the group, mirroring the trainer's
//! eviction policy:
//!
//!  * reductions silently fold the live ranks (the backends' contract);
//!  * the all-gather is the detection point — a dead shard owner fails
//!    `PeerFailed`, the survivors zero its shard entry and retry, and
//!    the dead rank's region keeps its pre-round anchor values (every
//!    survivor holds the same full anchor, so the skip is consistent);
//!  * from the next round boundary, shards are rebuilt over the
//!    survivors, restoring full coverage.

use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::collectives::frame::{PayloadReader, PayloadWriter};
use crate::collectives::{Collective, CommError, CommHandle, CommResult, RetryPolicy, ThreadComm};
use crate::coordinator::outer::{OuterOpt, OuterOptKind};
use crate::fault::{FaultKind, FaultPlan};
use crate::tensor::{kernels, ShardSpec};
use crate::util::prng::{mix, Rng};

/// Which wire representation the pseudo-gradient reduce-scatter uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DriverPayload {
    /// Full-precision f32 payloads.
    #[default]
    F32,
    /// int8 codes + per-chunk scales (the `payload=int8` lane).
    Int8,
}

impl DriverPayload {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(DriverPayload::F32),
            "int8" => Some(DriverPayload::Int8),
            _ => None,
        }
    }
}

/// One distributed run's knobs. Everything that feeds a draw is here,
/// so two workers constructed from equal configs are bitwise twins.
#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// Flat parameter count.
    pub params: usize,
    /// Outer rounds to run.
    pub rounds: usize,
    /// Inner SGD steps per round.
    pub inner_steps: usize,
    /// Master seed; every draw derives from it statelessly.
    pub seed: u64,
    /// Inner-loop learning rate.
    pub inner_lr: f32,
    /// Outer optimizer (paper default: Nesterov 0.8/0.85).
    pub outer: OuterOptKind,
    /// Pseudo-gradient wire representation.
    pub payload: DriverPayload,
    /// Per-collective retry/backoff policy.
    pub retry: RetryPolicy,
    /// Contiguous module count the parameter vector is split into; the
    /// round syncs module-by-module (EDiT's layer-wise shape). `1`
    /// reproduces the pre-module digests exactly.
    pub modules: usize,
    /// Issue module `m`'s collectives nonblocking and overlap them with
    /// module `m+1`'s inner compute. Bitwise identical to the blocking
    /// schedule at equal `modules`.
    pub overlap: bool,
    /// Wire-level chaos schedule: the `FaultKind::is_net` events keyed
    /// to this rank are injected at the top of their round (link drops,
    /// delays, partitions). Pure schedule — an empty plan is the
    /// fast path, and injection never feeds a stochastic draw, so the
    /// final anchor is unchanged by the plan (that is the whole point:
    /// chaos runs must digest-match clean ones).
    pub net_plan: FaultPlan,
    /// Write a [`WorkerCheckpoint`] every `k` completed rounds
    /// (`0` = never). Requires `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Directory for per-rank checkpoint files
    /// (`ckpt-rank{r}-round{k}.bin`).
    pub checkpoint_dir: Option<PathBuf>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            // Odd on purpose: uneven shards and a quant-chunk remainder.
            params: 1000,
            rounds: 3,
            inner_steps: 4,
            seed: 42,
            inner_lr: 0.05,
            outer: OuterOptKind::paper_nesterov(),
            payload: DriverPayload::F32,
            retry: RetryPolicy {
                max_attempts: 5,
                base_backoff: Duration::from_millis(20),
                timeout: Duration::from_secs(5),
            },
            modules: 1,
            overlap: false,
            net_plan: FaultPlan::default(),
            checkpoint_every: 0,
            checkpoint_dir: None,
        }
    }
}

/// What a worker ends with.
#[derive(Debug, Clone, PartialEq)]
pub struct DriverOutcome {
    /// The final synchronized anchor (identical across live ranks).
    pub anchor: Vec<f32>,
    /// FNV-1a over the anchor's raw f32 bits — the value the launcher
    /// prints and the smoke scripts diff.
    pub digest: u64,
    /// Rounds completed.
    pub rounds_done: usize,
    /// Ranks this worker observed dying, in detection order.
    pub evictions: Vec<usize>,
    /// Wall clock over all rounds (barrier to final gather).
    pub elapsed: Duration,
    /// Portion of `elapsed` spent blocked inside collective calls —
    /// issue backpressure, waits, and retries. `sync_wait / elapsed` is
    /// the measured exposed-sync fraction the bench gate compares to
    /// `StepModel::layerwise_exposed`.
    pub sync_wait: Duration,
}

/// FNV-1a over the IEEE-754 bit patterns: any single-bit anchor
/// divergence between backends changes the printed digest.
pub fn anchor_digest(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Contiguous shard table over the live ranks (ascending), dead ranks
/// pinned to `(0, 0)`. All ranks derive it from the same dead-set, so
/// the tables agree without communication.
pub fn build_shards(total: usize, world: usize, dead: &BTreeSet<usize>) -> Vec<(usize, usize)> {
    let live: Vec<usize> = (0..world).filter(|r| !dead.contains(r)).collect();
    let spec = ShardSpec::new(total, live.len().max(1));
    let mut out = vec![(0usize, 0usize); world];
    for (i, &r) in live.iter().enumerate() {
        out[r] = spec.range(i);
    }
    out
}

/// Dead-set ⇄ bitmask (ranks are `< 64` everywhere in this codebase).
fn dead_mask(dead: &BTreeSet<usize>) -> u64 {
    dead.iter().fold(0u64, |m, &r| m | (1u64 << (r as u32 & 63)))
}

fn unpack_dead(mask: u64) -> BTreeSet<usize> {
    (0..64usize).filter(|&r| mask & (1u64 << r) != 0).collect()
}

/// Split a 64-bit mask into three ≤22-bit chunks, each exact in an f32
/// (22 < 24 mantissa bits), so membership can ride a broadcast of f32s
/// without rounding. Inverse of [`f32s_to_mask`].
fn mask_to_f32s(mask: u64) -> [f32; 3] {
    [
        (mask & 0x3F_FFFF) as f32,
        ((mask >> 22) & 0x3F_FFFF) as f32,
        (mask >> 44) as f32,
    ]
}

fn f32s_to_mask(xs: &[f32]) -> u64 {
    (xs[0] as u64) | ((xs[1] as u64) << 22) | ((xs[2] as u64) << 44)
}

const CKPT_MAGIC: &[u8; 8] = b"EDTWCKPT";
const CKPT_VERSION: u8 = 1;

/// One rank's round-boundary state, enough to rejoin a socket run
/// bitwise: the synchronized anchor, the outer-optimizer momentum, the
/// agreed dead-set, and every config field that feeds a draw. Written
/// at round boundaries only — anchors are identical across live ranks
/// there, so a restore is equivalent to the rank never having left.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerCheckpoint {
    pub seed: u64,
    pub params: usize,
    pub world: usize,
    pub rank: usize,
    pub modules: usize,
    pub inner_steps: usize,
    pub inner_lr: f32,
    pub payload: DriverPayload,
    /// Next round to execute (rounds `0..round` are complete).
    pub round: usize,
    /// Dead-rank bitmask at the checkpointed boundary.
    pub dead: u64,
    pub anchor: Vec<f32>,
    pub momentum: Vec<f32>,
}

impl WorkerCheckpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 4 * (self.anchor.len() + self.momentum.len()));
        out.extend_from_slice(CKPT_MAGIC);
        out.push(CKPT_VERSION);
        let mut w = PayloadWriter::default();
        w.u64(self.seed)
            .u32(self.params as u32)
            .u32(self.world as u32)
            .u32(self.rank as u32)
            .u32(self.modules as u32)
            .u32(self.inner_steps as u32)
            .u32(self.inner_lr.to_bits())
            .u8(match self.payload {
                DriverPayload::F32 => 0,
                DriverPayload::Int8 => 1,
            })
            .u32(self.round as u32)
            .u64(self.dead)
            .f32s(&self.anchor)
            .f32s(&self.momentum);
        out.extend_from_slice(&w.finish());
        out
    }

    pub fn decode(bytes: &[u8]) -> io::Result<Self> {
        let bad = |m: &str| io::Error::new(io::ErrorKind::InvalidData, m.to_string());
        if bytes.len() < 9 || &bytes[..8] != CKPT_MAGIC {
            return Err(bad("not a worker checkpoint (bad magic)"));
        }
        if bytes[8] != CKPT_VERSION {
            return Err(bad("unsupported checkpoint version"));
        }
        let mut r = PayloadReader::new(&bytes[9..]);
        let seed = r.u64()?;
        let params = r.u32()? as usize;
        let world = r.u32()? as usize;
        let rank = r.u32()? as usize;
        let modules = r.u32()? as usize;
        let inner_steps = r.u32()? as usize;
        let inner_lr = f32::from_bits(r.u32()?);
        let payload = match r.u8()? {
            0 => DriverPayload::F32,
            1 => DriverPayload::Int8,
            _ => return Err(bad("unknown payload tag")),
        };
        let round = r.u32()? as usize;
        let dead = r.u64()?;
        let anchor = r.f32s()?;
        let momentum = r.f32s()?;
        if anchor.len() != params {
            return Err(bad("anchor length disagrees with params"));
        }
        if r.remaining() != 0 {
            return Err(bad("trailing bytes after checkpoint payload"));
        }
        Ok(Self {
            seed,
            params,
            world,
            rank,
            modules,
            inner_steps,
            inner_lr,
            payload,
            round,
            dead,
            anchor,
            momentum,
        })
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.encode())
    }

    pub fn load(path: &Path) -> io::Result<Self> {
        Self::decode(&std::fs::read(path)?)
    }

    /// Reject a restore whose config would diverge from the run it is
    /// rejoining — every field that feeds a draw must match bitwise.
    pub fn validate(&self, cfg: &DriverConfig, rank: usize, world: usize) -> Result<(), String> {
        let check = |ok: bool, what: &str| if ok { Ok(()) } else { Err(format!("checkpoint mismatch: {what}")) };
        check(self.seed == cfg.seed, "seed")?;
        check(self.params == cfg.params, "params")?;
        check(self.modules == cfg.modules.max(1), "modules")?;
        check(self.inner_steps == cfg.inner_steps, "inner_steps")?;
        check(self.inner_lr.to_bits() == cfg.inner_lr.to_bits(), "inner_lr")?;
        check(self.payload == cfg.payload, "payload")?;
        check(self.rank == rank, "rank")?;
        check(self.world == world, "world")?;
        check(self.round <= cfg.rounds, "round past configured horizon")?;
        check(self.anchor.len() == self.params, "anchor length")?;
        let want_m = OuterOpt::new(cfg.outer, cfg.params).momentum.len();
        check(self.momentum.len() == want_m, "momentum length")?;
        Ok(())
    }
}

/// The shared initial anchor: same for every rank by construction.
fn init_anchor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(mix(seed, 0xA17C_0000_0000_0001));
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// The rank's deterministic pseudo-gradient for one inner step of one
/// module. The module term is zero for `m = 0`, so a single-module run
/// draws exactly the historical stream.
fn grad_into(g: &mut [f32], seed: u64, rank: usize, round: usize, step: usize, module: usize) {
    let stream = ((round as u64) << 40)
        ^ ((step as u64) << 20)
        ^ ((module as u64) << 12)
        ^ (rank as u64)
        ^ 0x6772_6164_0000_0000;
    let mut rng = Rng::new(mix(seed, stream));
    for x in g.iter_mut() {
        *x = rng.normal_f32() * 0.1;
    }
}

/// Mutable per-worker round state threaded through the module schedule.
struct RoundState {
    rank: usize,
    anchor: Vec<f32>,
    theta: Vec<f32>,
    delta: Vec<f32>,
    grad: Vec<f32>,
    outer: OuterOpt,
    dead: BTreeSet<usize>,
    evictions: Vec<usize>,
    sync_wait: Duration,
}

impl RoundState {
    /// τ local SGD steps on module `m`'s slice, then the pseudo-gradient
    /// Δ_m = θ_{t,τ} − θ_t for that slice.
    fn compute_module(&mut self, cfg: &DriverConfig, round: usize, (moff, mlen): (usize, usize), m: usize) {
        let grad = &mut self.grad[moff..moff + mlen];
        let theta = &mut self.theta[moff..moff + mlen];
        for step in 0..cfg.inner_steps {
            grad_into(grad, cfg.seed, self.rank, round, step, m);
            kernels::axpy(theta, -cfg.inner_lr, grad);
        }
        for i in moff..moff + mlen {
            self.delta[i] = self.theta[i] - self.anchor[i];
        }
    }

    /// Outer update on the owned shard of module `m` (ZeRO-1 style).
    /// `folded` is the module-local delta slice whose own-shard region
    /// holds the live-group mean.
    fn outer_update(&mut self, moff: usize, folded: &[f32], shards_m: &[(usize, usize)]) {
        let (loff, llen) = shards_m[self.rank];
        self.outer.apply_range_scaled(
            &mut self.anchor,
            &folded[loff..loff + llen],
            moff + loff,
            1.0,
        );
    }

    /// Same update reading the fold result in place from `self.delta`
    /// (the blocking schedule's zero-copy path).
    fn outer_update_in_place(&mut self, moff: usize, shards_m: &[(usize, usize)]) {
        let (loff, llen) = shards_m[self.rank];
        let at = moff + loff;
        self.outer.apply_range_scaled(&mut self.anchor, &self.delta[at..at + llen], at, 1.0);
    }

    /// Evict `victim` (first detection records it) and drop its shard
    /// from this module's table so the retry skips its region.
    fn evict(&mut self, victim: usize, shards_m: &mut [(usize, usize)]) {
        if self.dead.insert(victim) {
            self.evictions.push(victim);
        }
        shards_m[victim] = (0, 0);
    }

    /// All-gather module `m`'s anchor slice — the membership detection
    /// point: a dead owner fails `PeerFailed`, the survivors evict it
    /// and retry with its shard zeroed (its region keeps the pre-round
    /// anchor on every survivor — consistent by identity).
    fn gather_module<C: Collective + ?Sized>(
        &mut self,
        comm: &C,
        cfg: &DriverConfig,
        (moff, mlen): (usize, usize),
        shards_m: &mut [(usize, usize)],
    ) -> CommResult<()> {
        let t0 = Instant::now();
        let r = loop {
            let slice = &mut self.anchor[moff..moff + mlen];
            match cfg.retry.run(|t| comm.try_all_gather(slice, shards_m, t)) {
                Ok(()) => break Ok(()),
                Err(CommError::PeerFailed { rank: victim }) => self.evict(victim, shards_m),
                Err(e) => break Err(e),
            }
        };
        self.sync_wait += t0.elapsed();
        r
    }
}

/// Issue module `m`'s pseudo-gradient reduce-scatter nonblocking.
fn issue_rs<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
    delta_m: &[f32],
    shards_m: &[(usize, usize)],
) -> CommHandle {
    let t = cfg.retry.timeout;
    match cfg.payload {
        DriverPayload::F32 => comm.start_reduce_scatter_mean(delta_m.to_vec(), shards_m, t),
        DriverPayload::Int8 => comm.start_reduce_scatter_mean_q8(delta_m.to_vec(), shards_m, t),
    }
}

/// Run one worker's rounds over `comm`. Generic over the backend —
/// this is the function both `edit-train worker --join` (SocketComm)
/// and `--local` (ThreadComm threads) execute.
pub fn run_worker<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
) -> CommResult<DriverOutcome> {
    run_worker_resumed(comm, cfg, None)
}

/// [`run_worker`] with two entry variants beyond the fresh start:
///
///  * `resume = Some(ck)` — restart from a round-boundary checkpoint:
///    anchor/momentum/dead-set come from `ck` and execution begins at
///    `ck.round`. Caller is responsible for [`WorkerCheckpoint::validate`].
///  * `comm.late_joiner()` — this rank was admitted mid-run. It cannot
///    know the group's round, so after its first barrier it adopts
///    `(round, dead, anchor)` from a rank-0-rooted broadcast and
///    participates from the next boundary. Existing ranks detect the
///    admission as `comm.size()` growth after a barrier and feed the
///    same broadcast; join-free runs never issue it, keeping their
///    collective schedule (and digests) bitwise unchanged. Rank 0 must
///    be alive at admission (it roots the state transfer).
pub fn run_worker_resumed<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
    resume: Option<&WorkerCheckpoint>,
) -> CommResult<DriverOutcome> {
    let mut world = comm.size();
    let rank = comm.rank();
    let n = cfg.params;
    let modules = cfg.modules.max(1);
    let mspec = ShardSpec::new(n, modules);
    let mut st = RoundState {
        rank,
        anchor: init_anchor(n, cfg.seed),
        theta: Vec::new(),
        delta: vec![0.0f32; n],
        grad: vec![0.0f32; n],
        outer: OuterOpt::new(cfg.outer, n),
        dead: BTreeSet::new(),
        evictions: Vec::new(),
        sync_wait: Duration::ZERO,
    };
    let mut round = 0usize;
    if let Some(ck) = resume {
        st.anchor.copy_from_slice(&ck.anchor);
        st.outer.momentum.clear();
        st.outer.momentum.extend_from_slice(&ck.momentum);
        st.dead = unpack_dead(ck.dead);
        round = ck.round;
    }
    st.theta = st.anchor.clone();
    let mut adopting = resume.is_none() && comm.late_joiner();
    let mut rounds_done = 0usize;
    let started = Instant::now();

    while adopting || round < cfg.rounds {
        // Wire-level chaos scheduled for (round, rank): severed links
        // exercise reconnect-with-replay, delays stretch the round so
        // grace/admission windows get hit deterministically. None of it
        // feeds a draw — a chaos run must digest-match a clean one.
        if !adopting {
            for ev in cfg.net_plan.net_events_at(round as u64, rank) {
                match ev.kind {
                    FaultKind::NetDrop => comm.drop_link(),
                    FaultKind::NetDelay { ms } => std::thread::sleep(Duration::from_millis(ms)),
                    FaultKind::Partition { secs } => {
                        comm.drop_link();
                        std::thread::sleep(Duration::from_secs_f64(secs));
                    }
                    _ => {}
                }
            }
        }

        // Per-module shard tables (module-local offsets). All ranks
        // derive them from the same dead-set, so they agree.
        let mut shards: Vec<Vec<(usize, usize)>> =
            (0..modules).map(|m| build_shards(mspec.range(m).1, world, &st.dead)).collect();
        let tb = Instant::now();
        cfg.retry.run(|t| comm.try_barrier(t))?;
        st.sync_wait += tb.elapsed();

        if adopting || comm.size() > world {
            // Join sync: a fixed-layout broadcast carries the round
            // counter, the dead-mask (three exact-in-f32 chunks), and
            // the anchor from rank 0 to the joiner. Established ranks
            // already hold identical copies, so the broadcast cannot
            // change their state.
            let mut state = vec![0.0f32; 4 + n];
            if !adopting && rank == 0 {
                state[0] = round as f32;
                state[1..4].copy_from_slice(&mask_to_f32s(dead_mask(&st.dead)));
                state[4..].copy_from_slice(&st.anchor);
            }
            let tj = Instant::now();
            cfg.retry.run(|t| comm.try_broadcast(&mut state, 0, t))?;
            st.sync_wait += tj.elapsed();
            if adopting {
                round = state[0] as usize;
                st.dead = unpack_dead(f32s_to_mask(&state[1..4]));
                st.anchor.copy_from_slice(&state[4..]);
                st.theta.copy_from_slice(&st.anchor);
                adopting = false;
                if round >= cfg.rounds {
                    break;
                }
            }
            world = comm.size();
            shards =
                (0..modules).map(|m| build_shards(mspec.range(m).1, world, &st.dead)).collect();
        }

        if cfg.overlap {
            overlapped_round(comm, cfg, &mut st, &mspec, &mut shards, round)?;
        } else {
            for m in 0..modules {
                let (moff, mlen) = mspec.range(m);
                st.compute_module(cfg, round, (moff, mlen), m);

                // Reduce-scatter module m's pseudo-gradients: own region
                // ends with the live-group mean. A rank dying here
                // degrades silently.
                let t0 = Instant::now();
                cfg.retry.run(|t| {
                    let slice = &mut st.delta[moff..moff + mlen];
                    match cfg.payload {
                        DriverPayload::F32 => comm.try_reduce_scatter_mean(slice, &shards[m], t),
                        DriverPayload::Int8 => {
                            comm.try_reduce_scatter_mean_q8(slice, &shards[m], t)
                        }
                    }
                })?;
                st.sync_wait += t0.elapsed();

                st.outer_update_in_place(moff, &shards[m]);
                st.gather_module(comm, cfg, (moff, mlen), &mut shards[m])?;
            }
        }

        // Inner restart from the synchronized anchor.
        st.theta.copy_from_slice(&st.anchor);
        round += 1;
        rounds_done += 1;

        // Round-boundary checkpoint: anchors agree across live ranks
        // here, so the file alone is enough to rejoin bitwise. An IO
        // failure degrades the checkpoint, never the run.
        if cfg.checkpoint_every > 0 && round % cfg.checkpoint_every == 0 {
            if let Some(dir) = &cfg.checkpoint_dir {
                let ck = WorkerCheckpoint {
                    seed: cfg.seed,
                    params: n,
                    world,
                    rank,
                    modules,
                    inner_steps: cfg.inner_steps,
                    inner_lr: cfg.inner_lr,
                    payload: cfg.payload,
                    round,
                    dead: dead_mask(&st.dead),
                    anchor: st.anchor.clone(),
                    momentum: st.outer.momentum.clone(),
                };
                let path = dir.join(format!("ckpt-rank{rank}-round{round}.bin"));
                if let Err(e) = ck.save(&path) {
                    eprintln!("warn: checkpoint write failed ({}): {e}", path.display());
                }
            }
        }
    }

    let digest = anchor_digest(&st.anchor);
    Ok(DriverOutcome {
        anchor: st.anchor,
        digest,
        rounds_done,
        evictions: st.evictions,
        elapsed: started.elapsed(),
        sync_wait: st.sync_wait,
    })
}

/// The overlapped module schedule: issue module `m`'s reduce-scatter,
/// compute module `m+1` while it folds, and wait only at each
/// dependency point. At most three ops are in flight (`rs_{m}`,
/// `ag_{m-1}`, `ag_{m-2}`), inside the backends' `PIPELINE_WINDOW`.
/// Fold order and membership semantics match the blocking schedule, so
/// the result is bitwise identical.
fn overlapped_round<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
    st: &mut RoundState,
    mspec: &ShardSpec,
    shards: &mut [Vec<(usize, usize)>],
    round: usize,
) -> CommResult<()> {
    let modules = shards.len();
    let mut rs_h: Vec<Option<CommHandle>> = (0..modules).map(|_| None).collect();
    let mut ag_h: Vec<Option<CommHandle>> = (0..modules).map(|_| None).collect();

    // Wait for module m's reduce-scatter, apply the outer update on the
    // owned shard, and immediately issue module m's all-gather.
    fn fold_and_gather<C: Collective + ?Sized>(
        comm: &C,
        cfg: &DriverConfig,
        st: &mut RoundState,
        mspec: &ShardSpec,
        shards: &[Vec<(usize, usize)>],
        m: usize,
        rs: CommHandle,
    ) -> CommResult<CommHandle> {
        let (moff, mlen) = mspec.range(m);
        let t0 = Instant::now();
        let folded = comm.wait_handle(rs)?;
        st.sync_wait += t0.elapsed();
        st.outer_update(moff, &folded, &shards[m]);
        Ok(comm.start_all_gather(
            st.anchor[moff..moff + mlen].to_vec(),
            &shards[m],
            cfg.retry.timeout,
        ))
    }

    // Complete module m's all-gather; on PeerFailed fall back to the
    // blocking evict/zero-shard/retry loop (the anchor slice is still
    // intact — the gather operated on a copy).
    fn finish_gather<C: Collective + ?Sized>(
        comm: &C,
        cfg: &DriverConfig,
        st: &mut RoundState,
        mspec: &ShardSpec,
        shards_m: &mut [(usize, usize)],
        m: usize,
        ag: CommHandle,
    ) -> CommResult<()> {
        let (moff, mlen) = mspec.range(m);
        let t0 = Instant::now();
        match comm.wait_handle(ag) {
            Ok(buf) => {
                st.anchor[moff..moff + mlen].copy_from_slice(&buf);
                st.sync_wait += t0.elapsed();
                Ok(())
            }
            Err(CommError::PeerFailed { rank: victim }) => {
                st.sync_wait += t0.elapsed();
                st.evict(victim, shards_m);
                st.gather_module(comm, cfg, (moff, mlen), shards_m)
            }
            Err(e) => {
                st.sync_wait += t0.elapsed();
                Err(e)
            }
        }
    }

    for m in 0..modules {
        st.compute_module(cfg, round, mspec.range(m), m);
        let (moff, mlen) = mspec.range(m);
        rs_h[m] = Some(issue_rs(comm, cfg, &st.delta[moff..moff + mlen], &shards[m]));
        if m >= 1 {
            let rs = rs_h[m - 1].take().expect("rs handle issued last iteration");
            ag_h[m - 1] = Some(fold_and_gather(comm, cfg, st, mspec, shards, m - 1, rs)?);
        }
        if m >= 2 {
            let ag = ag_h[m - 2].take().expect("ag handle issued last iteration");
            finish_gather(comm, cfg, st, mspec, &mut shards[m - 2], m - 2, ag)?;
        }
    }
    // Drain the tail: rs_{M-1} → ag_{M-1}, then the last two gathers.
    let rs = rs_h[modules - 1].take().expect("tail rs handle");
    ag_h[modules - 1] = Some(fold_and_gather(comm, cfg, st, mspec, shards, modules - 1, rs)?);
    for m in modules.saturating_sub(2)..modules {
        if let Some(ag) = ag_h[m].take() {
            finish_gather(comm, cfg, st, mspec, &mut shards[m], m, ag)?;
        }
    }
    Ok(())
}

/// Run a `world`-rank group on OS threads over a shared [`ThreadComm`]
/// — the in-process reference the socket path is diffed against.
pub fn run_local_group(world: usize, cfg: &DriverConfig) -> CommResult<Vec<DriverOutcome>> {
    let comms = ThreadComm::group(world);
    let mut out = Vec::with_capacity(world);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| s.spawn(move || run_worker(c, cfg)))
            .collect();
        for h in handles {
            out.push(h.join().expect("worker thread panicked"));
        }
    });
    out.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_group_ranks_agree_and_runs_reproduce() {
        let cfg = DriverConfig { params: 257, rounds: 3, ..Default::default() };
        for world in [1usize, 2, 3] {
            let a = run_local_group(world, &cfg).unwrap();
            for o in &a[1..] {
                assert_eq!(o.anchor, a[0].anchor, "world={world}");
            }
            let b = run_local_group(world, &cfg).unwrap();
            assert_eq!(a[0].digest, b[0].digest, "world={world}");
            assert!(a[0].evictions.is_empty());
        }
        // Different worlds genuinely shard differently but still sync:
        // the digest must be a function of (seed, world).
        let w2 = run_local_group(2, &cfg).unwrap();
        let w3 = run_local_group(3, &cfg).unwrap();
        assert_ne!(w2[0].digest, w3[0].digest);
    }

    #[test]
    fn int8_payload_differs_but_is_deterministic() {
        let f32cfg = DriverConfig { params: 300, ..Default::default() };
        let q8cfg = DriverConfig { payload: DriverPayload::Int8, ..f32cfg.clone() };
        let a = run_local_group(2, &f32cfg).unwrap();
        let b = run_local_group(2, &q8cfg).unwrap();
        let c = run_local_group(2, &q8cfg).unwrap();
        assert_ne!(a[0].digest, b[0].digest, "quantization must be observable");
        assert_eq!(b[0].digest, c[0].digest);
        assert_eq!(b[0].anchor, b[1].anchor);
    }

    #[test]
    fn overlapped_schedule_is_bitwise_identical() {
        for payload in [DriverPayload::F32, DriverPayload::Int8] {
            for modules in [1usize, 3, 4] {
                let blocking =
                    DriverConfig { params: 257, modules, payload, ..Default::default() };
                let overlapped = DriverConfig { overlap: true, ..blocking.clone() };
                for world in [1usize, 2, 3] {
                    let a = run_local_group(world, &blocking).unwrap();
                    let b = run_local_group(world, &overlapped).unwrap();
                    assert_eq!(
                        a[0].digest, b[0].digest,
                        "overlap changed the result: world={world} modules={modules} payload={payload:?}"
                    );
                    assert_eq!(a[0].anchor, b[0].anchor);
                }
            }
        }
    }

    #[test]
    fn single_module_layout_preserves_legacy_stream() {
        // modules=1 must draw the historical gradient stream: splitting
        // into modules only changes results when modules > 1.
        let legacy = DriverConfig { params: 300, ..Default::default() };
        let single = DriverConfig { modules: 1, ..legacy.clone() };
        let multi = DriverConfig { modules: 4, ..legacy.clone() };
        let a = run_local_group(2, &legacy).unwrap();
        let b = run_local_group(2, &single).unwrap();
        let c = run_local_group(2, &multi).unwrap();
        assert_eq!(a[0].digest, b[0].digest);
        assert_ne!(a[0].digest, c[0].digest, "module split must be observable");
    }

    #[test]
    fn dead_rank_is_evicted_and_survivors_agree() {
        // Rank 2 never shows up; a monitor marks it failed while the
        // survivors block on the first barrier — the driver must evict
        // at the all-gather and finish over the live pair.
        let cfg = DriverConfig { params: 101, rounds: 3, ..Default::default() };
        let comms = ThreadComm::group(3);
        let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
        let cfg = &cfg;
        let (a, b) = std::thread::scope(|s| {
            let h0 = s.spawn(move || run_worker(c0, cfg));
            let h1 = s.spawn(move || run_worker(c1, cfg));
            let m = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                c2.mark_failed(2);
            });
            m.join().unwrap();
            (h0.join().unwrap().unwrap(), h1.join().unwrap().unwrap())
        });
        assert_eq!(a.anchor, b.anchor);
        assert_eq!(a.evictions, vec![2]);
        assert_eq!(b.evictions, vec![2]);
    }

    #[test]
    fn dead_rank_is_evicted_under_overlap() {
        // Same scenario with in-flight handles: the PeerFailed surfaces
        // at a gather wait and the fallback evict/retry loop must leave
        // the survivors in agreement.
        let cfg = DriverConfig {
            params: 101,
            rounds: 3,
            modules: 4,
            overlap: true,
            ..Default::default()
        };
        let comms = ThreadComm::group(3);
        let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
        let cfg = &cfg;
        let (a, b) = std::thread::scope(|s| {
            let h0 = s.spawn(move || run_worker(c0, cfg));
            let h1 = s.spawn(move || run_worker(c1, cfg));
            let m = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                c2.mark_failed(2);
            });
            m.join().unwrap();
            (h0.join().unwrap().unwrap(), h1.join().unwrap().unwrap())
        });
        assert_eq!(a.anchor, b.anchor);
        assert_eq!(a.evictions, vec![2]);
        assert_eq!(b.evictions, vec![2]);
    }

    #[test]
    fn dead_mask_f32_chunks_are_exact() {
        for mask in [0u64, 1, 0b101, 0x3F_FFFF, u64::MAX, 0xDEAD_BEEF_CAFE_0123] {
            assert_eq!(f32s_to_mask(&mask_to_f32s(mask)), mask);
        }
    }

    #[test]
    fn net_plan_injection_does_not_change_digest() {
        // Chaos is schedule-only: a ThreadComm run (where drop_link is
        // a no-op and delays just sleep) must digest-match clean.
        let clean = DriverConfig { params: 64, rounds: 2, ..Default::default() };
        let plan = crate::fault::FaultPlan::parse("netdrop@0:1,netdelay@1:0:5", 0, 2).unwrap();
        let chaotic = DriverConfig { net_plan: plan, ..clean.clone() };
        let a = run_local_group(2, &clean).unwrap();
        let b = run_local_group(2, &chaotic).unwrap();
        assert_eq!(a[0].digest, b[0].digest);
    }

    #[test]
    fn checkpoint_codec_roundtrips_and_validate_rejects_mismatches() {
        let ck = WorkerCheckpoint {
            seed: 7,
            params: 5,
            world: 3,
            rank: 1,
            modules: 2,
            inner_steps: 4,
            inner_lr: 0.05,
            payload: DriverPayload::Int8,
            round: 2,
            dead: 0b100,
            anchor: vec![1.0, -2.5, 0.0, 3.25, f32::from_bits(0x7f80_0001)],
            momentum: vec![0.5, -0.5, 0.25, 0.0, 9.0],
        };
        // The anchor carries a NaN bit pattern on purpose: compare the
        // encodings, which are bit-transparent, not the floats.
        let back = WorkerCheckpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.encode(), ck.encode());
        assert_eq!(back.round, 2);
        assert_eq!(back.dead, 0b100);
        assert!(WorkerCheckpoint::decode(&ck.encode()[..10]).is_err(), "truncated");
        assert!(WorkerCheckpoint::decode(b"NOTACKPT\x01rest").is_err(), "bad magic");

        let cfg = DriverConfig {
            seed: 7,
            params: 5,
            rounds: 4,
            modules: 2,
            inner_steps: 4,
            inner_lr: 0.05,
            payload: DriverPayload::Int8,
            ..Default::default()
        };
        assert!(ck.validate(&cfg, 1, 3).is_ok());
        assert!(ck.validate(&cfg, 0, 3).is_err(), "wrong rank");
        assert!(ck.validate(&cfg, 1, 2).is_err(), "wrong world");
        let other_seed = DriverConfig { seed: 8, ..cfg.clone() };
        assert!(ck.validate(&other_seed, 1, 3).is_err(), "wrong seed");
        let short = DriverConfig { rounds: 1, ..cfg.clone() };
        assert!(ck.validate(&short, 1, 3).is_err(), "round past horizon");
    }

    #[test]
    fn checkpoint_restore_matches_uninterrupted_run() {
        let dir = std::env::temp_dir().join(format!("edit-driver-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let clean = DriverConfig { params: 120, rounds: 5, ..Default::default() };
        let reference = run_local_group(2, &clean).unwrap();

        // Phase 1: stop after round 3, leaving per-rank checkpoints.
        let phase1 = DriverConfig {
            rounds: 3,
            checkpoint_every: 3,
            checkpoint_dir: Some(dir.clone()),
            ..clean.clone()
        };
        run_local_group(2, &phase1).unwrap();

        // Phase 2: restore each rank and run the remaining rounds.
        let cks: Vec<WorkerCheckpoint> = (0..2)
            .map(|r| {
                WorkerCheckpoint::load(&dir.join(format!("ckpt-rank{r}-round3.bin"))).unwrap()
            })
            .collect();
        for (r, ck) in cks.iter().enumerate() {
            ck.validate(&clean, r, 2).unwrap();
            assert_eq!(ck.round, 3);
        }
        let comms = ThreadComm::group(2);
        let cfg = &clean;
        let outs: Vec<DriverOutcome> = std::thread::scope(|s| {
            let hs: Vec<_> = comms
                .iter()
                .zip(&cks)
                .map(|(c, ck)| s.spawn(move || run_worker_resumed(c, cfg, Some(ck)).unwrap()))
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(outs[0].digest, reference[0].digest, "restore must replay bitwise");
        assert_eq!(outs[0].anchor, outs[1].anchor);
        assert_eq!(outs[0].rounds_done, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
