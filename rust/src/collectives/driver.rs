//! Backend-generic distributed EDiT sync driver.
//!
//! The trainer's own sync path simulates its cluster in-process (the
//! scratch-arena pipeline priced by the α-β model); *this* module runs
//! the same outer-round shape — inner SGD steps, reduce-scatter of the
//! pseudo-gradients, Nesterov outer update on the owned shard,
//! all-gather of the anchor — over any [`Collective`] backend, with
//! every stochastic draw stateless in `(seed, round, step, rank)`.
//! That makes it the equivalence probe for transports: the same
//! `DriverConfig` must produce a **bitwise identical final anchor**
//! whether the ranks are OS threads sharing a `ThreadComm` or OS
//! processes speaking sockets through the rendezvous hub
//! (`edit-train worker --join` vs `--local`; asserted by
//! `tests/socket_backend.rs` and `scripts/smoke_multiproc.sh`).
//!
//! # Membership degrade
//!
//! A rank that dies mid-run shrinks the group, mirroring the trainer's
//! eviction policy:
//!
//!  * reductions silently fold the live ranks (the backends' contract);
//!  * the all-gather is the detection point — a dead shard owner fails
//!    `PeerFailed`, the survivors zero its shard entry and retry, and
//!    the dead rank's region keeps its pre-round anchor values (every
//!    survivor holds the same full anchor, so the skip is consistent);
//!  * from the next round boundary, shards are rebuilt over the
//!    survivors, restoring full coverage.

use std::collections::BTreeSet;
use std::time::Duration;

use crate::collectives::{Collective, CommError, CommResult, RetryPolicy, ThreadComm};
use crate::coordinator::outer::{OuterOpt, OuterOptKind};
use crate::tensor::{kernels, ShardSpec};
use crate::util::prng::{mix, Rng};

/// Which wire representation the pseudo-gradient reduce-scatter uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DriverPayload {
    /// Full-precision f32 payloads.
    #[default]
    F32,
    /// int8 codes + per-chunk scales (the `payload=int8` lane).
    Int8,
}

impl DriverPayload {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(DriverPayload::F32),
            "int8" => Some(DriverPayload::Int8),
            _ => None,
        }
    }
}

/// One distributed run's knobs. Everything that feeds a draw is here,
/// so two workers constructed from equal configs are bitwise twins.
#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// Flat parameter count.
    pub params: usize,
    /// Outer rounds to run.
    pub rounds: usize,
    /// Inner SGD steps per round.
    pub inner_steps: usize,
    /// Master seed; every draw derives from it statelessly.
    pub seed: u64,
    /// Inner-loop learning rate.
    pub inner_lr: f32,
    /// Outer optimizer (paper default: Nesterov 0.8/0.85).
    pub outer: OuterOptKind,
    /// Pseudo-gradient wire representation.
    pub payload: DriverPayload,
    /// Per-collective retry/backoff policy.
    pub retry: RetryPolicy,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            // Odd on purpose: uneven shards and a quant-chunk remainder.
            params: 1000,
            rounds: 3,
            inner_steps: 4,
            seed: 42,
            inner_lr: 0.05,
            outer: OuterOptKind::paper_nesterov(),
            payload: DriverPayload::F32,
            retry: RetryPolicy {
                max_attempts: 5,
                base_backoff: Duration::from_millis(20),
                timeout: Duration::from_secs(5),
            },
        }
    }
}

/// What a worker ends with.
#[derive(Debug, Clone, PartialEq)]
pub struct DriverOutcome {
    /// The final synchronized anchor (identical across live ranks).
    pub anchor: Vec<f32>,
    /// FNV-1a over the anchor's raw f32 bits — the value the launcher
    /// prints and the smoke scripts diff.
    pub digest: u64,
    /// Rounds completed.
    pub rounds_done: usize,
    /// Ranks this worker observed dying, in detection order.
    pub evictions: Vec<usize>,
}

/// FNV-1a over the IEEE-754 bit patterns: any single-bit anchor
/// divergence between backends changes the printed digest.
pub fn anchor_digest(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Contiguous shard table over the live ranks (ascending), dead ranks
/// pinned to `(0, 0)`. All ranks derive it from the same dead-set, so
/// the tables agree without communication.
pub fn build_shards(total: usize, world: usize, dead: &BTreeSet<usize>) -> Vec<(usize, usize)> {
    let live: Vec<usize> = (0..world).filter(|r| !dead.contains(r)).collect();
    let spec = ShardSpec::new(total, live.len().max(1));
    let mut out = vec![(0usize, 0usize); world];
    for (i, &r) in live.iter().enumerate() {
        out[r] = spec.range(i);
    }
    out
}

/// The shared initial anchor: same for every rank by construction.
fn init_anchor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(mix(seed, 0xA17C_0000_0000_0001));
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// The rank's deterministic pseudo-gradient for one inner step.
fn grad_into(g: &mut [f32], seed: u64, rank: usize, round: usize, step: usize) {
    let stream =
        ((round as u64) << 40) ^ ((step as u64) << 20) ^ (rank as u64) ^ 0x6772_6164_0000_0000;
    let mut rng = Rng::new(mix(seed, stream));
    for x in g.iter_mut() {
        *x = rng.normal_f32() * 0.1;
    }
}

/// Run one worker's rounds over `comm`. Generic over the backend —
/// this is the function both `edit-train worker --join` (SocketComm)
/// and `--local` (ThreadComm threads) execute.
pub fn run_worker<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
) -> CommResult<DriverOutcome> {
    let world = comm.size();
    let rank = comm.rank();
    let n = cfg.params;
    let mut dead: BTreeSet<usize> = BTreeSet::new();
    let mut evictions: Vec<usize> = Vec::new();
    let mut anchor = init_anchor(n, cfg.seed);
    let mut theta = anchor.clone();
    let mut delta = vec![0.0f32; n];
    let mut grad = vec![0.0f32; n];
    let mut outer = OuterOpt::new(cfg.outer, n);

    for round in 0..cfg.rounds {
        let mut shards = build_shards(n, world, &dead);
        cfg.retry.run(|t| comm.try_barrier(t))?;

        // Inner loop: τ local SGD steps on deterministic gradients.
        for step in 0..cfg.inner_steps {
            grad_into(&mut grad, cfg.seed, rank, round, step);
            kernels::axpy(&mut theta, -cfg.inner_lr, &grad);
        }
        // Pseudo-gradient Δ = θ_{t,τ} − θ_t (inner progress).
        for i in 0..n {
            delta[i] = theta[i] - anchor[i];
        }

        // Reduce-scatter the pseudo-gradients: own region ends with the
        // live-group mean. A rank dying here degrades silently.
        cfg.retry.run(|t| match cfg.payload {
            DriverPayload::F32 => comm.try_reduce_scatter_mean(&mut delta, &shards, t),
            DriverPayload::Int8 => comm.try_reduce_scatter_mean_q8(&mut delta, &shards, t),
        })?;

        // Outer update on the owned shard only (ZeRO-1 style).
        let (off, len) = shards[rank];
        outer.apply_range_scaled(&mut anchor, &delta[off..off + len], off, 1.0);

        // All-gather the updated anchor — the membership detection
        // point: a dead owner fails PeerFailed, the survivors evict it
        // and retry with its shard zeroed (its region keeps the
        // pre-round anchor on every survivor — consistent by identity).
        loop {
            match cfg.retry.run(|t| comm.try_all_gather(&mut anchor, &shards, t)) {
                Ok(()) => break,
                Err(CommError::PeerFailed { rank: victim }) => {
                    if dead.insert(victim) {
                        evictions.push(victim);
                    }
                    shards[victim] = (0, 0);
                }
                Err(e) => return Err(e),
            }
        }

        // Inner restart from the synchronized anchor.
        theta.copy_from_slice(&anchor);
    }

    let digest = anchor_digest(&anchor);
    Ok(DriverOutcome { anchor, digest, rounds_done: cfg.rounds, evictions })
}

/// Run a `world`-rank group on OS threads over a shared [`ThreadComm`]
/// — the in-process reference the socket path is diffed against.
pub fn run_local_group(world: usize, cfg: &DriverConfig) -> CommResult<Vec<DriverOutcome>> {
    let comms = ThreadComm::group(world);
    let mut out = Vec::with_capacity(world);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| s.spawn(move || run_worker(c, cfg)))
            .collect();
        for h in handles {
            out.push(h.join().expect("worker thread panicked"));
        }
    });
    out.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_group_ranks_agree_and_runs_reproduce() {
        let cfg = DriverConfig { params: 257, rounds: 3, ..Default::default() };
        for world in [1usize, 2, 3] {
            let a = run_local_group(world, &cfg).unwrap();
            for o in &a[1..] {
                assert_eq!(o.anchor, a[0].anchor, "world={world}");
            }
            let b = run_local_group(world, &cfg).unwrap();
            assert_eq!(a[0].digest, b[0].digest, "world={world}");
            assert!(a[0].evictions.is_empty());
        }
        // Different worlds genuinely shard differently but still sync:
        // the digest must be a function of (seed, world).
        let w2 = run_local_group(2, &cfg).unwrap();
        let w3 = run_local_group(3, &cfg).unwrap();
        assert_ne!(w2[0].digest, w3[0].digest);
    }

    #[test]
    fn int8_payload_differs_but_is_deterministic() {
        let f32cfg = DriverConfig { params: 300, ..Default::default() };
        let q8cfg = DriverConfig { payload: DriverPayload::Int8, ..f32cfg.clone() };
        let a = run_local_group(2, &f32cfg).unwrap();
        let b = run_local_group(2, &q8cfg).unwrap();
        let c = run_local_group(2, &q8cfg).unwrap();
        assert_ne!(a[0].digest, b[0].digest, "quantization must be observable");
        assert_eq!(b[0].digest, c[0].digest);
        assert_eq!(b[0].anchor, b[1].anchor);
    }

    #[test]
    fn dead_rank_is_evicted_and_survivors_agree() {
        // Rank 2 never shows up; a monitor marks it failed while the
        // survivors block on the first barrier — the driver must evict
        // at the all-gather and finish over the live pair.
        let cfg = DriverConfig { params: 101, rounds: 3, ..Default::default() };
        let comms = ThreadComm::group(3);
        let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
        let cfg = &cfg;
        let (a, b) = std::thread::scope(|s| {
            let h0 = s.spawn(move || run_worker(c0, cfg));
            let h1 = s.spawn(move || run_worker(c1, cfg));
            let m = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                c2.mark_failed(2);
            });
            m.join().unwrap();
            (h0.join().unwrap().unwrap(), h1.join().unwrap().unwrap())
        });
        assert_eq!(a.anchor, b.anchor);
        assert_eq!(a.evictions, vec![2]);
        assert_eq!(b.evictions, vec![2]);
    }
}
